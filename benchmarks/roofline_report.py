"""Roofline report: reads results/dryrun.json (written by the multi-pod
dry-run) and prints the per-(arch x shape x mesh) three-term roofline table
used in EXPERIMENTS.md §Roofline."""
from __future__ import annotations

import json
import os


def run(path="results/dryrun.json", mesh="pod16x16"):
    if not os.path.exists(path):
        print(f"rooflinereport: {path} missing — run "
              "PYTHONPATH=src python -m repro.launch.dryrun first")
        return []
    with open(path) as f:
        data = json.load(f)
    rows = []
    hdr = (f"{'arch':22s} {'shape':12s} {'comp_s':>9s} {'mem_s':>9s} "
           f"{'coll_s':>9s} {'dom':>6s} {'frac':>6s} {'useful':>7s}")
    print(hdr)
    for key, v in sorted(data.items()):
        if v.get("status") != "ok" or v.get("mesh") != mesh:
            continue
        r = v["roofline"]
        rows.append((v["arch"], v["shape"], r))
        print(f"{v['arch']:22s} {v['shape']:12s} "
              f"{r['compute_s']:9.4f} {r['memory_s']:9.4f} "
              f"{r['collective_s']:9.4f} {r['dominant'][:6]:>6s} "
              f"{r['roofline_fraction']:6.3f} "
              f"{v['useful_flops_ratio']:7.3f}")
    return rows


if __name__ == "__main__":
    run()

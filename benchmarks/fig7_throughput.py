"""Fig. 7: throughput under varying load, all three TPC-W mixes.

SharedDB vs query-at-a-time over offered-load sweep; reports good WIPS
(web interactions completing within their TPC-W timeout).
"""
from __future__ import annotations

import numpy as np

from benchmarks import common


def run(rates=(10, 40, 120, 250), duration=10.0,
        mixes=("browsing", "shopping", "ordering"), seed=7):
    rng = np.random.default_rng(seed)
    plan, shared, baseline, gen = common.build_engines(rng)
    common.warmup(shared, baseline, gen)
    rows = []
    for mix in mixes:
        for rate in rates:
            arr, dur = common.poisson_arrivals(rng, gen, mix, rate, duration)
            rs = common.run_shared(shared, arr, dur)
            arr2, _ = common.poisson_arrivals(rng, gen, mix, rate, duration)
            rb = common.run_baseline(baseline, arr2, dur)
            rows.append((mix, rate, rs, rb))
            print(f"fig7 {mix:9s} rate={rate:3d}/s  "
                  f"shared: good={rs.good_wips:6.2f} p99={rs.p99_s:6.2f}s "
                  f"cyc={rs.mean_cycle_s*1e3:6.0f}ms | "
                  f"qaat: good={rb.good_wips:6.2f} p99={rb.p99_s:6.2f}s",
                  flush=True)
    return rows


if __name__ == "__main__":
    run()

"""Fig. 10: batch completion time, heavy vs light queries.

Light = ProductDetail's get_book (PK join, 1 row).  Heavy = BestSellers
(3-table join + group-by + top-50).  SharedDB executes a batch in O(cycles)
with bounded per-cycle work; query-at-a-time grows linearly.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks import common

INT_MAX = 2147483647


def _batch(gen, template: str, n: int):
    items = []
    for _ in range(n):
        if template == "get_book":
            i = int(gen.rng.integers(0, gen.n_items))
            items.append(("get_book", {0: (i, i)}))
        else:
            lo = max(0, gen._next_order - 3333)
            subj = int(gen.rng.integers(0, 24))
            items.append(("best_sellers",
                          {0: (lo, INT_MAX), 1: (subj, subj)}))
    return items


def run(sizes=(1, 4, 16, 64, 256), seed=11):
    rng = np.random.default_rng(seed)
    plan, shared, baseline, gen = common.build_engines(rng)
    common.warmup(shared, baseline, gen)
    rows = []
    for template in ("get_book", "best_sellers"):
        for n in sizes:
            items = _batch(gen, template, n)
            t0 = time.time()
            for name, params in items:
                shared.submit(name, params)
            shared.run_until_drained()
            t_shared = time.time() - t0
            t0 = time.time()
            baseline.execute_batch(items)
            t_base = time.time() - t0
            rows.append((template, n, t_shared, t_base))
            print(f"fig10 {template:12s} batch={n:4d}  "
                  f"shared={t_shared*1e3:8.1f}ms  "
                  f"qaat={t_base*1e3:8.1f}ms  "
                  f"speedup={t_base/max(t_shared,1e-9):5.2f}x", flush=True)
    return rows


if __name__ == "__main__":
    run()

"""Fig. 11: load interaction — heavy queries must not starve light ones.

Fixed light load (get_book) + rising heavy load (best_sellers).  In
SharedDB both share the item/author scans and the plan's bounded cycles, so
light-query goodput stays flat; query-at-a-time head-of-line-blocks.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.workloads.tpcw import Interaction

INT_MAX = 2147483647


def _mk_arrivals(rng, gen, light_rate, heavy_rate, duration):
    arr = []
    for t in np.sort(rng.uniform(0, duration,
                                 max(1, int(light_rate * duration)))):
        i = int(gen.rng.integers(0, gen.n_items))
        arr.append((float(t), Interaction(
            "product_detail", [("get_book", {0: (i, i)})], [])))
    for t in np.sort(rng.uniform(0, duration,
                                 int(heavy_rate * duration))):
        lo = max(0, gen._next_order - 3333)
        subj = int(gen.rng.integers(0, 24))
        arr.append((float(t), Interaction(
            "best_sellers",
            [("best_sellers", {0: (lo, INT_MAX), 1: (subj, subj)})], [])))
    arr.sort(key=lambda x: x[0])
    return arr


def run(light_rate=50.0, heavy_rates=(0, 20, 80, 200, 400), duration=12.0,
        seed=13):
    rng = np.random.default_rng(seed)
    plan, shared, baseline, gen = common.build_engines(rng)
    common.warmup(shared, baseline, gen)
    rows = []
    for hr in heavy_rates:
        arr = _mk_arrivals(rng, gen, light_rate, hr, duration)
        rs = common.run_shared(shared, arr, duration)
        arr2 = _mk_arrivals(rng, gen, light_rate, hr, duration)
        rb = common.run_baseline(baseline, arr2, duration)
        rows.append((hr, rs, rb))
        print(f"fig11 heavy={hr:4.0f}/s  "
              f"shared: total_good={rs.good_wips:6.2f}/s p99={rs.p99_s:5.2f} | "
              f"qaat: total_good={rb.good_wips:6.2f}/s p99={rb.p99_s:5.2f}",
              flush=True)
    return rows


if __name__ == "__main__":
    run()

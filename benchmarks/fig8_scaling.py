"""Fig. 8: throughput scaling with compute units (PROJECTION).

One physical core here, so scaling is projected from the measured
single-core cycle time using the plan's per-node cost breakdown (Amdahl
over operator partitioning/replication, paper §4.3/§4.5): with k units,
cycle_k = t1 * max(largest_node_fraction, 1/k).  The baseline projects
linearly in k (optimistic for it — no contention modeled; the paper shows
MySQL saturating at 12 cores).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks import common
from repro.core import sla


def run(cores=(1, 2, 4, 8, 16, 32), n=64, mix="shopping", seed=23):
    rng = np.random.default_rng(seed)
    plan, shared, baseline, gen = common.build_engines(rng)
    common.warmup(shared, baseline, gen)

    # measured single-core throughput
    inters = gen.sample_mix(mix, n)
    t0 = time.time()
    for it in inters:
        for q in it.queries:
            shared.submit(*q)
        for u in it.updates:
            shared.submit_update(*u)
    shared.run_until_drained()
    t_shared = (time.time() - t0)
    t0 = time.time()
    for it in inters:
        for u in it.updates:
            baseline.apply_update(*u)
        for q in it.queries:
            baseline.execute(*q)
    t_base = time.time() - t0

    cost = sla.cycle_cost(plan)
    fracs = [v["flops"] for v in cost["nodes"].values()]
    max_frac = max(fracs) / max(sum(fracs), 1e-9)

    rows = []
    for k in cores:
        sh = (n / t_shared) / max(max_frac, 1.0 / k) * 1.0
        ba = (n / t_base) * k
        rows.append((k, sh, ba))
        print(f"fig8 cores={k:3d}  shared={sh:9.1f} WIPS(proj)  "
              f"qaat={ba:9.1f} WIPS(proj)", flush=True)
    print(f"fig8 note: largest-operator fraction={max_frac:.2f} "
          f"(shared-plan Amdahl ceiling)")
    return rows


if __name__ == "__main__":
    run()

"""Heartbeat critical-path microbenchmarks (the PR-2 perf record).

Three measurements, one per critical-path fix:

  join_scaling()      — partitioned bucketed probe vs the dense block
                        join at growing key counts (jnp backend, CPU);
                        the partitioned time INCLUDES the per-heartbeat
                        partition build, so the reported speedup is the
                        honest end-to-end ratio.
  dispatch_host_time()— packed single-transfer admission staging vs the
                        legacy per-template staging loop.  Both sides
                        time exactly reset + slot fill + H2D transfer
                        over preallocated buffers from the same admitted
                        batch, so the delta is purely the python scatter
                        loop + O(templates) transfers vs one packed
                        copy.  The full engine.dispatch() host time
                        (queue drain + staging + launch) rides along.
  cycle_times()       — mean heartbeat wall time over a TPC-W drain,
                        synchronous vs pipelined, via the executor's
                        per-cycle CycleResult accounting.

``python -m benchmarks.critical_path`` prints the dict; benchmarks/run.py
folds it into BENCH_PR3.json.
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import backends
from repro.core.executor import SharedDBEngine
from repro.core.lowering import partition_layout
from repro.core.storage import build_key_partitions
from repro.workloads import tpcw

SCALE = dict(scale_items=1000, scale_customers=2880)


def _best_of(fn, reps: int) -> float:
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def join_scaling(sizes=(512, 1024, 2048, 4096), W: int = 4,
                 reps: int = 5) -> List[Dict]:
    """Partitioned vs dense block join, Tl = Tr = keys, jnp backend."""
    be = backends.get_backend("jnp")
    out = []
    for T in sizes:
        rng = np.random.default_rng(T)
        keys_r = jnp.asarray(rng.permutation(T * 2)[:T], jnp.int32)
        keys_l = jnp.asarray(rng.choice(T * 2, T), jnp.int32)
        mask_l = jnp.asarray(rng.integers(0, 2**32, (T, W)), jnp.uint32)
        mask_r = jnp.asarray(rng.integers(0, 2**32, (T, W)), jnp.uint32)
        valid_r = jnp.asarray(rng.random(T) > 0.1)
        n_parts, bucket_cap = partition_layout(T)

        block = jax.jit(be.join_block)

        @jax.jit
        def partitioned(kl, ml, kr, mr, vr):
            parts = build_key_partitions(kr, vr, n_parts, bucket_cap)
            return be.join_partitioned(kl, ml, *parts, mr)

        args = (keys_l, mask_l, keys_r, mask_r, valid_r)
        jax.block_until_ready(block(*args))          # compile
        jax.block_until_ready(partitioned(*args))
        rb, mb = block(*args)
        rp, mp = partitioned(*args)
        assert (np.asarray(rb) == np.asarray(rp)).all()
        assert (np.asarray(mb) == np.asarray(mp)).all()
        t_block = _best_of(lambda: block(*args), reps)
        t_part = _best_of(lambda: partitioned(*args), reps)
        out.append({"keys": T, "n_partitions": n_parts,
                    "bucket_cap": bucket_cap,
                    "block_us": t_block * 1e6,
                    "partitioned_us": t_part * 1e6,
                    "speedup": t_block / max(t_part, 1e-12)})
    return out


def _legacy_stage(plan, bufs, tickets_by_tpl):
    """The pre-packed-ABI staging loop: per-template fill + per-template
    jnp.asarray — O(templates) H2D transfers per heartbeat."""
    batch = {}
    for name, tpl in plan.templates.items():
        params, active = bufs[name]
        active[:] = False
        admitted = tickets_by_tpl.get(name, ())[:len(active)]
        for slot, params_dict in enumerate(admitted):
            active[slot] = True
            for pi in range(len(tpl.preds)):
                params[slot, pi] = params_dict[pi]
        batch[name] = {"params": jnp.asarray(params),
                       "active": jnp.asarray(active)}
    return batch


def dispatch_host_time(n_queries: int = 64, reps: int = 30) -> Dict:
    """Host-side admission staging cost per heartbeat, packed vs legacy."""
    rng = np.random.default_rng(11)
    plan = tpcw.build_tpcw_plan(**SCALE)
    data = tpcw.generate_data(rng, **SCALE)
    gen = tpcw.WorkloadGenerator(rng, **SCALE)
    eng = SharedDBEngine(plan, tpcw.DEFAULT_UPDATE_SLOTS, data)
    eng.submit("get_book", {0: (1, 1)})
    eng.run_until_drained()                          # warm the jit cache

    queries = [q for it in gen.sample_mix("shopping", n_queries)
               for q in it.queries]
    tickets_by_tpl: Dict[str, list] = {}
    for name, params in queries:
        tickets_by_tpl.setdefault(name, []).append(params)
    # preallocated legacy buffers (parity with the packed path: neither
    # side pays allocation, the delta is loop + transfer count)
    legacy_bufs = {
        name: (np.zeros((plan.caps[name], max(len(t.preds), 1), 2),
                        np.int32),
               np.zeros((plan.caps[name],), bool))
        for name, t in plan.templates.items()}

    buf = eng._staging[0]

    def packed():
        # symmetric counterpart of _legacy_stage: reset + slot fill from
        # the same admitted batch + the single packed transfer pair
        buf.active[:] = False
        params, active = buf.params, buf.active
        for name, ps in tickets_by_tpl.items():
            tpl = plan.templates[name]
            off = plan.offsets[name]
            for slot, params_dict in enumerate(ps[:plan.caps[name]]):
                g = off + slot
                active[g] = True
                for pi in range(len(tpl.preds)):
                    params[g, pi] = params_dict[pi]
        return {"params": jnp.asarray(params),
                "active": jnp.asarray(active)}

    t_packed = _best_of(packed, reps)
    t_legacy = _best_of(
        lambda: _legacy_stage(plan, legacy_bufs, tickets_by_tpl), reps)

    # full dispatch() host time (staging + launch, returns pre-sync)
    def one_dispatch():
        for name, ps in tickets_by_tpl.items():
            for p in ps[:plan.caps[name]]:
                eng.submit(name, p)
        t0 = time.perf_counter()
        eng.dispatch()
        dt = time.perf_counter() - t0
        eng.collect()
        return dt

    one_dispatch()                                   # warm
    t_dispatch = min(one_dispatch() for _ in range(reps))
    return {"n_templates": len(plan.templates),
            "packed_stage_us": t_packed * 1e6,
            "per_template_stage_us": t_legacy * 1e6,
            "stage_speedup": t_legacy / max(t_packed, 1e-12),
            "dispatch_host_us": t_dispatch * 1e6}


def cycle_times(n_interactions: int = 120, reps: int = 3) -> Dict:
    """Mean heartbeat wall time, sync vs pipelined, over a TPC-W drain."""
    rng = np.random.default_rng(7)
    plan = tpcw.build_tpcw_plan(**SCALE)
    data = tpcw.generate_data(rng, **SCALE)
    gen = tpcw.WorkloadGenerator(rng, **SCALE)
    eng = SharedDBEngine(plan, tpcw.DEFAULT_UPDATE_SLOTS, data)
    eng.submit("get_book", {0: (1, 1)})
    eng.run_until_drained()                          # warm the jit cache

    means = {"sync": [], "pipelined": []}
    for _ in range(reps):
        for label, pipelined in (("sync", False), ("pipelined", True)):
            for it in gen.sample_mix("shopping", n_interactions):
                for q in it.queries:
                    eng.submit(*q)
                for u in it.updates:
                    eng.submit_update(*u)
            done = eng.run_until_drained(pipelined=pipelined)
            means[label].append(
                float(np.mean([d.wall_s for d in done])))
    sync = min(means["sync"])
    piped = min(means["pipelined"])
    return {"mean_cycle_us_sync": sync * 1e6,
            "mean_cycle_us_pipelined": piped * 1e6,
            "pipelined_sync_ratio": piped / max(sync, 1e-12)}


def run(smoke: bool = False) -> Dict:
    sizes = (1024, 4096) if smoke else (512, 1024, 2048, 4096)
    return {
        "join_scaling": join_scaling(sizes=sizes,
                                     reps=3 if smoke else 5),
        "dispatch": dispatch_host_time(reps=10 if smoke else 30),
        "cycle": cycle_times(n_interactions=30 if smoke else 120,
                             reps=1 if smoke else 3),
    }


if __name__ == "__main__":
    import json
    print(json.dumps(run(smoke="--smoke" in __import__("sys").argv),
                     indent=2))

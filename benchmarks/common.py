"""Measured virtual-time simulator shared by all TPC-W benchmarks.

This container has ONE CPU core, so offered load is modeled with a virtual
clock: arrivals are timestamped by the offered rate; compute time is the
MEASURED wall time of each engine call; latency = virtual completion -
virtual arrival.  SharedDB admits queued work per heartbeat (queries that
arrive during a cycle wait for the next — paper §3.2); the baseline
processes interactions one at a time in arrival order.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Tuple

import numpy as np

from repro.core.baseline import QueryAtATimeEngine
from repro.core.executor import SharedDBEngine
from repro.workloads import tpcw
from repro.workloads.tpcw import WI_TIMEOUT, WorkloadGenerator

DEFAULT_SCALE = dict(scale_items=1000, scale_customers=2880)


def build_engines(rng, scale=None, jit=True):
    scale = scale or DEFAULT_SCALE
    plan = tpcw.build_tpcw_plan(**scale)
    data = tpcw.generate_data(rng, **scale)
    shared = SharedDBEngine(plan, tpcw.DEFAULT_UPDATE_SLOTS, data, jit=jit)
    baseline = QueryAtATimeEngine(plan, data, jit=jit)
    gen = WorkloadGenerator(rng, scale["scale_items"],
                            scale["scale_customers"])
    return plan, shared, baseline, gen


def warmup(shared: SharedDBEngine, baseline: QueryAtATimeEngine,
           gen: WorkloadGenerator):
    """Compile the always-on plan + every baseline prepared statement."""
    for kind in tpcw.MIXES["shopping"]:
        it = gen.interaction(kind)
        for name, params in it.queries:
            shared.submit(name, params)
            baseline.execute(name, params)
        for upd in it.updates:
            shared.submit_update(*upd)
            baseline.apply_update(*upd)
    shared.run_until_drained()


@dataclasses.dataclass
class SimResult:
    offered_wips: float
    achieved_wips: float
    good_wips: float          # completed within the TPC-W WI timeout
    p50_s: float
    p99_s: float
    cycles: int = 0
    mean_cycle_s: float = 0.0


def run_shared(shared: SharedDBEngine, arrivals, sim_end: float,
               max_wall_s: float = 120.0) -> SimResult:
    """arrivals: sorted [(t, Interaction)]. Virtual-clock measured sim."""
    vnow, idx = 0.0, 0
    lat_by_inter: Dict[int, List[float]] = {}
    kinds: Dict[int, str] = {}
    ticket_map = []
    cycle_times = []
    wall0 = time.time()
    while (idx < len(arrivals) or shared.pending()) \
            and time.time() - wall0 < max_wall_s:
        # admit work that has arrived by now
        while idx < len(arrivals) and arrivals[idx][0] <= vnow:
            t_arr, inter = arrivals[idx]
            iid = idx
            kinds[iid] = inter.kind
            lat_by_inter.setdefault(iid, [])
            for name, params in inter.queries:
                tk = shared.submit(name, params)
                ticket_map.append((iid, t_arr, tk))
            for upd in inter.updates:
                shared.submit_update(*upd)
            idx += 1
        if not shared.pending():
            # idle: jump to next arrival
            if idx < len(arrivals):
                vnow = max(vnow, arrivals[idx][0])
                continue
            break
        t0 = time.time()
        shared.run_cycle()
        dt = time.time() - t0
        cycle_times.append(dt)
        vnow += dt
        for iid, t_arr, tk in ticket_map:
            if tk.done_time is not None and tk.result is not None \
                    and not hasattr(tk, "_counted"):
                tk._counted = True
                lat_by_inter[iid].append(vnow - t_arr)
    return _summarize(arrivals, lat_by_inter, kinds, sim_end,
                      cycles=len(cycle_times),
                      mean_cycle=float(np.mean(cycle_times))
                      if cycle_times else 0.0)


def run_baseline(baseline: QueryAtATimeEngine, arrivals, sim_end: float,
                 max_wall_s: float = 120.0) -> SimResult:
    vnow = 0.0
    lat_by_inter: Dict[int, List[float]] = {}
    kinds: Dict[int, str] = {}
    wall0 = time.time()
    for iid, (t_arr, inter) in enumerate(arrivals):
        if time.time() - wall0 > max_wall_s:
            break
        kinds[iid] = inter.kind
        start = max(vnow, t_arr)
        t0 = time.time()
        for upd in inter.updates:
            baseline.apply_update(*upd)
        for name, params in inter.queries:
            baseline.execute(name, params)
        dt = time.time() - t0
        vnow = start + dt
        lat_by_inter[iid] = [vnow - t_arr] * max(len(inter.queries), 1)
    return _summarize(arrivals, lat_by_inter, kinds, sim_end)


def _summarize(arrivals, lat_by_inter, kinds, sim_end,
               cycles=0, mean_cycle=0.0) -> SimResult:
    n_offered = len(arrivals)
    done, good, lats = 0, 0, []
    for iid, (t_arr, inter) in enumerate(arrivals):
        ls = lat_by_inter.get(iid)
        if not ls or len(ls) < max(len(inter.queries), 1):
            continue
        done += 1
        worst = max(ls)
        lats.append(worst)
        if worst <= WI_TIMEOUT[kinds[iid]]:
            good += 1
    lats = np.array(lats) if lats else np.array([np.inf])
    return SimResult(
        offered_wips=n_offered / sim_end,
        achieved_wips=done / sim_end,
        good_wips=good / sim_end,
        p50_s=float(np.percentile(lats, 50)),
        p99_s=float(np.percentile(lats, 99)),
        cycles=cycles, mean_cycle_s=mean_cycle)


def poisson_arrivals(rng, gen: WorkloadGenerator, mix: str, rate: float,
                     duration: float) -> Tuple[list, float]:
    n = max(1, int(rate * duration))
    ts = np.sort(rng.uniform(0, duration, n))
    inters = gen.sample_mix(mix, n)
    return list(zip(ts.tolist(), inters)), duration

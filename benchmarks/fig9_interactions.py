"""Fig. 9: max throughput per individual web interaction."""
from __future__ import annotations

import time

import numpy as np

from benchmarks import common
from repro.workloads.tpcw import MIXES


def run(n_per_kind=32, seed=17, kinds=None):
    rng = np.random.default_rng(seed)
    plan, shared, baseline, gen = common.build_engines(rng)
    common.warmup(shared, baseline, gen)
    kinds = kinds or list(MIXES["shopping"])
    rows = []
    for kind in kinds:
        inters = [gen.interaction(kind) for _ in range(n_per_kind)]
        t0 = time.time()
        for it in inters:
            for q in it.queries:
                shared.submit(*q)
            for u in it.updates:
                shared.submit_update(*u)
        shared.run_until_drained()
        wips_s = n_per_kind / (time.time() - t0)
        inters = [gen.interaction(kind) for _ in range(n_per_kind)]
        t0 = time.time()
        for it in inters:
            for u in it.updates:
                baseline.apply_update(*u)
            for q in it.queries:
                baseline.execute(*q)
        wips_b = n_per_kind / (time.time() - t0)
        rows.append((kind, wips_s, wips_b))
        print(f"fig9 {kind:22s} shared={wips_s:8.1f} WIPS  "
              f"qaat={wips_b:8.1f} WIPS  ratio={wips_s/max(wips_b,1e-9):5.2f}",
              flush=True)
    return rows


if __name__ == "__main__":
    run()

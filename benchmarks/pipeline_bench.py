"""Pipelined dispatch/collect vs the synchronous heartbeat loop.

Drains identical backlogs of TPC-W interactions through ONE compiled
engine, alternating between the synchronous ``run_cycle`` loop (dispatch
immediately followed by a blocking collect — the seed behaviour) and
``run_until_drained(pipelined=True)`` (up to ``pipeline_depth``
heartbeats in flight, so queue draining and numpy staging for cycle N+1
overlap device execution of cycle N).  Alternating reps on a shared
engine keep jit compilation and allocator state out of the comparison;
the minimum over reps is the noise-robust statistic.

    PYTHONPATH=src python benchmarks/pipeline_bench.py [n_interactions]
"""
from __future__ import annotations

import sys
import time

import numpy as np

from repro.core.executor import SharedDBEngine
from repro.workloads import tpcw

SCALE = dict(scale_items=1000, scale_customers=2880)


def run(n: int = 150, reps: int = 4, seed: int = 7):
    rng = np.random.default_rng(seed)
    plan = tpcw.build_tpcw_plan(**SCALE)
    data = tpcw.generate_data(rng, **SCALE)
    gen = tpcw.WorkloadGenerator(rng, **SCALE)

    engine = SharedDBEngine(plan, tpcw.DEFAULT_UPDATE_SLOTS, data)
    engine.submit("get_book", {0: (1, 1)})
    engine.run_until_drained()          # warm the jit cache

    times = {"sync": [], "pipelined": []}
    cycles = {"sync": 0, "pipelined": 0}
    for _ in range(reps):
        for label, pipelined in (("sync", False), ("pipelined", True)):
            inters = gen.sample_mix("shopping", n)
            tickets = []
            for it in inters:
                for q in it.queries:
                    tickets.append(engine.submit(*q))
                for u in it.updates:
                    engine.submit_update(*u)
            c0 = engine.cycles_run
            t0 = time.time()
            engine.run_until_drained(pipelined=pipelined)
            times[label].append(time.time() - t0)
            cycles[label] += engine.cycles_run - c0
            assert all(t.result is not None for t in tickets)

    rows = []
    for label in ("sync", "pipelined"):
        best = min(times[label])
        per_cycle = best / max(cycles[label] // reps, 1)
        rows.append((label, best, cycles[label] // reps, per_cycle))
        print(f"{label:9s}: min {best:6.3f}s/drain over {reps} reps, "
              f"~{cycles[label] // reps} cycles, "
              f"{per_cycle * 1e3:7.1f} ms/cycle", flush=True)
    sync, piped = rows[0][3], rows[1][3]
    print(f"pipelined/sync cycle-time ratio: {piped / sync:.3f} "
          f"(<= ~1.0 means the overlap does not regress latency)",
          flush=True)
    return rows


if __name__ == "__main__":
    run(int(sys.argv[1]) if len(sys.argv) > 1 else 150)

"""Incremental-scan benchmarks (the PR-3 perf record).

Two measurements, one kernel-level and one engine-level:

  scan_curve() — the delta scan phase (contiguous admission pane merged
                 by dynamic_update_slice + dirty-row kernel + sorted
                 scatter-back, exactly the composite
                 core/lowering.build_delta_cycle runs per stage) vs the
                 full-rescan compare kernel, at the real TPC-W item
                 stage's window width / pane capacity / dirty capacity,
                 over growing table sizes.  Steady-state shape: one
                 changed admission word, <=1% dirty rows.  Both sides
                 run inside one compiled fori_loop (the carry feeding
                 each iteration, like the real heartbeat chain) so the
                 measurement is per-iteration compute, not python/jit
                 dispatch overhead.
  heartbeat()  — engine-level steady-state heartbeat wall time over the
                 13-template TPC-W plan: trickle admission (one point
                 template) plus two row updates per beat, measured with
                 delta_scans=True vs False; CycleResult.scan_path
                 attributes each heartbeat to its path.

``python -m benchmarks.delta_scan_bench`` prints the dict;
benchmarks/run.py folds it into BENCH_PR3.json, which
tests/test_sla_gate.py gates against stored thresholds.
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import backends
from repro.core.executor import SharedDBEngine
from repro.core.lowering import lower_plan
from repro.workloads import tpcw


def _best_of(fn, reps: int) -> float:
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def _delta_scan_fn(backend, w: int, A: int):
    """The build_delta_cycle scan phase as a standalone jittable."""

    def fn(prev, cols, lo, hi, valid, dirty_rows, changed):
        from repro.core.storage import scatter_dirty_rows
        T = cols.shape[1]
        wch = jnp.any(changed.reshape(w, 32), axis=1)
        w0 = jnp.minimum(jnp.argmax(wch).astype(jnp.int32), w - A)
        lo_a = jax.lax.dynamic_slice(lo, (0, w0 * 32),
                                     (lo.shape[0], A * 32))
        hi_a = jax.lax.dynamic_slice(hi, (0, w0 * 32),
                                     (hi.shape[0], A * 32))
        pane = backend.scan(cols, lo_a, hi_a, valid)
        m = jax.lax.dynamic_update_slice(prev, pane, (0, w0))
        dwords = backend.scan_delta(cols, lo, hi, valid, dirty_rows)
        return scatter_dirty_rows(m, dirty_rows, dwords, T)

    return fn


def scan_curve(sizes=(1024, 4096), reps: int = 5,
               iters: int = 40) -> List[Dict]:
    """Delta vs full-rescan scan phase at the TPC-W item stage shape."""
    be = backends.get_backend("jnp")
    # the real stage geometry: window width, pane capacity, dirty cap
    plan = tpcw.build_tpcw_plan(1000, 2880)
    st = next(s for s in lower_plan(plan).scans if s.table == "item")
    w, A = st.whi - st.wlo, st.delta_words
    C, Q = len(st.cols), st.q_window
    D = plan.catalog.schemas["item"].dirty_cap
    out = []
    for T in sizes:
        rng = np.random.default_rng(T)
        cols0 = jnp.asarray(rng.integers(0, T, (C, T)), jnp.int32)
        lo = jnp.asarray(rng.integers(0, T, (C, Q)), jnp.int32)
        hi = lo + jnp.asarray(rng.integers(0, T // 8, (C, Q)), jnp.int32)
        valid = jnp.asarray(rng.random(T) > 0.05)
        # steady state: one changed admission word, <=1% dirty rows
        changed = np.zeros(Q, bool)
        changed[64:72] = True
        n_dirty = max(1, T // 100)
        dirty = np.full(D, T, np.int64)
        dirty[:n_dirty] = np.sort(rng.choice(T, n_dirty, replace=False))
        dirty_j = jnp.asarray(dirty, jnp.int32)
        changed_j = jnp.asarray(changed)

        delta_step = _delta_scan_fn(be, w, A)
        prev = jax.jit(be.scan)(cols0, lo, hi, valid)
        # the delta phase must reproduce the full rescan bit-for-bit
        got = delta_step(prev, cols0, lo, hi, valid, dirty_j, changed_j)
        assert (np.asarray(got) == np.asarray(prev)).all()

        # measure inside one compiled loop, each iteration consuming the
        # previous mask (the real carry chain) so nothing hoists out
        def chained(step):
            def body(_, m):
                cols = cols0 + (m[0, 0] & jnp.uint32(0)).astype(jnp.int32)
                return step(m, cols)
            return jax.jit(
                lambda: jax.lax.fori_loop(0, iters, body, prev))

        loop_full = chained(lambda m, cols: be.scan(cols, lo, hi, valid))
        loop_delta = chained(lambda m, cols: delta_step(
            m, cols, lo, hi, valid, dirty_j, changed_j))
        jax.block_until_ready(loop_full())               # compile
        jax.block_until_ready(loop_delta())
        # alternate sides per rep so machine drift hits both equally
        t_full = t_delta = float("inf")
        for _ in range(reps):
            t_full = min(t_full, _best_of(loop_full, 1))
            t_delta = min(t_delta, _best_of(loop_delta, 1))
        t_full /= iters
        t_delta /= iters
        out.append({"rows": T, "q_window": Q, "pane_words": A,
                    "dirty_rows": n_dirty,
                    "full_us": t_full * 1e6, "delta_us": t_delta * 1e6,
                    "speedup": t_full / max(t_delta, 1e-12)})
    return out


def heartbeat(scale_items: int = 4096, beats: int = 30,
              reps: int = 3) -> Dict:
    """Steady-state heartbeat wall time, delta vs forced full rescan.

    Both engines are driven INTERLEAVED, beat for beat, so machine drift
    during the run lands on both sides equally (sequential runs showed
    up to 2x apparent skew from contention alone on shared CPUs)."""
    rng = np.random.default_rng(9)
    plan = tpcw.build_tpcw_plan(scale_items, 2880)
    data = tpcw.generate_data(rng, scale_items, 2880)
    engines = {}
    for label, delta_scans in (("delta", True), ("full", False)):
        eng = SharedDBEngine(plan, tpcw.DEFAULT_UPDATE_SLOTS, data,
                             delta_scans=delta_scans)
        eng.submit("get_book", {0: (1, 1)})
        eng.run_until_drained()                          # compiles full
        for _ in range(2):       # two slot-stable beats: the second is
            # delta-eligible, so this compiles the delta cycle too —
            # keeping BOTH paths' jit cost out of the measured loop
            eng.submit_update("item", "update",
                              {"key": 1, "col": "i_cost", "val": 1})
            eng.submit("admin_item", {0: (1, 1)})
            eng.run_until_drained()
        engines[label] = eng
    walls = {label: [] for label in engines}
    paths = {label: {"delta": 0, "full": 0, "mixed": 0}
             for label in engines}
    for _ in range(reps):
        for i in range(beats):
            k = int(rng.integers(0, scale_items))
            v = int(rng.integers(100, 9999))
            for label, eng in engines.items():
                eng.submit("admin_item", {0: (k, k)})
                eng.submit_update("item", "update",
                                  {"key": k, "col": "i_cost", "val": v})
                eng.submit_update("item", "update",
                                  {"key": (k + 7) % scale_items,
                                   "col": "i_stock", "val": 9})
                done = eng.run_until_drained(max_cycles=4)
                walls[label].extend(d.wall_s for d in done)
                for d in done:
                    paths[label][d.scan_path or "full"] += 1
    d_eng = engines["delta"]
    total = max(d_eng.delta_cycles + d_eng.full_cycles, 1)
    d_us = float(np.mean(walls["delta"])) * 1e6
    f_us = float(np.mean(walls["full"])) * 1e6
    return {"scale_items": scale_items, "beats": beats * reps,
            "delta_heartbeat_us": d_us,
            "full_heartbeat_us": f_us,
            "heartbeat_speedup": f_us / max(d_us, 1e-9),
            "delta_cycle_fraction": d_eng.delta_cycles / total,
            "paths_delta_engine": paths["delta"],
            "paths_full_engine": paths["full"]}


def run(smoke: bool = False) -> Dict:
    return {
        "curve": scan_curve(sizes=(1024, 4096),
                            reps=3 if smoke else 5),
        "heartbeat": heartbeat(beats=15 if smoke else 30,
                               reps=1 if smoke else 3),
    }


if __name__ == "__main__":
    import json
    import sys
    print(json.dumps(run(smoke="--smoke" in sys.argv), indent=2))

"""Render the EXPERIMENTS.md §Dry-run and §Roofline tables from
results/dryrun.json.  Run after the sweep:

    PYTHONPATH=src python -m benchmarks.render_experiments > /tmp/tables.md
"""
from __future__ import annotations

import json
import sys


def fmt_bytes(n):
    return f"{n / 2**30:.2f}"


def render(path="results/dryrun.json"):
    with open(path) as f:
        data = json.load(f)

    out = []
    for mesh in ("pod16x16", "pod2x16x16"):
        out.append(f"\n### Mesh {mesh} "
                   f"({'512 chips, 2 pods' if '2x' in mesh else '256 chips'})\n")
        out.append("| arch | shape | status | GiB/dev (args+tmp) | HLO "
                   "PFLOPs | HLO TB | coll GB/link | compute s | memory s "
                   "| collective s | dominant | roofline frac | useful "
                   "ratio |")
        out.append("|---|---|---|---|---|---|---|---|---|---|---|---|---|"
                   [:-1])
        for key in sorted(data):
            v = data[key]
            if v.get("mesh") != mesh or "|" in key and len(
                    key.split("|")) > 3:
                continue
            if v.get("status") == "skipped":
                out.append(f"| {v['arch']} | {v['shape']} | skip "
                           f"({v['reason'][:40]}…) | | | | | | | | | |")
                continue
            if v.get("status") != "ok":
                out.append(f"| {v['arch']} | {v['shape']} | ERROR | | | | "
                           f"| | | | | |")
                continue
            m = v["memory"]
            gib = (m["argument_bytes_per_device"]
                   + m["temp_bytes_per_device"]) / 2**30
            r = v["roofline"]
            coll_link = v["collective_bytes"] / v["n_chips"] / 1e9
            out.append(
                f"| {v['arch']} | {v['shape']} | ok | {gib:.2f} | "
                f"{v['hlo_flops']/1e15:.2f} | {v['hlo_bytes']/1e12:.2f} | "
                f"{coll_link:.2f} | {r['compute_s']:.4f} | "
                f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | "
                f"{r['dominant']} | {r['roofline_fraction']:.3f} | "
                f"{v['useful_flops_ratio']:.2f} |")
    # collective schedule summary
    out.append("\n### Collective schedules (single-pod, counts per step)\n")
    out.append("| arch | shape | all-gather | all-reduce | reduce-scatter "
               "| all-to-all | permute |")
    out.append("|---|---|---|---|---|---|---|")
    for key in sorted(data):
        v = data[key]
        if v.get("status") != "ok" or v.get("mesh") != "pod16x16":
            continue
        c = v["collectives"]["counts"]
        out.append(f"| {v['arch']} | {v['shape']} | "
                   f"{c.get('all-gather', 0)} | {c.get('all-reduce', 0)} | "
                   f"{c.get('reduce-scatter', 0)} | "
                   f"{c.get('all-to-all', 0)} | "
                   f"{c.get('collective-permute', 0)} |")
    return "\n".join(out)


if __name__ == "__main__":
    path = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun.json"
    print(render(path))

"""Sharded reseed-beat benchmark (the PR-5 perf record).

The delta beats made the steady state cheap (PR 3/4); what remains on
the critical path is the full-rescan / reseed beat — the bounded worst
case every overflow or admission-churn heartbeat pays — and that is
exactly what row-range sharding scatters across the mesh
(core/sharding.py).  Two measurements:

  per_device() — the reseed scan work ONE device pays, before vs after
                 sharding: the full item-stage compare at the padded
                 table height ``Tp`` vs the per-shard slice height
                 ``Ts = Tp / S`` taken from the real ``ShardSpec`` of
                 the plan.  Both run identically on one device in a
                 compiled sequence, so the ratio is deterministic on
                 any CI host — this is the quantity a real mesh (one
                 shard per chip, the paper's one-operator-per-core
                 scaling, §4.5) converts into wall-clock, and the gate
                 trips if the sharded lowering ever stops splitting the
                 row ranges.
  engine_beats() — context: wall time of the engine-level reseed beat
                 on a 1-shard vs multi-shard mesh of FORCED host CPU
                 devices, plus the sharded steady-state delta beat and
                 its path fractions.  On a 2-core CI host the forced
                 devices time-slice the same cores and XLA:CPU already
                 multi-threads the single-device op, so these walls
                 measure overhead honesty (ceilings + the delta paths
                 still engaging), not the mesh speedup.

Runs in a SUBPROCESS of ``benchmarks/run.py`` with
``--xla_force_host_platform_device_count`` set, so the PR-3/4 records
keep measuring on the plain single-device client:

    python -m benchmarks.sharded_bench [--smoke]   # prints JSON record

``run.py`` folds the record into ``BENCH_PR5.json``;
``tests/test_sla_gate.py`` gates it against stored thresholds.
"""
from __future__ import annotations

import os

if "--xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = " ".join(
        [os.environ.get("XLA_FLAGS", ""),
         "--xla_force_host_platform_device_count=8"]).strip()
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import time                                               # noqa: E402
from typing import Dict                                   # noqa: E402

import numpy as np                                        # noqa: E402

SCALE_ITEMS = 4096
SHARDS = 4


def _timeit(f, args, n=20, reps=4) -> float:
    import jax
    jax.block_until_ready(f(*args))
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        for _ in range(n):
            out = f(*args)
        jax.block_until_ready(out)
        best = min(best, (time.perf_counter() - t0) / n)
    return best


def per_device(scale_items: int = SCALE_ITEMS,
               shards: int = SHARDS) -> Dict:
    """Reseed scan cost one device pays: full stage height vs the
    per-shard slice, at the REAL plan's item-stage geometry."""
    import jax
    import jax.numpy as jnp
    from repro.core import backends
    from repro.core.lowering import lower_plan
    from repro.core.sharding import build_shard_spec, make_row_mesh
    from repro.workloads import tpcw

    be = backends.get_backend("jnp")
    plan = tpcw.build_tpcw_plan(scale_items, 2880, dense_pk_index=False)
    spec = build_shard_spec(plan, make_row_mesh(shards))
    st = next(s for s in lower_plan(plan).scans if s.table == "item")
    C, Q = len(st.cols), st.q_window
    Tp, Ts = spec.padded["item"], spec.shard_rows["item"]
    rng = np.random.default_rng(0)
    lo = jnp.asarray(rng.integers(0, 5000, (C, Q)), jnp.int32)
    hi = lo + 2000

    def scan_at(T: int) -> float:
        cols = jnp.asarray(rng.integers(0, 10000, (C, T)), jnp.int32)
        valid = jnp.asarray(rng.random(T) > 0.05)
        f = jax.jit(lambda c, v: be.scan(c, lo, hi, v))
        return _timeit(f, (cols, valid))

    full_us = scan_at(Tp) * 1e6
    shard_us = scan_at(Ts) * 1e6
    return {"table": "item", "rows_full": Tp, "rows_shard": Ts,
            "cols": C, "q_window": Q, "shards": shards,
            "full_scan_us": full_us, "shard_scan_us": shard_us,
            "speedup": full_us / max(shard_us, 1e-9)}


def engine_beats(scale_items: int = SCALE_ITEMS, shards: int = SHARDS,
                 beats: int = 8, warmup: int = 2) -> Dict:
    """Engine-level context on forced host devices: reseed beat walls
    (1-shard vs sharded mesh, interleaved beat-for-beat) and the
    sharded steady-state delta beat with its path fractions."""
    from repro.core.executor import SharedDBEngine
    from repro.core.sharding import make_row_mesh
    from repro.workloads import tpcw

    rng = np.random.default_rng(11)
    plan = tpcw.build_tpcw_plan(scale_items, 2880, dense_pk_index=False)
    data = tpcw.generate_data(rng, scale_items, 2880)
    engines = {}
    for label, n in (("single", 1), ("sharded", shards)):
        eng = SharedDBEngine(plan, tpcw.DEFAULT_UPDATE_SLOTS, data,
                             delta_scans=False, delta_joins=False,
                             mesh=make_row_mesh(n))
        for _ in range(warmup):                          # compile + warm
            eng.submit("get_book", {0: (1, 1)})
            eng.run_until_drained()
        engines[label] = eng
    walls = {label: [] for label in engines}
    for i in range(beats):
        k = int(rng.integers(0, scale_items))
        c = int(rng.integers(0, 2880))
        for label, eng in engines.items():
            eng.submit("get_book", {0: (k, k)})
            eng.submit_update("customer", "update",
                              {"key": c, "col": "c_expiration",
                               "val": 13000 + i})
            done = eng.run_until_drained(max_cycles=4)
            assert all(d.scan_path == "full" for d in done)
            walls[label].extend(d.wall_s for d in done)

    # steady-state delta beats: the SAME trickle stream on the sharded
    # mesh and on a single device, so the end-to-end sharded/single
    # delta-beat ratio is apples-to-apples inside this one forced-host
    # subprocess.  With the PR-6 on-device cross-shard merge, collect()
    # no longer pays a host-side key-merge, so the ratio measures
    # shard_map dispatch overhead (bounded by the SLA gate) rather than
    # a host merge that grows with the result surface.
    def delta_walls(mesh):
        drng = np.random.default_rng(13)
        eng = SharedDBEngine(plan, tpcw.DEFAULT_UPDATE_SLOTS, data,
                             mesh=mesh)
        eng.submit("get_book", {0: (1, 1)})
        eng.run_until_drained()                           # seed (full)
        for i in range(2):                                # compile delta
            eng.submit_update("customer", "update",
                              {"key": 1, "col": "c_expiration",
                               "val": 13000 + i})
            eng.submit("get_book", {0: (1, 1)})
            eng.run_until_drained()
        dwalls = []
        for i in range(beats):
            k = int(drng.integers(0, scale_items))
            c = int(drng.integers(0, 2880))
            eng.submit("get_book", {0: (k, k)})
            eng.submit_update("customer", "update",
                              {"key": c, "col": "c_expiration",
                               "val": 14000 + i})
            dwalls.extend(d.wall_s
                          for d in eng.run_until_drained(max_cycles=4))
        return eng, dwalls

    eng, dwalls = delta_walls(make_row_mesh(shards))
    _, dwalls_single = delta_walls(None)
    total = max(eng.delta_cycles + eng.full_cycles, 1)
    sharded_delta_us = float(np.mean(dwalls)) * 1e6
    single_delta_us = float(np.mean(dwalls_single)) * 1e6
    return {"scale_items": scale_items, "shards": shards,
            "beats": beats, "devices_forced": True,
            "single_reseed_us": float(np.mean(walls["single"])) * 1e6,
            "sharded_reseed_us": float(np.mean(walls["sharded"])) * 1e6,
            "delta_heartbeat_us": sharded_delta_us,
            "single_delta_heartbeat_us": single_delta_us,
            "sharded_delta_ratio": sharded_delta_us
            / max(single_delta_us, 1e-9),
            "delta_cycle_fraction": eng.delta_cycles / total,
            "delta_join_fraction": eng.delta_join_cycles
            / max(eng.delta_join_cycles + eng.full_join_cycles, 1)}


def run(smoke: bool = False) -> Dict:
    return {"per_device": per_device(),
            "engine": engine_beats(beats=6 if smoke else 12)}


if __name__ == "__main__":
    import json
    import sys
    print(json.dumps(run(smoke="--smoke" in sys.argv), indent=2))

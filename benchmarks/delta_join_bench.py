"""Delta-aware join benchmarks (the PR-4 perf record).

Two measurements, one kernel-level and one engine-level:

  join_curve() — the delta join phase (dirty-row probe via the
                 ``join_delta`` backend op + sorted-scatter merge into
                 the carried rid array + the bitmask intersection,
                 exactly what core/lowering's delta-join post_scan runs
                 per stage) vs the full partitioned re-probe, at the
                 TPC-W window width and partition layout, over growing
                 table sizes.  Steady-state shape: <=1% dirty spine
                 rows, PK side untouched.  Both sides run inside one
                 compiled fori_loop (the rid carry feeding each
                 iteration, like the real heartbeat chain) so the
                 measurement is per-iteration compute, not python/jit
                 dispatch overhead.
  heartbeat()  — engine-level steady-state heartbeat wall time over the
                 index-less TPC-W plan (every join partitioned):
                 slot-stable trickle admission plus one spine-side
                 (customer) update per beat, measured with
                 delta_joins=True vs False (delta SCANS on for both, so
                 the difference isolates the join phase);
                 CycleResult.join_path attributes each heartbeat.

``python -m benchmarks.delta_join_bench`` prints the dict;
benchmarks/run.py folds it into BENCH_PR4.json, which
tests/test_sla_gate.py gates against stored thresholds.
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import backends
from repro.core.lowering import lower_plan, partition_layout
from repro.core.storage import build_key_partitions, scatter_dirty_rows
from repro.workloads import tpcw


def _best_of(fn, reps: int) -> float:
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def _combined(rid, mask_l, mask_r):
    safe = jnp.clip(rid, 0, mask_r.shape[0] - 1)
    return jnp.where((rid >= 0)[:, None], mask_l & mask_r[safe],
                     jnp.uint32(0))


def _delta_join_fn(backend):
    """The delta-join stage phase as a standalone jittable."""

    def fn(rid_carry, keys_l, mask_l, parts, mask_r, dirty):
        T = keys_l.shape[0]
        bkeys, brows, bounds = parts
        rid_d = backend.join_delta(keys_l, dirty, bkeys, brows, bounds)
        rid = scatter_dirty_rows(rid_carry, dirty, rid_d, T)
        return rid, _combined(rid, mask_l, mask_r)

    return fn


def join_curve(sizes=(1024, 4096), reps: int = 5,
               iters: int = 40) -> List[Dict]:
    """Delta vs full partitioned probe at the TPC-W window width."""
    be = backends.get_backend("jnp")
    # the real plan geometry: window width in words, dirty capacity
    plan = tpcw.build_tpcw_plan(4096, 2880, dense_pk_index=False)
    W = lower_plan(plan).W
    D = plan.catalog.schemas["order_line"].dirty_cap
    out = []
    for T in sizes:
        rng = np.random.default_rng(T)
        n_parts, bucket_cap = partition_layout(T)
        keys_r = jnp.asarray(rng.permutation(T * 2)[:T], jnp.int32)
        valid_r = jnp.asarray(rng.random(T) > 0.05)
        keys_l0 = jnp.asarray(rng.integers(0, T * 2, T), jnp.int32)
        mask_l = jnp.asarray(rng.integers(0, 2**32, (T, W)), jnp.uint32)
        mask_r = jnp.asarray(rng.integers(0, 2**32, (T, W)), jnp.uint32)
        parts = build_key_partitions(keys_r, valid_r, n_parts, bucket_cap)
        # steady state: <=1% dirty spine rows, PK side untouched
        n_dirty = max(1, T // 100)
        dirty = np.full(D, T, np.int64)
        dirty[:n_dirty] = np.sort(rng.choice(T, n_dirty, replace=False))
        dirty_j = jnp.asarray(dirty, jnp.int32)

        delta_step = _delta_join_fn(be)
        rid0, comb0 = jax.jit(be.join_partitioned)(keys_l0, mask_l,
                                                   *parts, mask_r)
        # the delta phase must reproduce the full probe bit-for-bit
        rid1, comb1 = delta_step(rid0, keys_l0, mask_l, parts, mask_r,
                                 dirty_j)
        assert (np.asarray(rid1) == np.asarray(rid0)).all()
        assert (np.asarray(comb1) == np.asarray(comb0)).all()

        # measure inside one compiled loop, each iteration consuming the
        # previous rid (the real carry chain) so nothing hoists out
        def chained(step):
            def body(_, rid):
                keys_l = keys_l0 + (rid[0] & jnp.int32(0))
                return step(rid, keys_l)
            return jax.jit(
                lambda: jax.lax.fori_loop(0, iters, body, rid0))

        loop_full = chained(
            lambda rid, keys_l: be.join_partitioned(
                keys_l, mask_l, *parts, mask_r)[0])
        loop_delta = chained(
            lambda rid, keys_l: delta_step(
                rid, keys_l, mask_l, parts, mask_r, dirty_j)[0])
        jax.block_until_ready(loop_full())               # compile
        jax.block_until_ready(loop_delta())
        # alternate sides per rep so machine drift hits both equally
        t_full = t_delta = float("inf")
        for _ in range(reps):
            t_full = min(t_full, _best_of(loop_full, 1))
            t_delta = min(t_delta, _best_of(loop_delta, 1))
        t_full /= iters
        t_delta /= iters
        out.append({"rows": T, "w_words": W,
                    "n_partitions": n_parts, "bucket_cap": bucket_cap,
                    "dirty_rows": n_dirty,
                    "full_us": t_full * 1e6, "delta_us": t_delta * 1e6,
                    "speedup": t_full / max(t_delta, 1e-12)})
    return out


def heartbeat(scale_items: int = 4096, beats: int = 30,
              reps: int = 3) -> Dict:
    """Steady-state heartbeat wall time, delta joins vs forced full
    probes (delta scans ON for both sides, isolating the join phase).

    Both engines are driven INTERLEAVED, beat for beat, so machine drift
    during the run lands on both sides equally."""
    from repro.core.executor import SharedDBEngine

    rng = np.random.default_rng(11)
    plan = tpcw.build_tpcw_plan(scale_items, 2880, dense_pk_index=False)
    data = tpcw.generate_data(rng, scale_items, 2880)
    engines = {}
    for label, delta_joins in (("delta", True), ("full", False)):
        eng = SharedDBEngine(plan, tpcw.DEFAULT_UPDATE_SLOTS, data,
                             delta_joins=delta_joins)
        eng.submit("get_book", {0: (1, 1)})
        eng.run_until_drained()                          # compiles full
        for _ in range(2):       # two slot-stable beats: the second is
            # delta-eligible, compiling the delta(-join) cycle too —
            # keeping every path's jit cost out of the measured loop
            eng.submit_update("customer", "update",
                              {"key": 1, "col": "c_expiration",
                               "val": 13000})
            eng.submit("get_book", {0: (1, 1)})
            eng.run_until_drained()
        engines[label] = eng
    walls = {label: [] for label in engines}
    join_paths = {label: {"delta": 0, "full": 0, "mixed": 0}
                  for label in engines}
    for _ in range(reps):
        for i in range(beats):
            k = int(rng.integers(0, scale_items))
            c = int(rng.integers(0, 2880))
            v = int(rng.integers(12000, 15000))
            for label, eng in engines.items():
                eng.submit("get_book", {0: (k, k)})
                eng.submit_update("customer", "update",
                                  {"key": c, "col": "c_expiration",
                                   "val": v})
                done = eng.run_until_drained(max_cycles=4)
                walls[label].extend(d.wall_s for d in done)
                for d in done:
                    join_paths[label][d.join_path or "full"] += 1
    d_eng = engines["delta"]
    total = max(d_eng.delta_join_cycles + d_eng.full_join_cycles, 1)
    d_us = float(np.mean(walls["delta"])) * 1e6
    f_us = float(np.mean(walls["full"])) * 1e6
    return {"scale_items": scale_items, "beats": beats * reps,
            "delta_heartbeat_us": d_us,
            "full_heartbeat_us": f_us,
            "heartbeat_speedup": f_us / max(d_us, 1e-9),
            "delta_join_fraction": d_eng.delta_join_cycles / total,
            "join_paths_delta_engine": join_paths["delta"],
            "join_paths_full_engine": join_paths["full"]}


def run(smoke: bool = False) -> Dict:
    return {
        "curve": join_curve(sizes=(1024, 4096),
                            reps=3 if smoke else 5),
        "heartbeat": heartbeat(beats=15 if smoke else 30,
                               reps=1 if smoke else 3),
    }


if __name__ == "__main__":
    import json
    import sys
    print(json.dumps(run(smoke="--smoke" in sys.argv), indent=2))

"""Dynamic plan-folding benchmark (the PR-8 serving record).

The fold contract (core/folding.py) is that admitting a new query
template costs the running clients almost nothing: the extended plan
compiles on a background thread while the OLD compiled heartbeat keeps
serving, and the only beat that pays for the swap is the single forced
full-rescan migration beat.  This bench measures exactly that contract
on the index-less TPC-W plan at the 4096-row acceptance geometry:

  steady      — the pre-fold steady-state delta beat wall (the PR-6
                fused single-launch path, asserted via launch counts);
  during_fold — the SAME trickle beats while the background fold
                builds + jit-warms the extended plan.  The SLA gate
                (tests/test_sla_gate.py) holds their median within
                1.5x of the steady median: folding must not stop — or
                visibly stall — the world;
  migration   — the one full-rescan beat that commits the fold
                (carry migration + reseed under the new layout);
  post_steady — steady beats on the extended plan, back on the single
                fused launch (launch counts asserted again: the swap
                must not knock the engine off the fused path).

``python -m benchmarks.fold_bench`` prints the dict; benchmarks/run.py
folds it into BENCH_PR8.json for the SLA gate.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.core.executor import SharedDBEngine
from repro.core.plan import compile_plan
from repro.workloads import tpcw

SCALE_ITEMS = 4096
SCALE_CUSTOMERS = 2880
N_BASE = 10          # held out and folded in mid-run:
#                      order_lines / order_display / get_cart

CHAINED_OPS = ("scan", "scan_delta", "join_delta", "join_partitioned",
               "join_block")


def _median_us(beats: List) -> float:
    return float(np.median([b.wall_s for b in beats])) * 1e6


def _assert_fused(beats: List, label: str) -> Dict[str, int]:
    ops: Dict[str, int] = {}
    for b in beats:
        for op, n in b.backend_ops.items():
            if n:
                ops[op] = max(ops.get(op, 0), n)
    assert ops.get("fused_delta") == 1, (label, ops)
    assert all(ops.get(op, 0) == 0 for op in CHAINED_OPS), (label, ops)
    return ops


def run(smoke: bool = False, scale_items: int = SCALE_ITEMS) -> Dict:
    import time

    rng = np.random.default_rng(11)
    catalog = tpcw.make_catalog(scale_items, SCALE_CUSTOMERS,
                                dense_pk_index=False)
    templates, caps = tpcw.make_templates(
        catalog.schemas["item"].capacity)
    base = compile_plan(catalog, templates[:N_BASE],
                        {t.name: caps[t.name]
                         for t in templates[:N_BASE]})
    data = tpcw.generate_data(rng, scale_items, SCALE_CUSTOMERS)
    eng = SharedDBEngine(base, tpcw.DEFAULT_UPDATE_SLOTS, data,
                         kernels="jnp")

    def trickle(subs, i):
        eng.submit_update("customer", "update",
                          {"key": int(rng.integers(0, SCALE_CUSTOMERS)),
                           "col": "c_expiration", "val": 13000 + i})
        for name, params in subs:
            eng.submit(name, params)
        return eng.run_until_drained()

    pre = [("get_book", {0: (5, 5)}), ("get_customer", {0: (7, 7)})]
    post = [("order_lines", {0: (10, 10)}), ("get_cart", {0: (12, 12)}),
            ("get_book", {0: (5, 5)})]
    n_steady = 6 if smoke else 12

    for name, params in pre:                 # seed + compile deltas
        eng.submit(name, params)
    eng.run_until_drained()
    for i in range(3):
        trickle(pre, i)
    steady = [b for i in range(n_steady) for b in trickle(pre, 10 + i)
              if b.join_path == "delta"]
    assert steady, "never reached the pre-fold delta-join path"
    pre_ops = _assert_fused(steady, "steady")

    # ---- background fold: the old compiled heartbeat keeps serving
    # while the extended plan builds + jit-warms on the fold thread
    t0 = time.perf_counter()
    eng.begin_fold(templates[N_BASE:],
                   {t.name: caps[t.name] for t in templates[N_BASE:]},
                   background=True)
    # measure a fixed window of beats inside the build (the fold thread
    # runs deniced — serving keeps the cores, the build fills the
    # gaps), then idle so the build can land
    during: List = []
    n_during = 4 if smoke else 8
    while len(during) < n_during and eng.fold_in_flight() \
            and not eng.fold_ready():
        during.extend(b for b in trickle(pre, 100 + len(during))
                      if b.scan_path == "delta")
    beats_during_build = len(during)
    while eng.fold_in_flight() and not eng.fold_ready():
        time.sleep(0.01)
    build_wall_s = time.perf_counter() - t0
    assert during, "fold built before a single beat was served"
    _assert_fused([b for b in during if b.join_path == "delta"],
                  "during_fold")

    # ---- the migration beat: commit + carry migration + full rescan
    mig = trickle(post, 999)
    assert eng.folds_done == 1 and mig[0].scan_path == "full", \
        (eng.folds_done, [b.scan_path for b in mig])

    for i in range(3):                       # compile the post deltas
        trickle(post, 1000 + i)
    post_steady = [b for i in range(n_steady)
                   for b in trickle(post, 1100 + i)
                   if b.join_path == "delta"]
    assert post_steady, "never reached the post-fold delta-join path"
    post_ops = _assert_fused(post_steady, "post_steady")

    steady_us = _median_us(steady)
    during_us = _median_us(during)
    return {
        "scale_items": scale_items,
        "steady_beats": len(steady),
        "steady_us": steady_us,
        "beats_during_build": beats_during_build,
        "during_fold_us": during_us,
        "fold_serving_ratio": during_us / max(steady_us, 1e-9),
        "build_wall_s": build_wall_s,
        "migration_beat_us": mig[0].wall_s * 1e6,
        "post_steady_us": _median_us(post_steady),
        "pre_fold_launches": int(sum(pre_ops.values())),
        "post_fold_launches": int(sum(post_ops.values())),
    }


if __name__ == "__main__":
    import json
    import sys
    print(json.dumps(run(smoke="--smoke" in sys.argv), indent=2))

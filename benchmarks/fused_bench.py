"""Fused delta-heartbeat benchmark (the PR-6 perf record).

The PR-4/5 steady state chained one backend launch per delta unit —
pane recompute + dirty re-scan per predicated stage, dirty probe +
rid merge per carried join.  PR 6 fuses the whole delta path into ONE
``backend.fused_delta`` launch (kernels/fused_delta.py), so the
measurement is engine-level and beat-for-beat:

  heartbeat() — steady-state trickle beats on the index-less TPC-W
                plan at the 4096-row acceptance geometry, fused engine
                vs the CHAINED engine (the same jnp operator backend
                with ``fused_delta=None``, which drops the lowering
                back onto the per-unit op chain).  Both engines admit
                the identical update + query stream, interleaved per
                beat so host noise hits both sides alike.  Each side
                reports the per-phase wall breakdown the executor now
                records (staging / dispatch / kernel / collect) and
                the per-beat backend-op launch counts — the fused side
                must show exactly ONE fused_delta op and ZERO chained
                delta ops, asserted here so the record can never show
                a stale path.

  delta_phase() — the fused work itself (every predicated stage's
                  pane + dirty rescan, every carried join's probe)
                  measured inside one compiled carry chain at the real
                  lowered geometry, fused op vs the chained op
                  sequence.  The beat wall above is dominated by the
                  full-width group-by/sort post stages that run
                  identically on both sides, so THIS is where the
                  fusion win is measurable on a noisy host.

The record also carries the ANALYTIC roofline footprint of one fused
beat (roofline/analysis.fused_delta_footprint): bytes moved, integer
compare-ops, and which roofline term dominates on the target part.

``python -m benchmarks.fused_bench`` prints the dict;
benchmarks/run.py folds it into BENCH_PR6.json, which
tests/test_sla_gate.py gates against stored thresholds.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np

from repro.core import backends
from repro.core.executor import SharedDBEngine
from repro.core.lowering import lower_plan
from repro.roofline.analysis import fused_delta_footprint
from repro.workloads import tpcw

SCALE_ITEMS = 4096
SCALE_CUSTOMERS = 2880

CHAINED_OPS = ("scan", "scan_delta", "join_delta", "join_partitioned",
               "join_block")


def _chained_backend_name() -> str:
    """The jnp backend with the fused op removed: the lowering then
    emits the PR-4/5 chained delta path, everything else identical."""
    name = "jnp-chained"
    if name not in backends.available_backends():
        backends.register_backend(dataclasses.replace(
            backends.get_backend("jnp"), name=name, fused_delta=None))
    return name


def _phase_means(beats: List) -> Dict[str, float]:
    return {
        "wall_us": float(np.mean([b.wall_s for b in beats])) * 1e6,
        "stage_us": float(np.mean([b.t_stage_s for b in beats])) * 1e6,
        "dispatch_us": float(np.mean([b.t_dispatch_s
                                      for b in beats])) * 1e6,
        "kernel_us": float(np.mean([b.t_kernel_s for b in beats])) * 1e6,
        "collect_us": float(np.mean([b.t_collect_s
                                     for b in beats])) * 1e6,
    }


def heartbeat(scale_items: int = SCALE_ITEMS, beats: int = 10,
              warmup: int = 3) -> Dict:
    """Fused vs chained steady-state delta beat, interleaved."""
    rng = np.random.default_rng(4)
    plan = tpcw.build_tpcw_plan(scale_items, SCALE_CUSTOMERS,
                                dense_pk_index=False)
    data = tpcw.generate_data(rng, scale_items, SCALE_CUSTOMERS)
    engines = {
        "fused": SharedDBEngine(plan, tpcw.DEFAULT_UPDATE_SLOTS, data,
                                kernels="jnp"),
        "chained": SharedDBEngine(plan, tpcw.DEFAULT_UPDATE_SLOTS, data,
                                  kernels=_chained_backend_name()),
    }

    def trickle(eng, i):
        eng.submit_update("customer", "update",
                          {"key": int(rng.integers(0, SCALE_CUSTOMERS)),
                           "col": "c_expiration", "val": 13000 + i})
        eng.submit("order_lines", {0: (10, 10)})
        eng.submit("get_cart", {0: (12, 12)})
        eng.submit("get_book", {0: (5, 5)})
        return eng.run_until_drained()

    for eng in engines.values():                 # seed + compile deltas
        eng.submit("order_lines", {0: (10, 10)})
        eng.submit("get_cart", {0: (12, 12)})
        eng.submit("get_book", {0: (5, 5)})
        eng.run_until_drained()
        for i in range(warmup):
            trickle(eng, i)
    steady = {label: [] for label in engines}
    for i in range(beats):
        for label, eng in engines.items():       # interleaved beats
            steady[label].extend(b for b in trickle(eng, 100 + i)
                                 if b.join_path == "delta")
    record = {"scale_items": scale_items, "beats": beats}
    for label, bs in steady.items():
        assert bs, f"{label} engine never reached the delta-join path"
        ops: Dict[str, int] = {}
        for b in bs:
            for op, n in b.backend_ops.items():
                if n:
                    ops[op] = max(ops.get(op, 0), n)
        record[label] = {**_phase_means(bs), "backend_ops_per_beat": ops,
                         "delta_beats": len(bs)}
    fused_ops = record["fused"]["backend_ops_per_beat"]
    assert fused_ops.get("fused_delta") == 1, fused_ops
    assert all(fused_ops.get(op, 0) == 0 for op in CHAINED_OPS), \
        fused_ops
    chained_ops = record["chained"]["backend_ops_per_beat"]
    assert chained_ops.get("fused_delta", 0) == 0, chained_ops
    record["fused_vs_chained"] = (record["fused"]["wall_us"]
                                  / max(record["chained"]["wall_us"],
                                        1e-9))
    record["chained_launches"] = int(sum(chained_ops.values()))
    record["fused_launches"] = int(
        sum(fused_ops.values()))             # fused_delta + post groupbys
    return record


def delta_phase(reps: int = 5, iters: int = 40) -> Dict:
    """The fused work itself, fused op vs chained op sequence, measured
    inside one compiled carry chain at the real lowered TPC-W geometry.

    The engine-level beat wall at the acceptance scale is dominated by
    the full-width group-by/sort post stages (see the PR-3 perf table:
    "scan is not the bottleneck at this scale"), which run identically
    on both sides — so ``heartbeat()``'s wall ratio sits at ~1.0 inside
    host noise.  This is the apples-to-apples measurement of the path
    PR 6 actually fuses, at the steady-state trickle shape (ONE changed
    admission pane, ONE dirty table, ONE dirty-spine join, every other
    stage idle): the chained path re-runs every stage's pane recompute
    + dirty rescan and every carried join's probe with empty inputs —
    exactly what the chained delta cycle compiles — while the fused op
    cond-skips them (identities on the carry, kernels/ref.py).
    """
    import jax
    import jax.numpy as jnp

    from repro.core.lowering import INT_MIN, partition_layout
    from repro.core.storage import build_key_partitions, scatter_dirty_rows

    be = backends.get_backend("jnp")
    rng = np.random.default_rng(6)
    plan = tpcw.build_tpcw_plan(SCALE_ITEMS, SCALE_CUSTOMERS,
                                dense_pk_index=False)
    lowered = lower_plan(plan)
    schemas = plan.catalog.schemas

    scan_in = []
    for k, st in enumerate(s for s in lowered.scans if s.cols):
        T, D = schemas[st.table].capacity, schemas[st.table].dirty_cap
        C, Q, A = len(st.cols), st.q_window, st.delta_words
        cols = jnp.asarray(rng.integers(0, T, (C, T)), jnp.int32)
        lo = jnp.asarray(rng.integers(0, T, (C, Q)), jnp.int32)
        hi = lo + jnp.asarray(rng.integers(0, T // 8, (C, Q)), jnp.int32)
        valid = jnp.asarray(rng.random(T) > 0.05)
        carry = jax.jit(be.scan)(cols, lo, hi, valid)
        rows = np.full(D, T, np.int64)
        n_dirty = max(1, T // 100)
        if k == 1:                     # steady state: ONE dirty table,
            rows[:n_dirty] = np.sort(  # ONE stage's admission changed
                rng.choice(T, n_dirty, replace=False))
        scan_in.append(backends.FusedScanIn(
            cols=cols, lo=lo, hi=hi,
            lo_p=lo[:, :A * 32], hi_p=hi[:, :A * 32], valid=valid,
            carry=carry, w0=jnp.int32(0),
            span=jnp.int32(1 if k == 0 else 0),
            rows=jnp.asarray(rows, jnp.int32),
            dn=jnp.int32(n_dirty if k == 1 else 0)))

    join_in = []
    for k, j in enumerate(jj for jj in lowered.joins
                          if jj.kind != "gather"):
        Tl, Tr = schemas[j.spine].capacity, schemas[j.pk_table].capacity
        Dl = schemas[j.spine].dirty_cap
        keys = jnp.asarray(rng.integers(0, Tr * 2, Tl), jnp.int32)
        keys_r = jnp.asarray(rng.permutation(Tr * 2)[:Tr], jnp.int32)
        valid_r = jnp.asarray(rng.random(Tr) > 0.05)
        if j.kind == "partitioned":
            bkeys, brows, bounds = build_key_partitions(
                keys_r, valid_r, *partition_layout(Tr))
        else:                          # block: one-bucket pseudo-parts
            from repro.core.storage import INT_SENTINEL
            bkeys = jnp.where(valid_r, keys_r, INT_SENTINEL)[None, :]
            brows = jnp.where(valid_r,
                              jnp.arange(Tr, dtype=jnp.int32), -1)[None, :]
            bounds = jnp.full((1,), INT_MIN, jnp.int32)
        rows = np.full(Dl, Tl, np.int64)
        n_dirty = max(1, Tl // 100)
        if k == 0:                     # ONE join's spine dirty
            rows[:n_dirty] = np.sort(
                rng.choice(Tl, n_dirty, replace=False))
        rid0 = jnp.max(jnp.where(
            (bkeys[jnp.clip(jnp.searchsorted(
                bounds, keys, side="right").astype(jnp.int32) - 1,
                0, bounds.shape[0] - 1)] == keys[:, None]),
            brows[jnp.clip(jnp.searchsorted(
                bounds, keys, side="right").astype(jnp.int32) - 1,
                0, bounds.shape[0] - 1)], -1), axis=1)
        join_in.append(backends.FusedJoinIn(
            keys=keys, rows=jnp.asarray(rows, jnp.int32),
            dn=jnp.int32(n_dirty if k == 0 else 0),
            bkeys=bkeys, brows=brows, bounds=bounds, rid_carry=rid0))

    def chained_step(scan_in, join_in):
        """What build_delta_cycle compiles WITHOUT the fused op: every
        stage's pane + dirty rescan, every join's dirty probe."""
        words, rids = [], []
        for e in scan_in:
            T = e.cols.shape[1]
            pane = be.scan(e.cols, e.lo_p, e.hi_p, e.valid)
            m = jax.lax.dynamic_update_slice(e.carry, pane, (0, e.w0))
            dw = be.scan_delta(e.cols, e.lo, e.hi, e.valid, e.rows)
            words.append(scatter_dirty_rows(m, e.rows, dw, T))
        for e in join_in:
            rd = be.join_delta(e.keys, e.rows, e.bkeys, e.brows, e.bounds)
            rids.append(scatter_dirty_rows(e.rid_carry, e.rows, rd,
                                           e.keys.shape[0]))
        return tuple(words), tuple(rids)

    # both sides must be identities on the steady-state carry
    wf, rf = jax.jit(be.fused_delta)(tuple(scan_in), tuple(join_in))
    wc, rc = jax.jit(chained_step)(tuple(scan_in), tuple(join_in))
    for a, b, e in zip(wf, wc, scan_in):
        assert (np.asarray(a) == np.asarray(b)).all()
        assert (np.asarray(a) == np.asarray(e.carry)).all()
    for a, b, e in zip(rf, rc, join_in):
        assert (np.asarray(a) == np.asarray(b)).all()
        assert (np.asarray(a) == np.asarray(e.rid_carry)).all()

    def loop(step):
        # thread a dependency through every stage's inputs so nothing
        # hoists out of the measured carry chain
        def body(_, m):
            p = (m[0, 0] & jnp.uint32(0)).astype(jnp.int32)
            s_in = tuple(e._replace(cols=e.cols + p) for e in scan_in)
            j_in = tuple(e._replace(keys=e.keys + p) for e in join_in)
            words, rids = step(s_in, j_in)
            dep = sum((w[0, 0] & jnp.uint32(0) for w in words[1:]),
                      jnp.uint32(0))
            dep += sum((r[0] & 0 for r in rids), 0).astype(jnp.uint32)
            return words[0] ^ dep
        return jax.jit(lambda: jax.lax.fori_loop(
            0, iters, body, scan_in[0].carry))

    loop_f, loop_c = loop(be.fused_delta), loop(chained_step)
    jax.block_until_ready(loop_f())                        # compile
    jax.block_until_ready(loop_c())
    t_f = t_c = float("inf")
    for _ in range(reps):          # alternate sides so drift cancels
        t_f = min(t_f, _best_of_phase(loop_f))
        t_c = min(t_c, _best_of_phase(loop_c))
    t_f /= iters
    t_c /= iters
    return {"scan_stages": len(scan_in), "joins": len(join_in),
            "chained_us": t_c * 1e6, "fused_us": t_f * 1e6,
            "speedup": t_c / max(t_f, 1e-12)}


def _best_of_phase(fn) -> float:
    import time

    import jax
    t0 = time.perf_counter()
    jax.block_until_ready(fn())
    return time.perf_counter() - t0


def run(smoke: bool = False) -> Dict:
    rec = heartbeat(beats=6 if smoke else 12)
    rec["delta_phase"] = delta_phase()
    lowered = lower_plan(tpcw.build_tpcw_plan(SCALE_ITEMS,
                                              SCALE_CUSTOMERS,
                                              dense_pk_index=False))
    rec["roofline"] = fused_delta_footprint(lowered)
    return rec


if __name__ == "__main__":
    import json
    import sys
    print(json.dumps(run(smoke="--smoke" in sys.argv), indent=2))

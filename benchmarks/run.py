# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness: one entry per paper figure (Figs. 7-11) plus the
beyond-paper roofline report, the critical-path record, and the
incremental-scan / incremental-join / sharded-reseed records.

    python -m benchmarks.run [--quick]   # figures + BENCH_PR3/4/5.json
    python -m benchmarks.run --smoke     # machine-readable records only
                                         # (the CI cycle-time SLA gate);
                                         # refuses to overwrite committed
                                         # BENCH_PR*.json without --force

Every invocation (re)writes the machine-readable perf trajectory:
``BENCH_PR3.json`` (per-heartbeat cycle time, host dispatch/staging
time, the partitioned-vs-block join scaling curve, the pipelined/sync
cycle-time ratio, and the delta-vs-full-rescan scan curve +
steady-state heartbeat), ``BENCH_PR4.json`` (the delta-vs-full JOIN
probe curve + the index-less steady-state heartbeat) and
``BENCH_PR5.json`` (the sharded reseed beat on a multi-shard row mesh
vs a single shard — measured in a SUBPROCESS with forced host devices,
so the single-device records above stay undisturbed) and
``BENCH_PR6.json`` (the fused delta-heartbeat record: fused vs chained
steady-state beat with per-phase wall breakdown + launch counts, the
analytic fused-beat roofline footprint, and the end-to-end
sharded/single delta-beat ratio) and ``BENCH_PR8.json`` (the dynamic
plan-folding serving record: steady-state delta beat vs beats served
while a background fold builds the extended plan — gated within 1.5x —
plus the migration-beat wall and the post-fold fused steady beat).
``tests/test_sla_gate.py`` fails the build when any record regresses
past its stored thresholds — including when a record or row goes
missing.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

BENCH_JSON = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                          os.pardir, "BENCH_PR3.json")
BENCH_PR4_JSON = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                              os.pardir, "BENCH_PR4.json")
BENCH_PR5_JSON = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                              os.pardir, "BENCH_PR5.json")
BENCH_PR6_JSON = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                              os.pardir, "BENCH_PR6.json")
BENCH_PR8_JSON = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                              os.pardir, "BENCH_PR8.json")


def write_bench_pr8(smoke: bool) -> dict:
    """The dynamic plan-folding serving record: steady-state delta beat
    wall vs beats served WHILE a background fold builds the extended
    plan (the gate holds the ratio within 1.5x — folding must not stall
    the world), plus the single migration-beat wall and the post-fold
    steady beat back on the fused single launch."""
    from benchmarks import fold_bench
    record = {"pr": 8, "mode": "smoke" if smoke else "full",
              "fold": fold_bench.run(smoke=smoke)}
    path = os.path.abspath(BENCH_PR8_JSON)
    with open(path, "w") as f:
        json.dump(record, f, indent=2)
        f.write("\n")
    fo = record["fold"]
    print(f"== Plan folding -> {path} ==", flush=True)
    print(f"steady delta beat {fo['steady_us']:.0f}us vs "
          f"{fo['during_fold_us']:.0f}us during the background fold "
          f"(ratio {fo['fold_serving_ratio']:.3f}; "
          f"{fo['beats_during_build']} beats served while the extended "
          f"plan built for {fo['build_wall_s']:.1f}s); migration beat "
          f"{fo['migration_beat_us']:.0f}us; post-fold steady "
          f"{fo['post_steady_us']:.0f}us "
          f"({fo['post_fold_launches']} launches)", flush=True)
    return record


def write_bench_pr6(smoke: bool, pr5_record: dict) -> dict:
    """The fused delta-heartbeat record: fused vs chained steady-state
    beat (single device, in-process like the PR-3/4 records) with the
    per-phase wall breakdown, per-beat backend-op launch counts and the
    analytic roofline footprint of one fused beat — plus the end-to-end
    sharded/single delta-beat ratio lifted from the PR-5 subprocess
    record (same forced-host mesh, so the ratio is apples-to-apples)."""
    from benchmarks import fused_bench
    e = pr5_record["sharded_engine"]
    record = {"pr": 6, "mode": "smoke" if smoke else "full",
              "fused": fused_bench.run(smoke=smoke),
              "sharded_delta": {
                  "shards": e["shards"],
                  "sharded_delta_heartbeat_us": e["delta_heartbeat_us"],
                  "single_delta_heartbeat_us":
                      e["single_delta_heartbeat_us"],
                  "ratio": e["sharded_delta_ratio"]}}
    path = os.path.abspath(BENCH_PR6_JSON)
    with open(path, "w") as f:
        json.dump(record, f, indent=2)
        f.write("\n")
    fu = record["fused"]
    print(f"== Fused delta heartbeat -> {path} ==", flush=True)
    print(f"fused {fu['fused']['wall_us']:.0f}us vs chained "
          f"{fu['chained']['wall_us']:.0f}us per delta beat "
          f"(ratio {fu['fused_vs_chained']:.3f}; fused launches "
          f"{fu['fused_launches']} vs chained "
          f"{fu['chained_launches']}); phase breakdown fused "
          f"stage/dispatch/kernel/collect = "
          f"{fu['fused']['stage_us']:.0f}/"
          f"{fu['fused']['dispatch_us']:.0f}/"
          f"{fu['fused']['kernel_us']:.0f}/"
          f"{fu['fused']['collect_us']:.0f}us; delta phase fused "
          f"{fu['delta_phase']['fused_us']:.0f}us vs chained "
          f"{fu['delta_phase']['chained_us']:.0f}us "
          f"({fu['delta_phase']['speedup']:.2f}x); sharded/single delta "
          f"ratio {record['sharded_delta']['ratio']:.2f}", flush=True)
    return record


def write_bench_pr5(smoke: bool) -> dict:
    """Run the sharded bench in a subprocess (it forces the 8-device
    host platform before jax initializes) and fold the record into
    ``BENCH_PR5.json``.  A failing subprocess fails the run — the SLA
    gate must never see a silently missing record."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    if "--xla_force_host_platform_device_count" not in \
            env.get("XLA_FLAGS", ""):
        env["XLA_FLAGS"] = " ".join(
            [env.get("XLA_FLAGS", ""),
             "--xla_force_host_platform_device_count=8"]).strip()
    env.setdefault("JAX_PLATFORMS", "cpu")
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(root, "src"), env.get("PYTHONPATH", "")]).rstrip(
        os.pathsep)
    cmd = [sys.executable, "-m", "benchmarks.sharded_bench"]
    if smoke:
        cmd.append("--smoke")
    out = subprocess.run(cmd, capture_output=True, text=True, cwd=root,
                         timeout=3600, env=env)
    if out.returncode != 0:
        raise RuntimeError(
            f"sharded bench failed:\n{out.stderr[-4000:]}")
    rec = json.loads(out.stdout)
    record = {"pr": 5, "mode": "smoke" if smoke else "full",
              "sharded_reseed": rec["per_device"],
              "sharded_engine": rec["engine"]}
    path = os.path.abspath(BENCH_PR5_JSON)
    with open(path, "w") as f:
        json.dump(record, f, indent=2)
        f.write("\n")
    rs, e = record["sharded_reseed"], record["sharded_engine"]
    print(f"== Sharded reseed -> {path} ==", flush=True)
    print(f"per-device reseed scan x{rs['shards']} shards: "
          f"{rs['shard_scan_us']:.0f}us vs single-shard "
          f"{rs['full_scan_us']:.0f}us ({rs['speedup']:.2f}x); "
          f"engine reseed sharded {e['sharded_reseed_us']:.0f}us vs "
          f"single {e['single_reseed_us']:.0f}us on forced host "
          f"devices; sharded delta beat {e['delta_heartbeat_us']:.0f}us "
          f"(delta fraction {e['delta_cycle_fraction']:.2f})",
          flush=True)
    return record


def _emit(name: str, us: float, derived: str):
    print(f"{name},{us:.1f},{derived}", flush=True)


def write_bench_json(smoke: bool) -> dict:
    from benchmarks import critical_path, delta_scan_bench
    record = {"pr": 3, "mode": "smoke" if smoke else "full",
              **critical_path.run(smoke=smoke),
              "delta_scan": delta_scan_bench.run(smoke=smoke)}
    path = os.path.abspath(BENCH_JSON)
    with open(path, "w") as f:
        json.dump(record, f, indent=2)
        f.write("\n")
    big = record["join_scaling"][-1]
    print(f"== Critical path -> {path} ==", flush=True)
    print(f"join {big['keys']}x{big['keys']}: partitioned "
          f"{big['partitioned_us']:.0f}us vs block {big['block_us']:.0f}us "
          f"({big['speedup']:.1f}x)", flush=True)
    print(f"staging: packed {record['dispatch']['packed_stage_us']:.0f}us "
          f"vs per-template "
          f"{record['dispatch']['per_template_stage_us']:.0f}us "
          f"({record['dispatch']['stage_speedup']:.1f}x)", flush=True)
    print(f"cycle: sync {record['cycle']['mean_cycle_us_sync']:.0f}us, "
          f"pipelined {record['cycle']['mean_cycle_us_pipelined']:.0f}us "
          f"(ratio {record['cycle']['pipelined_sync_ratio']:.3f})",
          flush=True)
    ds = record["delta_scan"]
    big = ds["curve"][-1]
    print(f"delta scan {big['rows']} rows: {big['delta_us']:.0f}us vs "
          f"full {big['full_us']:.0f}us ({big['speedup']:.1f}x); "
          f"steady heartbeat delta "
          f"{ds['heartbeat']['delta_heartbeat_us']:.0f}us vs full "
          f"{ds['heartbeat']['full_heartbeat_us']:.0f}us "
          f"(delta fraction "
          f"{ds['heartbeat']['delta_cycle_fraction']:.2f})", flush=True)

    from benchmarks import delta_join_bench
    record4 = {"pr": 4, "mode": "smoke" if smoke else "full",
               "delta_join": delta_join_bench.run(smoke=smoke)}
    path4 = os.path.abspath(BENCH_PR4_JSON)
    with open(path4, "w") as f:
        json.dump(record4, f, indent=2)
        f.write("\n")
    dj = record4["delta_join"]
    big = dj["curve"][-1]
    print(f"== Delta joins -> {path4} ==", flush=True)
    print(f"delta join {big['rows']} rows: {big['delta_us']:.0f}us vs "
          f"full probe {big['full_us']:.0f}us ({big['speedup']:.1f}x); "
          f"index-less steady heartbeat delta "
          f"{dj['heartbeat']['delta_heartbeat_us']:.0f}us vs full "
          f"{dj['heartbeat']['full_heartbeat_us']:.0f}us "
          f"(delta-join fraction "
          f"{dj['heartbeat']['delta_join_fraction']:.2f})", flush=True)

    record5 = write_bench_pr5(smoke)
    write_bench_pr6(smoke, record5)
    write_bench_pr8(smoke)
    return record


def _existing_bench_records():
    """Committed BENCH_PR*.json records a --smoke run would overwrite."""
    root = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        os.pardir)
    return sorted(
        os.path.abspath(os.path.join(root, f))
        for f in os.listdir(root)
        if f.startswith("BENCH_PR") and f.endswith(".json"))


def main() -> None:
    quick = "--quick" in sys.argv
    t_start = time.time()

    if "--smoke" in sys.argv:
        # a smoke run writes the SAME BENCH_PR*.json paths as a full
        # run — silently clobbering committed full-mode records with
        # smoke-mode numbers poisons every later comparison.  Refuse
        # unless explicitly forced.
        existing = _existing_bench_records()
        if existing and "--force" not in sys.argv:
            print("refusing to overwrite committed bench records with "
                  "smoke-mode numbers:", file=sys.stderr)
            for p in existing:
                print(f"  {p}", file=sys.stderr)
            print("re-run with --force to overwrite them anyway",
                  file=sys.stderr)
            raise SystemExit(2)
        write_bench_json(smoke=True)
        print(f"total bench wall: {time.time() - t_start:.0f}s", flush=True)
        return

    from benchmarks import (fig7_throughput, fig8_scaling, fig9_interactions,
                            fig10_heavy_light, fig11_interaction,
                            roofline_report)

    print("== Fig 7: throughput vs load (3 mixes) ==", flush=True)
    rows = fig7_throughput.run(
        rates=(10, 60) if quick else (10, 40, 120, 250),
        duration=6.0 if quick else 10.0,
        mixes=("shopping",) if quick else ("browsing", "shopping",
                                           "ordering"))
    for mix, rate, rs, rb in rows:
        _emit(f"fig7_{mix}_r{rate}_shared", rs.mean_cycle_s * 1e6,
              f"good_wips={rs.good_wips:.2f};p99_s={rs.p99_s:.2f}")
        _emit(f"fig7_{mix}_r{rate}_qaat", 0.0,
              f"good_wips={rb.good_wips:.2f};p99_s={rb.p99_s:.2f}")

    print("== Fig 8: scaling with cores (projection) ==", flush=True)
    for k, sh, ba in fig8_scaling.run(n=24 if quick else 64):
        _emit(f"fig8_cores{k}", 0.0,
              f"shared_wips={sh:.1f};qaat_wips={ba:.1f}")

    print("== Fig 9: individual web interactions ==", flush=True)
    for kind, ws, wb in fig9_interactions.run(
            n_per_kind=8 if quick else 32):
        _emit(f"fig9_{kind}", 1e6 / max(ws, 1e-9),
              f"shared_wips={ws:.1f};qaat_wips={wb:.1f}")

    print("== Fig 10: heavy vs light batches ==", flush=True)
    for template, n, ts, tb in fig10_heavy_light.run(
            sizes=(1, 16, 64) if quick else (1, 4, 16, 64, 256)):
        _emit(f"fig10_{template}_n{n}", ts / max(n, 1) * 1e6,
              f"shared_s={ts:.3f};qaat_s={tb:.3f};"
              f"speedup={tb / max(ts, 1e-9):.2f}")

    print("== Fig 11: load interaction ==", flush=True)
    for hr, rs, rb in fig11_interaction.run(
            heavy_rates=(0, 20, 200) if quick else (0, 20, 80, 200, 400),
            duration=6.0 if quick else 12.0):
        _emit(f"fig11_heavy{hr}", rs.mean_cycle_s * 1e6,
              f"shared_good={rs.good_wips:.2f};qaat_good={rb.good_wips:.2f}")

    print("== Pipeline: dispatch/collect overlap vs sync ==", flush=True)
    from benchmarks import pipeline_bench
    for label, dt, cycles, per_cycle in pipeline_bench.run(
            n=100 if quick else 300):
        _emit(f"pipeline_{label}", per_cycle * 1e6,
              f"total_s={dt:.3f};cycles={cycles}")

    print("== Roofline (from dry-run artifacts) ==", flush=True)
    for arch, shape, r in roofline_report.run():
        _emit(f"roofline_{arch}_{shape}", r["step_time_s"] * 1e6,
              f"dom={r['dominant']};frac={r['roofline_fraction']:.3f}")

    write_bench_json(smoke=quick)

    print(f"total bench wall: {time.time() - t_start:.0f}s", flush=True)


if __name__ == "__main__":
    main()

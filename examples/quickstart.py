"""Quickstart: the SharedDB engine in ~60 lines.

Builds a TPC-W database, submits a mixed batch of concurrent queries +
updates, runs heartbeat cycles, and shows that one shared plan answered
everything — including per-query results and the bounded-computation SLA
model.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import sla
from repro.core.executor import SharedDBEngine
from repro.workloads import tpcw

rng = np.random.default_rng(0)
SCALE = dict(scale_items=1000, scale_customers=2880)

plan = tpcw.build_tpcw_plan(**SCALE)
data = tpcw.generate_data(rng, **SCALE)
engine = SharedDBEngine(plan, tpcw.DEFAULT_UPDATE_SLOTS, data)

print("Global plan (always-on, compiled once):")
print(f"  {len(plan.scans)} shared scans, {len(plan.joins)} shared joins, "
      f"{len(plan.sorts)} shared sorts, {len(plan.groups)} shared "
      f"group-bys; query capacity {plan.qcap}/cycle")

# one hundred concurrent queries of different types, one stone
tickets = []
for i in range(40):
    item = int(rng.integers(0, 1000))
    tickets.append(engine.submit("get_book", {0: (item, item)}))
for s in range(10):
    tickets.append(engine.submit("search_subject", {0: (s, s)}))
lo = 2000
tickets.append(engine.submit("best_sellers",
                             {0: (lo, 2**31 - 1), 1: (3, 3)}))
engine.submit_update("item", "update", {"key": 7, "col": "i_cost",
                                        "val": 999})

engine.run_until_drained()
print(f"\n{len(tickets)} queries answered in {engine.cycles_run} "
      f"heartbeat cycle(s)")

bk = tickets[0]
rows = bk.result["rows"]
item_row = engine.materialize("item", rows[rows >= 0][:1])
print(f"get_book -> item row {item_row['i_id'][0]}, "
      f"cost {item_row['i_cost'][0]} cents")
bs = tickets[-1]
print(f"best_sellers -> top-5 items {bs.result['groups'][:5].tolist()}, "
      f"qty {bs.result['scores'][:5].astype(int).tolist()}")

model = sla.provision(plan, sla_seconds=3.0)
print(f"\nSLA model: worst-case cycle {model['worst_cycle_s']*1e3:.2f} ms "
      f"per chip -> {model['chips_required']} chip(s) for a 3 s SLA")
print(model["guarantee"])

"""Serve a reduced-config LM with SharedDB heartbeat cycles: batched
admission, one always-on compiled plan, bounded per-cycle work.

    PYTHONPATH=src python examples/serve_lm.py [arch]
"""
import sys

from repro.launch import serve

arch = sys.argv[1] if len(sys.argv) > 1 else "recurrentgemma-2b"
serve.main(["--arch", arch, "--smoke", "--requests", "24",
            "--capacity", "8", "--max-seq", "96", "--prefill-len", "24",
            "--new-tokens", "12"])

"""Train a reduced-config LM end-to-end on CPU with the full substrate:
sharded data pipeline, AdamW, atomic checkpointing, fault-tolerant loop
(including an injected mid-run failure + bit-exact resume).

    PYTHONPATH=src python examples/train_lm.py [arch]
"""
import shutil
import sys
import tempfile

from repro.launch import train

arch = sys.argv[1] if len(sys.argv) > 1 else "mamba2-370m"
ckpt = tempfile.mkdtemp(prefix="repro_ckpt_")
try:
    log = train.main(["--arch", arch, "--smoke", "--steps", "40",
                      "--batch", "8", "--seq", "64", "--ckpt", ckpt,
                      "--save-every", "10"])
    losses = [m["loss"] for m in log]
    assert losses[-1] < losses[0], "loss did not improve"
    print(f"\nloss improved {losses[0]:.3f} -> {losses[-1]:.3f}; "
          f"checkpoints in {ckpt} (atomic, keep-last-3)")
finally:
    shutil.rmtree(ckpt, ignore_errors=True)

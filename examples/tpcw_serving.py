"""End-to-end driver: the full TPC-W workload served by SharedDB.

Replays a stream of web interactions from the shopping mix against the
shared engine AND the query-at-a-time baseline, printing the throughput /
latency comparison (the in-miniature version of the paper's Fig. 7).

    PYTHONPATH=src python examples/tpcw_serving.py [n_interactions]
"""
import sys
import time

import numpy as np

from repro.core.baseline import QueryAtATimeEngine
from repro.core.executor import SharedDBEngine
from repro.workloads import tpcw

n = int(sys.argv[1]) if len(sys.argv) > 1 else 150
rng = np.random.default_rng(1)
SCALE_I, SCALE_C = 1000, 2880

plan = tpcw.build_tpcw_plan(SCALE_I, SCALE_C)
data = tpcw.generate_data(rng, SCALE_I, SCALE_C)
shared = SharedDBEngine(plan, tpcw.DEFAULT_UPDATE_SLOTS, data)
qaat = QueryAtATimeEngine(plan, data)
gen = tpcw.WorkloadGenerator(rng, SCALE_I, SCALE_C)

inters = gen.sample_mix("shopping", n)
n_q = sum(len(it.queries) for it in inters)
n_u = sum(len(it.updates) for it in inters)
print(f"{n} shopping-mix interactions = {n_q} queries + {n_u} updates")

# ---- SharedDB: everything batched through the always-on plan -----------
t0 = time.time()
for it in inters:
    for q in it.queries:
        shared.submit(*q)
    for u in it.updates:
        shared.submit_update(*u)
shared.run_until_drained()
t_shared = time.time() - t0
print(f"SharedDB : {n / t_shared:7.1f} WIPS  "
      f"({shared.cycles_run} cycles, "
      f"{t_shared / max(shared.cycles_run, 1) * 1e3:.0f} ms/cycle, "
      f"includes first-cycle compile)")

# ---- query-at-a-time baseline ------------------------------------------
inters2 = gen.sample_mix("shopping", n)
t0 = time.time()
for it in inters2:
    for u in it.updates:
        qaat.apply_update(*u)
    for q in it.queries:
        qaat.execute(*q)
t_base = time.time() - t0
print(f"QueryAtAT: {n / t_base:7.1f} WIPS")
print(f"shared-vs-qaat wall ratio at n={n}: {t_base / t_shared:.2f}x "
      f"(grows with concurrency — see benchmarks/fig7, fig10, fig11)")

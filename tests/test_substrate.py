"""Substrate tests: data pipeline, checkpoint, fault tolerance, elastic,
optimizer, serving scheduler."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.checkpoint import CheckpointManager
from repro.configs import smoke_config
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.optim.adamw import (AdamWConfig, adamw_init, adamw_update,
                               compress_grads)
from repro.runtime import ElasticMeshManager, FaultTolerantLoop
from repro.runtime.fault_tolerance import HeartbeatBoard, StragglerPolicy
from repro.serving import CycleServer


# ------------------------------------------------------------------ data
def test_pipeline_deterministic_and_replayable():
    cfg = DataConfig(vocab=100, seq_len=16, global_batch=4, seed=7)
    p1 = TokenPipeline(cfg)
    p2 = TokenPipeline(cfg)
    b1 = p1.batch_at(5)
    b2 = p2.batch_at(5)
    assert (b1["tokens"] == b2["tokens"]).all()
    assert (b1["labels"] == b1["tokens"] * 0 + b1["labels"]).all()
    # labels are next-token shifted
    assert b1["tokens"].shape == (4, 16)


def test_pipeline_host_sharding_disjoint_rng():
    cfg = DataConfig(vocab=1000, seq_len=32, global_batch=8, seed=1)
    h0 = TokenPipeline(cfg, host_id=0, n_hosts=2).batch_at(0)
    h1 = TokenPipeline(cfg, host_id=1, n_hosts=2).batch_at(0)
    assert h0["tokens"].shape == (4, 32)
    assert not (h0["tokens"] == h1["tokens"]).all()


# ------------------------------------------------------------ checkpoint
def test_checkpoint_roundtrip_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": np.arange(10, dtype=np.float32),
            "b": {"c": np.ones((3, 3), np.int32)}}
    for step in (10, 20, 30):
        mgr.save(tree, step, extra={"next_step": step})
    assert mgr.latest_step() == 30
    got, manifest = mgr.restore(tree, 30)
    assert (got["a"] == tree["a"]).all()
    assert manifest["extra"]["next_step"] == 30
    # keep=2 garbage-collected step 10
    assert not os.path.isdir(tmp_path / "step_00000010")


def test_checkpoint_detects_corruption(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = {"w": np.arange(100, dtype=np.float32)}
    path = mgr.save(tree, 1, extra={"next_step": 1})
    shard = os.path.join(path, "shard_0.npz")
    blob = dict(np.load(shard))
    blob["w"][0] = 999.0
    np.savez(shard, **blob)
    with pytest.raises(IOError, match="corruption"):
        mgr.restore(tree, 1)


def test_checkpoint_ignores_partial_writes(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = {"w": np.zeros(4, np.float32)}
    mgr.save(tree, 5, extra={"next_step": 5})
    os.makedirs(tmp_path / "step_00000009.tmp")   # simulated crash
    assert mgr.latest_step() == 5
    CheckpointManager(str(tmp_path))              # reopen: gc the .tmp
    assert not os.path.isdir(tmp_path / "step_00000009.tmp")


# -------------------------------------------------------- fault tolerance
def test_fault_tolerant_loop_restarts_bit_exact(tmp_path):
    """Inject a failure mid-run; the loop must resume from the checkpoint
    and produce the SAME final state as an uninterrupted run."""
    def step_fn(state, step):
        return {"x": state["x"] + step}, {"step": step}

    mgr1 = CheckpointManager(str(tmp_path / "a"))
    loop1 = FaultTolerantLoop(step_fn, mgr1, save_every=5)
    s1, _ = loop1.run({"x": np.zeros(2)}, 0, 20)

    mgr2 = CheckpointManager(str(tmp_path / "b"))
    loop2 = FaultTolerantLoop(step_fn, mgr2, save_every=5)
    s2, _ = loop2.run({"x": np.zeros(2)}, 0, 20,
                      fail_at={13: RuntimeError("injected node failure")})
    assert loop2.restarts == 1
    np.testing.assert_array_equal(s1["x"], s2["x"])


def test_fault_before_first_checkpoint_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    loop = FaultTolerantLoop(lambda s, i: (s, {}), mgr, save_every=50)
    with pytest.raises(RuntimeError):
        loop.run({"x": np.zeros(1)}, 0, 10,
                 fail_at={2: RuntimeError("early failure")})


def test_straggler_detection():
    board = HeartbeatBoard()
    pol = StragglerPolicy(factor=1.5, patience=3)
    for step in range(4):
        for host in range(4):
            dur = 1.0 if host != 2 else 3.0   # host 2 is slow
            board.beat(host, step, dur, now=float(step))
    assert board.stragglers(pol) == [2]
    assert board.dead_hosts(pol, now=100.0) == [0, 1, 2, 3]
    assert board.dead_hosts(pol, now=3.5) == []


def test_elastic_mesh_ladder():
    mgr = ElasticMeshManager()
    assert mgr.select(512) == (2, 16, 16)
    assert mgr.select(511) == (1, 16, 16)
    assert mgr.select(200, global_batch=256) == (1, 8, 16)
    plan = mgr.shrink_plan((2, 16, 16), 300)
    assert plan["target"] == (1, 16, 16)
    with pytest.raises(RuntimeError):
        mgr.select(0)


# ladder validation / explicit alive-device meshes / never-beaten-host
# death live in tests/test_elastic_relower.py (no hypothesis needed)


# --------------------------------------------------------------- optimizer
def test_adamw_descends_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = adamw_init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, gnorm = adamw_update(params, grads, state, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.2


@given(seed=st.integers(0, 1000))
@settings(max_examples=10, deadline=None)
def test_sign_compression_error_feedback_unbiased(seed):
    """With error feedback, compressed updates track the true gradient sum
    (the residual stays bounded)."""
    rng = np.random.default_rng(seed)
    cfg = AdamWConfig(compression="sign")
    g_total = np.zeros(8)
    q_total = np.zeros(8)
    state = {}
    for _ in range(60):
        g = {"w": jnp.asarray(rng.standard_normal(8), jnp.float32)}
        q, state = compress_grads(g, state, cfg)
        g_total += np.asarray(g["w"])
        q_total += np.asarray(q["w"])
    err = np.abs(g_total - q_total).max()
    # residual bounded by one step's magnitude, not growing with T
    assert err < 6.0


# ----------------------------------------------------------------- serving
def test_cycle_server_bounded_cycles_and_completion():
    cfg = smoke_config("stablelm-1.6b")
    srv = CycleServer(cfg, capacity=4, max_seq=64, prefill_len=8,
                      prefill_budget=2)
    rng = np.random.default_rng(0)
    reqs = [srv.submit(rng.integers(1, cfg.vocab, 8).tolist(),
                       max_new_tokens=5) for _ in range(10)]
    done = srv.run_until_drained()
    assert len(done) == 10
    assert all(len(r.output) == 5 for r in reqs)
    # bounded admission: at most `capacity` active at once
    assert srv.cycles >= 10 * 5 // 4 // 2  # sanity lower bound


def test_cycle_server_decode_matches_offline_prefill():
    """A served continuation equals offline teacher-forced generation."""
    from repro.models.registry import get_model
    cfg = smoke_config("yi-6b")
    srv = CycleServer(cfg, capacity=2, max_seq=32, prefill_len=8)
    api = get_model(cfg)
    prompt = list(range(1, 9))
    r = srv.submit(prompt, max_new_tokens=4)
    srv.run_until_drained()
    # offline: greedy decode with the same params
    toks = list(prompt)
    params = srv.params
    logits, cache = api.prefill(params, {"tokens": jnp.asarray([toks],
                                                               jnp.int32)},
                                cache_capacity=32)
    out = [int(jnp.argmax(logits[0]))]
    pos = len(toks)
    for _ in range(3):
        logits, cache = api.decode_step(
            params, cache, jnp.asarray([[out[-1]]], jnp.int32),
            jnp.asarray([pos], jnp.int32))
        out.append(int(jnp.argmax(logits[0])))
        pos += 1
    assert r.output == out

"""Fold differential leg, run under ``python -O`` (CI named step).

Runs the dynamic plan-folding differential — mid-stream registration
through ``QueryCycleServer``, carry migration, the forced full-rescan
migration beat, post-fold parity against a COLD engine compiled with
the final template set — with assert statements STRIPPED.  That is the
point of the leg: the engine's carry/layout guard and the fold
admission rules must be real errors (``RuntimeError`` /
``FoldError``), not asserts, so every check here is an explicit raise.

    PYTHONPATH=src python -O tests/run_fold_differential.py
"""
import os
import sys

os.environ.setdefault("JAX_PLATFORMS", "cpu")
_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        _flags + " --xla_force_host_platform_device_count=8").strip()

import numpy as np  # noqa: E402

from repro.core.executor import SharedDBEngine  # noqa: E402
from repro.core.plan import compile_plan  # noqa: E402
from repro.serving import QueryCycleServer  # noqa: E402
from repro.workloads import tpcw  # noqa: E402

SCALE_I, SCALE_C = 64, 128
N_BASE = 10


def check(cond, msg):
    if not cond:
        raise SystemExit(f"FOLD DIFFERENTIAL FAILED: {msg}")


def compare(a, b):
    ra, rb = a.result, b.result
    check(ra is not None and rb is not None, f"unserved {a.template}")
    if "rows" in ra:
        sa = set(int(x) for x in np.asarray(ra["rows"]) if x >= 0)
        sb = set(int(x) for x in np.asarray(rb["rows"]) if x >= 0)
        check(sa == sb, f"{a.template} rows {sorted(sa)[:5]} != "
                        f"{sorted(sb)[:5]}")
    else:
        sa = np.sort(np.asarray(ra["scores"]).ravel())
        sb = np.sort(np.asarray(rb["scores"]).ravel())
        check(np.allclose(sa, sb, rtol=1e-6), f"{a.template} scores")


def run(mesh, tag):
    catalog = tpcw.make_catalog(SCALE_I, SCALE_C)
    templates, caps = tpcw.make_templates(
        catalog.schemas["item"].capacity)
    base = compile_plan(catalog, templates[:N_BASE],
                        {t.name: caps[t.name]
                         for t in templates[:N_BASE]})
    full = compile_plan(catalog, list(templates), caps)

    def data():
        return tpcw.generate_data(np.random.default_rng(0),
                                  SCALE_I, SCALE_C)

    eng = SharedDBEngine(base, tpcw.DEFAULT_UPDATE_SLOTS, data(),
                         kernels="jnp", mesh=mesh)
    server = QueryCycleServer(eng, background_folds=False)
    cold = SharedDBEngine(full, tpcw.DEFAULT_UPDATE_SLOTS, data(),
                          kernels="jnp", mesh=mesh)
    pairs = []

    def submit(name, params):
        pairs.append((server.submit(name, params),
                      cold.submit(name, params)))

    def update(u):
        server.submit_update(*u)
        cold.submit_update(*u)

    def heartbeat():
        server.heartbeat()
        cold.run_until_drained()
        while pairs:
            compare(*pairs.pop())

    submit("get_book", {0: (5, 5)})
    submit("search_subject", {0: (2, 2)})
    heartbeat()
    for i in range(2):
        update(("customer", "update", {"key": 3 + i,
                                       "col": "c_expiration",
                                       "val": 900 + i}))
        submit("get_customer", {0: (7 + i, 7 + i)})
        submit("get_book", {0: (5, 5)})
        heartbeat()
    check(eng.delta_cycles >= 1, f"{tag}: no delta beat engaged")

    # register the held-out templates mid-stream, one fold for the batch
    out = server.register_templates(
        [(t, caps[t.name]) for t in templates[N_BASE:]])
    check(all(r["status"] == "folding" for r in out), f"{tag}: {out}")
    submit("order_lines", {0: (10, 10)})
    submit("get_cart", {0: (12, 12)})
    submit("order_display", {0: (9, 9)})
    heartbeat()
    check(eng.folds_done == 1, f"{tag}: fold did not commit")
    check(eng.last_scan_path == "full",
          f"{tag}: migration beat was {eng.last_scan_path!r}")

    for i in range(3):          # post-fold steady state, slot-stable
        update(("customer", "update", {"key": 5 + i,
                                       "col": "c_expiration",
                                       "val": 40 + i}))
        submit("order_lines", {0: (20 + i, 20 + i)})
        submit("get_cart", {0: (12, 12)})
        submit("get_book", {0: (5, 5)})
        heartbeat()
    check(eng.last_scan_path == "delta",
          f"{tag}: post-fold steady state fell off the delta path")
    for table in ("item", "customer", "order_line"):
        got, want = eng.snapshot(table), cold.snapshot(table)
        for col in base.catalog.schemas[table].columns:
            check((got[col] == want[col]).all(),
                  f"{tag}: snapshot {table}.{col}")

    # the carry/layout guard must hold with asserts stripped: repeat
    # the last steady beat verbatim (delta-eligible) on a stale token
    eng.submit("order_lines", {0: (22, 22)})
    eng.submit("get_cart", {0: (12, 12)})
    eng.submit("get_book", {0: (5, 5)})
    eng._carry_token = ("stale-layout",)
    try:
        eng.dispatch()
    except RuntimeError:
        eng._carry_token = eng._layout_token
    else:
        raise SystemExit(f"{tag}: stale-carry dispatch did not raise")
    print(f"fold differential ok [{tag}]", flush=True)


def check_stripped_guards():
    """The hot-path guards converted from bare asserts (planlint rule
    ``no-bare-assert``) must still fire with asserts stripped — that is
    the point of the conversion."""
    from repro.core.dataquery import mask_width
    from repro.core.storage import bulk_load
    schema = tpcw.make_catalog(SCALE_I, SCALE_C).schemas["country"]
    overflow = {c: np.zeros(schema.capacity + 1, np.int32)
                for c in schema.columns}
    try:
        bulk_load(schema, overflow)
    except ValueError as e:
        check("planlint:no-bare-assert" in str(e),
              f"bulk_load guard lost its rule id: {e}")
    else:
        raise SystemExit("bulk_load overflow did not raise under -O")
    try:
        mask_width(33)
    except ValueError:
        pass
    else:
        raise SystemExit("mask_width(33) did not raise under -O")
    print("stripped-guard probes ok", flush=True)


def main():
    if __debug__:
        raise SystemExit("this leg must run under python -O "
                         "(assert statements stripped)")
    from jax.sharding import Mesh
    import jax
    check_stripped_guards()
    run(None, "unsharded")
    devs = np.array(jax.devices()[:2])
    with_mesh = Mesh(devs, ("rows",))
    run(with_mesh, "2-shard mesh")
    print("FOLD_DIFFERENTIAL_OK", flush=True)


if __name__ == "__main__":
    main()

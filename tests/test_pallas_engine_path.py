"""Integration: the SharedDB engine running with the PALLAS kernel path
(interpret mode on CPU) produces identical results to the jnp ref path —
the full-stack proof that the TPU kernels are drop-in."""
import os

import numpy as np
import pytest


@pytest.fixture()
def pallas_env(monkeypatch):
    monkeypatch.setenv("REPRO_KERNELS", "pallas")
    yield
    # env restored by monkeypatch


def test_engine_cycle_matches_ref_path_under_pallas(pallas_env):
    from repro.core.executor import SharedDBEngine
    from repro.workloads import tpcw

    rng = np.random.default_rng(5)
    # tiny scale: interpret-mode Pallas executes the kernel body in Python
    plan = tpcw.build_tpcw_plan(128, 256)
    data = tpcw.generate_data(rng, 128, 256)

    eng_pallas = SharedDBEngine(plan, tpcw.DEFAULT_UPDATE_SLOTS, data,
                                jit=False)
    t1 = eng_pallas.submit("get_book", {0: (5, 5)})
    t2 = eng_pallas.submit("search_subject", {0: (3, 3)})
    t3 = eng_pallas.submit("best_sellers", {0: (0, 2**31 - 1), 1: (2, 2)})
    eng_pallas.run_cycle()

    os.environ["REPRO_KERNELS"] = "ref"
    eng_ref = SharedDBEngine(plan, tpcw.DEFAULT_UPDATE_SLOTS, data,
                             jit=False)
    r1 = eng_ref.submit("get_book", {0: (5, 5)})
    r2 = eng_ref.submit("search_subject", {0: (3, 3)})
    r3 = eng_ref.submit("best_sellers", {0: (0, 2**31 - 1), 1: (2, 2)})
    eng_ref.run_cycle()

    for a, b in ((t1, r1), (t2, r2)):
        assert (np.asarray(a.result["rows"])
                == np.asarray(b.result["rows"])).all()
    np.testing.assert_allclose(np.asarray(t3.result["scores"]),
                               np.asarray(r3.result["scores"]), rtol=1e-5)

"""Pipelined dispatch/collect executor: equivalence with the synchronous
path, backpressure, latency accounting, snapshot semantics, and the
shared-vs-query-at-a-time correctness property (deterministic version —
the hypothesis sweep lives in test_engine.py)."""
import numpy as np
import pytest

from repro.core.baseline import QueryAtATimeEngine
from repro.core.executor import SharedDBEngine
from repro.workloads import tpcw

SCALE_I, SCALE_C = 400, 1200


@pytest.fixture(scope="module")
def world():
    rng = np.random.default_rng(3)
    plan = tpcw.build_tpcw_plan(SCALE_I, SCALE_C)
    data = tpcw.generate_data(rng, SCALE_I, SCALE_C)
    shared = SharedDBEngine(plan, tpcw.DEFAULT_UPDATE_SLOTS, data)
    baseline = QueryAtATimeEngine(plan, data)
    gen = tpcw.WorkloadGenerator(rng, SCALE_I, SCALE_C)
    return plan, shared, baseline, gen


def _compare(t, r2):
    if "rows" in t.result:
        a = set(int(x) for x in np.asarray(t.result["rows"]) if x >= 0)
        b = set(int(x) for x in r2["rows"] if x >= 0)
        assert a == b, (t.template, t.params, sorted(a)[:5], sorted(b)[:5])
    else:
        np.testing.assert_allclose(np.sort(np.asarray(t.result["scores"])),
                                   np.sort(np.asarray(r2["scores"])),
                                   rtol=1e-6)


def test_pipelined_shared_equals_query_at_a_time(world):
    """Paper Fig. 3 correctness through the PIPELINED path: the shared
    plan with overlapped dispatch/collect == per-query plans."""
    plan, shared, baseline, gen = world
    inters = gen.sample_mix("shopping", 40)
    for it in inters:  # stable snapshot: updates first
        for u in it.updates:
            shared.submit_update(*u)
            baseline.apply_update(*u)
    shared.run_until_drained(pipelined=True)
    tickets = []
    for it in inters:
        for q in it.queries:
            tickets.append(shared.submit(*q))
    shared.run_until_drained(pipelined=True)
    assert not shared.in_flight()
    assert all(t.result is not None for t in tickets)
    for t in tickets:
        _compare(t, baseline.execute(t.template, t.params).result)


def test_dispatch_collect_equals_run_cycle(world):
    """Explicit dispatch()/collect() routes the same results as the
    synchronous run_cycle() wrapper."""
    plan, shared, _, gen = world
    item = 13
    t_sync = shared.submit("get_related", {0: (item, item)})
    shared.run_cycle()
    t_split = shared.submit("get_related", {0: (item, item)})
    shared.dispatch()
    assert shared.in_flight() == 1
    assert t_split.done_time is None       # not routed until collect
    out = shared.collect()
    assert t_split in out["get_related"]
    assert t_split.done_time is not None
    assert (np.asarray(t_sync.result["rows"])
            == np.asarray(t_split.result["rows"])).all()


def test_pipeline_backpressure_bounds_inflight(world):
    """At most pipeline_depth cycles outstanding; every admitted query is
    still routed exactly once."""
    plan, shared, _, gen = world
    cap = plan.caps["admin_item"]
    tickets = [shared.submit("admin_item", {0: (i % 64, i % 64)})
               for i in range(cap * 4)]       # 4 cycles worth of backlog
    n_dispatch = 0
    while shared.pending():
        shared.dispatch()
        n_dispatch += 1
        assert shared.in_flight() <= shared.pipeline_depth
    while shared.in_flight():
        shared.collect()
    assert n_dispatch == 4
    assert all(t.result is not None for t in tickets)


def test_backpressure_spill_surfaces_in_collect_returns(world):
    """A cycle collected internally by dispatch() backpressure must still
    appear in a collect() return — every ticket exactly once."""
    plan, shared, _, gen = world
    cap = plan.caps["admin_item"]
    tickets = [shared.submit("admin_item", {0: (i % 64, i % 64)})
               for i in range(cap * 3)]      # 3 cycles; depth is 2
    for _ in range(3):
        shared.dispatch()                    # 3rd dispatch spills cycle 1
    seen = []
    while shared.in_flight() or shared._spilled:
        seen.extend(shared.collect().get("admin_item", []))
    assert sorted(t.id for t in seen) == sorted(t.id for t in tickets)


def test_pipelined_latency_is_two_cycles_worst_case(world):
    """A query admitted at dispatch k completes at collect k — queue wait
    plus execution, never more (paper §3.5)."""
    plan, shared, _, gen = world
    before = shared.cycles_run
    t = shared.submit("get_book", {0: (2, 2)})
    shared.dispatch()
    shared.collect()
    assert t.result is not None
    assert shared.cycles_run == before + 1


def test_snapshot_isolation_and_arrival_order_pipelined(world):
    """Updates admitted with cycle k are visible to cycle-k queries, and
    apply in arrival order, under the pipelined admission path."""
    plan, shared, _, gen = world
    item = 42
    t0 = shared.submit("admin_item", {0: (item, item)})
    shared.run_cycle()
    old_cost = int(shared.materialize(
        "item", t0.result["rows"][:1])["i_cost"][0])
    shared.submit_update("item", "update",
                         {"key": item, "col": "i_cost",
                          "val": old_cost + 111})
    shared.submit_update("item", "update",
                         {"key": item, "col": "i_cost",
                          "val": old_cost + 222})
    t1 = shared.submit("admin_item", {0: (item, item)})
    shared.dispatch()       # update + query admitted to the same beat
    shared.collect()
    row1 = shared.materialize("item", t1.result["rows"][:1])
    assert int(row1["i_cost"][0]) == old_cost + 222  # last writer wins


def test_staging_buffers_are_reused_not_reallocated(world):
    plan, shared, _, gen = world
    bufs = [id(a) for b in shared._staging for a in (b.params, b.active)]
    shared.submit("get_book", {0: (1, 1)})
    shared.run_cycle()
    shared.submit("get_book", {0: (2, 2)})
    shared.run_cycle()
    after = [id(a) for b in shared._staging for a in (b.params, b.active)]
    assert bufs == after
    # packed admission: ONE contiguous params buffer + ONE active vector
    # covering every template's slot range
    for b in shared._staging:
        assert b.params.shape == (plan.qcap, plan.n_params_max, 2)
        assert b.active.shape == (plan.qcap,)


def test_run_until_drained_bounds_cycles_collected_and_times_them(world):
    """max_cycles bounds COLLECTED cycles; every entry carries its wall
    time; no admitted work is stranded in flight when the bound trips."""
    plan, shared, _, gen = world
    cap = plan.caps["admin_item"]
    for i in range(cap * 4):                  # 4 cycles worth of backlog
        shared.submit("admin_item", {0: (i % 64, i % 64)})
    before = shared.cycles_run
    done = shared.run_until_drained(max_cycles=2, pipelined=True)
    assert len(done) == 2
    assert shared.cycles_run == before + 2
    assert not shared.in_flight()             # nothing stranded
    assert shared.pending() == cap * 2        # the rest stayed queued
    assert all(d.wall_s >= 0.0 for d in done)
    routed = sum(len(ts) for d in done for ts in d.tickets.values())
    assert routed == cap * 2
    # the remainder drains with per-cycle accounting intact
    rest = shared.run_until_drained(pipelined=True)
    assert sum(len(ts) for d in rest for ts in d.tickets.values()) \
        == cap * 2


def test_stale_staging_state_does_not_leak_between_cycles(world):
    """A template active in cycle k must not ghost-execute in cycle k+1
    out of the reused staging buffers."""
    plan, shared, _, gen = world
    t0 = shared.submit("search_subject", {0: (3, 3)})
    shared.run_cycle()
    n0 = (np.asarray(t0.result["rows"]) >= 0).sum()
    assert n0 > 0
    # next cycle: a different template only; search_subject inactive
    t1 = shared.submit("get_password", {0: (5, 5)})
    out = shared.run_cycle()
    assert out["search_subject"] == []
    assert (np.asarray(t1.result["rows"]) >= 0).sum() == 1


def test_baseline_dispatch_collect_matches_execute(world):
    plan, _, baseline, gen = world
    items = [("get_book", {0: (7, 7)}), ("search_subject", {0: (1, 1)}),
             ("get_customer", {0: (9, 9)})]
    sync = [baseline.execute(n, p) for n, p in items]
    pending = [baseline.dispatch(n, p) for n, p in items]
    split = [baseline.collect(t) for t in pending]
    for a, b in zip(sync, split):
        assert (np.asarray(a.result["rows"])
                == np.asarray(b.result["rows"])).all()


def test_cycle_server_dispatch_collect_protocol():
    from repro.configs import smoke_config
    from repro.serving import CycleServer
    cfg = smoke_config("stablelm-1.6b")
    srv = CycleServer(cfg, capacity=3, max_seq=32, prefill_len=8,
                      prefill_budget=2)
    rng = np.random.default_rng(0)
    reqs = [srv.submit(rng.integers(1, cfg.vocab, 6).tolist(),
                       max_new_tokens=4) for _ in range(6)]
    # explicit split heartbeats drive the server to completion
    guard = 0
    while (srv.pending() or srv.active()) and guard < 100:
        srv.dispatch()
        srv.collect()
        guard += 1
    assert all(len(r.output) == 4 for r in reqs)
    assert srv.cycles == guard
    # protocol misuse is explicit, not a crash
    assert srv.collect() == []               # nothing in flight: no-op
    srv.submit(rng.integers(1, cfg.vocab, 6).tolist(), max_new_tokens=2)
    srv.dispatch()
    with pytest.raises(RuntimeError):
        srv.dispatch()                       # double dispatch refused
    srv.collect()


def test_cycle_server_reports_per_heartbeat_admission_counts():
    """CycleResult-parity accounting on the serving path: every drained
    heartbeat records its admitted prefills and active slots, so
    benchmarks can attribute cycle time to load."""
    from repro.configs import smoke_config
    from repro.serving import CycleServer
    cfg = smoke_config("stablelm-1.6b")
    srv = CycleServer(cfg, capacity=3, max_seq=32, prefill_len=8,
                      prefill_budget=2)
    rng = np.random.default_rng(1)
    reqs = [srv.submit(rng.integers(1, cfg.vocab, 6).tolist(),
                       max_new_tokens=3) for _ in range(5)]
    srv.run_until_drained()
    assert all(len(r.output) == 3 for r in reqs)
    n = len(srv.last_drain_walls)
    assert len(srv.last_drain_admitted) == n
    assert len(srv.last_drain_active) == n
    assert sum(srv.last_drain_admitted) == len(reqs)
    assert srv.last_drain_admitted[0] == 2      # prefill budget caps it
    assert all(0 <= a <= 3 for a in srv.last_drain_active)
    assert max(srv.last_drain_active) == 3      # capacity reached

"""Property tests for the data-query model (packed query bitmasks)."""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import dataquery as dq

QCAPS = st.sampled_from([32, 64, 128, 256])


@given(qcap=QCAPS, data=st.data())
@settings(max_examples=30, deadline=None)
def test_pack_unpack_roundtrip(qcap, data):
    n = data.draw(st.integers(1, 40))
    bits = np.array(data.draw(st.lists(
        st.lists(st.booleans(), min_size=qcap, max_size=qcap),
        min_size=n, max_size=n)), bool)
    packed = dq.pack(jnp.asarray(bits))
    assert packed.shape == (n, qcap // 32)
    out = np.asarray(dq.unpack(packed, qcap))
    assert (out == bits).all()


@given(qcap=QCAPS, data=st.data())
@settings(max_examples=30, deadline=None)
def test_set_algebra_matches_python_sets(qcap, data):
    n = data.draw(st.integers(1, 16))
    sets_a = [set(data.draw(st.lists(st.integers(0, qcap - 1),
                                     max_size=10))) for _ in range(n)]
    sets_b = [set(data.draw(st.lists(st.integers(0, qcap - 1),
                                     max_size=10))) for _ in range(n)]

    def to_mask(sets):
        bits = np.zeros((n, qcap), bool)
        for i, s in enumerate(sets):
            for q in s:
                bits[i, q] = True
        return dq.pack(jnp.asarray(bits))

    ma, mb = to_mask(sets_a), to_mask(sets_b)
    uni = np.asarray(dq.unpack(dq.union(ma, mb), qcap))
    inter = np.asarray(dq.unpack(dq.intersect(ma, mb), qcap))
    for i in range(n):
        assert {q for q in range(qcap) if uni[i, q]} == sets_a[i] | sets_b[i]
        assert {q for q in range(qcap) if inter[i, q]} \
            == sets_a[i] & sets_b[i]
    # popcount == set cardinality of union
    pc = np.asarray(dq.popcount(dq.union(ma, mb)))
    for i in range(n):
        assert pc[i] == len(sets_a[i] | sets_b[i])
    any_q = np.asarray(dq.any_query(ma))
    for i in range(n):
        assert any_q[i] == (len(sets_a[i]) > 0)


@given(qcap=QCAPS, qid=st.integers(0, 255), data=st.data())
@settings(max_examples=30, deadline=None)
def test_select_query_membership(qcap, qid, data):
    qid = qid % qcap
    n = data.draw(st.integers(1, 16))
    bits = np.array(data.draw(st.lists(
        st.lists(st.booleans(), min_size=qcap, max_size=qcap),
        min_size=n, max_size=n)), bool)
    mask = dq.pack(jnp.asarray(bits))
    sel = np.asarray(dq.select_query(mask, qid))
    assert (sel == bits[:, qid]).all()

"""SharedDB engine: unit + integration + THE property test of the paper —
shared batched execution returns identical results to query-at-a-time."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import dataquery as dq, operators as ops, sla
from repro.core.baseline import QueryAtATimeEngine
from repro.core.executor import SharedDBEngine
from repro.workloads import tpcw

SCALE_I, SCALE_C = 400, 1200


@pytest.fixture(scope="module")
def world():
    rng = np.random.default_rng(3)
    plan = tpcw.build_tpcw_plan(SCALE_I, SCALE_C)
    data = tpcw.generate_data(rng, SCALE_I, SCALE_C)
    shared = SharedDBEngine(plan, tpcw.DEFAULT_UPDATE_SLOTS, data)
    baseline = QueryAtATimeEngine(plan, data)
    gen = tpcw.WorkloadGenerator(rng, SCALE_I, SCALE_C)
    return plan, shared, baseline, gen


def _compare(t, r2):
    if "rows" in t.result:
        a = set(int(x) for x in np.asarray(t.result["rows"]) if x >= 0)
        b = set(int(x) for x in r2["rows"] if x >= 0)
        assert a == b, (t.template, t.params, sorted(a)[:5], sorted(b)[:5])
    else:
        np.testing.assert_allclose(np.sort(np.asarray(t.result["scores"])),
                                   np.sort(np.asarray(r2["scores"])),
                                   rtol=1e-6)


def test_shared_equals_query_at_a_time(world):
    """Paper Fig. 3 correctness: ONE big shared plan == per-query plans."""
    plan, shared, baseline, gen = world
    inters = gen.sample_mix("shopping", 80)
    for it in inters:  # stable snapshot: updates first
        for u in it.updates:
            shared.submit_update(*u)
            baseline.apply_update(*u)
    shared.run_until_drained()
    tickets = []
    for it in inters:
        for q in it.queries:
            tickets.append(shared.submit(*q))
    shared.run_until_drained()
    assert all(t.result is not None for t in tickets)
    for t in tickets:
        _compare(t, baseline.execute(t.template, t.params).result)


@given(seed=st.integers(0, 10_000))
@settings(max_examples=8, deadline=None)
def test_property_shared_equals_qaat_random_workloads(world, seed):
    plan, shared, baseline, gen = world
    rng = np.random.default_rng(seed)
    mix = ["browsing", "shopping", "ordering"][seed % 3]
    inters = [gen.interaction(k) for k in
              rng.choice(list(tpcw.MIXES[mix]), 12)]
    for it in inters:
        for u in it.updates:
            shared.submit_update(*u)
            baseline.apply_update(*u)
    shared.run_until_drained()
    tickets = []
    for it in inters:
        for q in it.queries:
            tickets.append(shared.submit(*q))
    shared.run_until_drained()
    for t in tickets:
        _compare(t, baseline.execute(t.template, t.params).result)


def test_snapshot_isolation_within_cycle(world):
    """Updates admitted to cycle k are visible to cycle-k queries;
    updates queued after the cycle drain are not."""
    plan, shared, _, gen = world
    item = 42
    t0 = shared.submit("admin_item", {0: (item, item)})
    shared.run_cycle()
    row0 = shared.materialize("item", t0.result["rows"][:1])
    old_cost = int(row0["i_cost"][0])
    shared.submit_update("item", "update",
                         {"key": item, "col": "i_cost",
                          "val": old_cost + 111})
    t1 = shared.submit("admin_item", {0: (item, item)})
    shared.run_cycle()  # same cycle: update applied before queries
    row1 = shared.materialize("item", t1.result["rows"][:1])
    assert int(row1["i_cost"][0]) == old_cost + 111


def test_updates_apply_in_arrival_order(world):
    plan, shared, _, gen = world
    item = 77
    shared.submit_update("item", "update",
                         {"key": item, "col": "i_cost", "val": 1})
    shared.submit_update("item", "update",
                         {"key": item, "col": "i_cost", "val": 2})
    t = shared.submit("admin_item", {0: (item, item)})
    shared.run_cycle()
    row = shared.materialize("item", t.result["rows"][:1])
    assert int(row["i_cost"][0]) == 2  # last writer in arrival order wins


def test_insert_then_query_same_cycle(world):
    plan, shared, _, gen = world
    # id far outside the workload generator's reachable range so no other
    # test in this module can have created it
    new_c = plan.catalog.schemas["customer"].key_space - 9
    shared.submit_update("customer", "insert",
                         {"c_id": new_c, "c_uname": new_c,
                          "c_passwd": 1, "c_addr_id": 0, "c_discount": 3,
                          "c_since": 11111, "c_expiration": 13333})
    t = shared.submit("get_customer", {0: (new_c, new_c)})
    shared.run_cycle()
    rows = t.result["rows"]
    assert (rows >= 0).sum() == 1
    got = shared.materialize("customer", rows[:1])
    assert int(got["c_discount"][0]) == 3


def test_bounded_computation_same_plan_any_load(world):
    """The SLA core claim: per-cycle cost model is independent of the
    number of submitted queries."""
    plan, shared, _, gen = world
    c1 = sla.cycle_cost(plan)["total_flops"]
    for _ in range(50):
        shared.submit("get_book", {0: (1, 1)})
    shared.run_until_drained()
    c2 = sla.cycle_cost(plan)["total_flops"]
    assert c1 == c2
    p = sla.provision(plan, 3.0)
    assert p["chips_required"] >= 1
    assert p["cycle_budget_s"] == 1.5  # latency <= 2 cycles (paper §3.5)


def test_route_topn_respects_limits():
    mask = dq.pack(jnp.ones((10, 32), bool))
    rows = ops.route_topn(mask, jnp.full((32,), 3, jnp.int32), 8)
    assert (rows[0] >= 0).sum() == 3
    assert rows[0, :3].tolist() == [0, 1, 2]


def test_compress_union_reports_overflow():
    mask = dq.pack(jnp.ones((100, 32), bool))
    rows, cmask, n_want = ops.compress_union(mask, 16)
    assert int(n_want) == 100
    assert rows.shape == (16,)
    assert (np.asarray(rows) >= 0).all()


def test_shared_join_fk_null_and_missing_keys():
    pk_index = jnp.asarray([0, -1, 1], jnp.int32)      # key 1 absent
    right_mask = jnp.asarray([[3], [5]], jnp.uint32)
    fk = jnp.asarray([0, 1, 2, -5, 99], jnp.int32)     # -5/99 out of range
    left_mask = jnp.full((5, 1), 0xFF, jnp.uint32)
    rid, m = ops.shared_join_fk(fk, left_mask, pk_index, right_mask)
    assert rid.tolist() == [0, -1, 1, -1, -1]
    assert m[:, 0].tolist() == [3, 0, 5, 0, 0]

"""planlint acceptance: the static verifier proves the heartbeat
invariants on shipped configs and catches every seeded mutation.

Three legs:

  * clean-config proofs — the analyzer (the same passes the CLI and the
    always-on construction gate run) reports ZERO errors on a real
    sharded config, generalizing tests/test_sharding_locality.py's
    hand proofs;
  * seeded-mutation corpus (tests/lint_corpus/) — each planted bug
    class is caught with its expected rule id;
  * fold admission — ``extend_plan`` / ``begin_fold`` reject through
    the planlint passes, with the rule id in the ``FoldError`` /
    ``RuntimeError`` message.
"""
import dataclasses
import importlib

import numpy as np
import pytest

import jax

from lint_corpus import CORPUS
from repro.analysis_static.diagnostics import PlanLintError, errors_in
from repro.analysis_static.registry import RULES
from repro.core import backends, folding
from repro.core.executor import SharedDBEngine, _measure_key_stats
from repro.core.lowering import build_cycle, build_delta_cycle, lower_plan
from repro.core.plan import Pred, QueryTemplate
from repro.core.storage import empty_update_batch
from repro.workloads import tpcw

SCALE_I, SCALE_C = 64, 128


def _struct(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(np.shape(x), np.asarray(x).dtype),
        tree)


@pytest.fixture(scope="module")
def ctx():
    """The corpus context: one index-less plan + lazy traced setups."""
    plan = tpcw.build_tpcw_plan(SCALE_I, SCALE_C, dense_pk_index=False)
    data = tpcw.generate_data(np.random.default_rng(0), SCALE_I, SCALE_C)
    key_stats = _measure_key_stats(plan, data)
    lowered = lower_plan(plan, key_stats=key_stats)
    slots = tpcw.DEFAULT_UPDATE_SLOTS
    cache = {}

    def _io():
        queries = {"params": jax.ShapeDtypeStruct(
                       (plan.qcap, plan.n_params_max, 2), np.int32),
                   "active": jax.ShapeDtypeStruct((plan.qcap,), bool)}
        updates = _struct({t: empty_update_batch(s, slots, xp=np)
                           for t, s in plan.catalog.schemas.items()})
        return queries, updates

    def traced():
        """Unsharded jnp cycles + abstract args (shape-eval only)."""
        if "traced" not in cache:
            be = backends.get_backend("jnp")
            full = build_cycle(lowered, be)
            delta = build_delta_cycle(lowered, be)
            delta_j = build_delta_cycle(lowered, be, delta_joins=True)
            state = _struct(plan.catalog.init_state(data))
            queries, updates = _io()
            s2, carry, res = jax.eval_shape(full, state, queries,
                                            updates)
            qd = dict(queries,
                      changed=jax.ShapeDtypeStruct((plan.qcap,), bool))
            cache["traced"] = {
                "full": full, "delta": delta, "delta_j": delta_j,
                "args_full": (state, queries, updates),
                "args_delta": (s2, carry, qd, updates),
                "args_dj": (s2, carry, res["_join_rids"], qd, updates)}
        return cache["traced"]

    def sharded():
        """2-shard jnp delta cycle + abstract args."""
        if jax.device_count() < 2:
            pytest.skip("needs 2 CPU host devices")
        if "sharded" not in cache:
            from repro.core.sharding import (build_shard_spec,
                                             build_sharded_cycle,
                                             build_sharded_delta_cycle,
                                             init_sharded_state,
                                             make_row_mesh)
            be = backends.get_backend("jnp")
            spec = build_shard_spec(plan, make_row_mesh(2))
            full = build_sharded_cycle(lowered, be, spec)
            delta = build_sharded_delta_cycle(lowered, be, spec)
            state = _struct(init_sharded_state(spec, data))
            queries, updates = _io()
            s2, carry, _ = jax.eval_shape(full, state, queries, updates)
            qd = dict(queries,
                      changed=jax.ShapeDtypeStruct((plan.qcap,), bool))
            cache["sharded"] = {
                "spec": spec, "full": full, "delta": delta,
                "args_delta": (s2, carry, qd, updates)}
        return cache["sharded"]

    def geometry():
        from repro.analysis_static.kernel_passes import \
            geometry_from_lowered
        return geometry_from_lowered(lowered)

    return {"plan": plan, "data": data, "key_stats": key_stats,
            "lowered": lowered, "slots": slots, "traced": traced,
            "sharded": sharded, "geometry": geometry}


# ---------------------------------------------------------------------------
# Clean-config proofs
# ---------------------------------------------------------------------------


def test_construction_passes_clean_on_shipped_plans(ctx):
    from repro.analysis_static.ir_passes import run_construction_passes
    assert run_construction_passes(ctx["lowered"],
                                   ctx["key_stats"]) is not None
    dense = lower_plan(tpcw.build_tpcw_plan(SCALE_I, SCALE_C))
    assert run_construction_passes(dense) is not None


def test_construction_passes_reject_corrupt_layout(ctx):
    """The always-on gate: a lowered plan whose admission layout is
    corrupt raises PlanLintError with the rule id, before anything
    compiles against it."""
    plan = ctx["plan"]
    names = sorted(plan.offsets, key=plan.offsets.get)
    offsets = dict(plan.offsets)
    offsets[names[1]] = plan.offsets[names[0]]
    bad = dataclasses.replace(ctx["lowered"],
                              plan=dataclasses.replace(plan,
                                                       offsets=offsets))
    from repro.analysis_static.ir_passes import run_construction_passes
    with pytest.raises(PlanLintError, match="ir-slot-overlap"):
        run_construction_passes(bad, ctx["key_stats"])


def test_kernel_passes_clean_on_shipped_geometry(ctx):
    from repro.analysis_static.kernel_passes import run_kernel_passes
    assert errors_in(run_kernel_passes(ctx["lowered"])) == []


def test_analyzer_proves_sharded_config_clean():
    """One full analyzer cell (the CI planlint job sweeps both backends
    at shards {1,2,4}): zero collectives on both delta flavours, reseed
    all_gathers one per mirrored stage, no full-window compare on the
    delta path, donation contract clean."""
    if jax.device_count() < 2:
        pytest.skip("needs 2 CPU host devices")
    from repro.analysis_static.lint import lint_config
    findings = lint_config("tpcw-nopk", "jnp", 2, SCALE_I, SCALE_C)
    assert errors_in(findings) == [], errors_in(findings)


# ---------------------------------------------------------------------------
# Seeded-mutation corpus
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", CORPUS)
def test_corpus_mutation_caught(ctx, name):
    mod = importlib.import_module(f"lint_corpus.{name}")
    assert mod.EXPECT in RULES, f"{name}: EXPECT names unknown rule"
    errs = errors_in(mod.findings(ctx))
    assert errs, f"{name}: mutation produced no error findings"
    got = {f.rule for f in errs}
    assert mod.EXPECT in got, (name, mod.EXPECT, got)


# ---------------------------------------------------------------------------
# Fold admission through planlint
# ---------------------------------------------------------------------------


def _new_template(name="zz_lint_new"):
    return QueryTemplate(name, "item",
                         preds=(Pred("item", "i_id"),), limit=1)


def test_fold_errors_carry_rule_ids(ctx):
    plan = ctx["plan"]
    dup = next(iter(plan.templates.values()))
    with pytest.raises(folding.FoldError,
                       match="fold-duplicate-template"):
        folding.extend_plan(plan, [dup], {dup.name: 4})
    new = _new_template()
    with pytest.raises(folding.FoldError, match="fold-zero-cap"):
        folding.extend_plan(plan, [new], {new.name: 0})
    alien = QueryTemplate("zz_alien", "no_such_table",
                          preds=(Pred("no_such_table", "x"),), limit=1)
    with pytest.raises(folding.FoldError, match="fold-alien-table"):
        folding.extend_plan(plan, [alien], {"zz_alien": 4})
    with pytest.raises(folding.FoldError,
                       match="fold-duplicate-in-batch"):
        folding.extend_plan(plan, [new, _new_template()], {new.name: 4})


def test_begin_fold_in_flight_rule_id(ctx):
    eng = SharedDBEngine(ctx["plan"], ctx["slots"], ctx["data"],
                         jit=False)
    eng.begin_fold([_new_template("zz_fold_a")], {"zz_fold_a": 4},
                   background=True)
    with pytest.raises(RuntimeError, match="planlint:fold-in-flight"):
        eng.begin_fold([_new_template("zz_fold_b")], {"zz_fold_b": 4})

"""Cycle-time SLA regression gate (ROADMAP item).

``python -m benchmarks.run --smoke`` writes ``BENCH_PR3.json`` (delta vs
full-rescan scan curve, steady-state heartbeat wall time, critical-path
record), ``BENCH_PR4.json`` (delta vs full JOIN probe curve, index-less
steady-state heartbeat) and ``BENCH_PR5.json`` (the sharded reseed-beat
record: the per-device reseed scan at full vs per-shard row height,
plus the engine-level beats on the forced-host-device mesh and the
sharded steady-state delta fractions) and ``BENCH_PR6.json`` (the
fused delta-heartbeat record: fused vs chained steady-state beat with
launch counts, plus the end-to-end sharded/single delta-beat ratio)
and ``BENCH_PR8.json`` (the plan-folding serving record: beats served
during a background fold vs the steady state, the migration-beat wall
and the post-fold fused steady beat); this suite fails when
any record regresses past the STORED thresholds below instead of
silently drifting.  CI regenerates the records right before running the
tests (see .github/workflows/ci.yml); locally the committed records
gate until you regenerate them.

A MISSING record file or record key is a HARD FAILURE, not a skip: the
records are committed, CI regenerates them before the suite, and a
bench that silently stopped emitting a row must fail the gate rather
than pass it vacuously.  (The only skip left is the measurement-backend
guard: the records are measured on the jnp backend, so other
REPRO_KERNELS legs would gate a stale record.)

The thresholds are deliberately looser than freshly measured numbers
(scan-phase speedup measures 3-6x, join-phase 10-20x, sharded reseed
~1.5-2x on a 2-core CI host, heartbeats tens of milliseconds) so the
gate trips on order-of-magnitude regressions — a delta path that
stopped engaging, a heartbeat that went quadratic, a reseed that
stopped sharding — not on shared-CPU noise.
"""
import json
import os

import pytest

_ROOT = os.path.join(os.path.dirname(__file__), os.pardir)
BENCH = os.path.join(_ROOT, "BENCH_PR3.json")
BENCH_PR4 = os.path.join(_ROOT, "BENCH_PR4.json")
BENCH_PR5 = os.path.join(_ROOT, "BENCH_PR5.json")
BENCH_PR6 = os.path.join(_ROOT, "BENCH_PR6.json")
BENCH_PR8 = os.path.join(_ROOT, "BENCH_PR8.json")

# stored thresholds — the gate
SMOKE_HEARTBEAT_BUDGET_US = 3_000_000   # absolute ceiling per heartbeat
MIN_DELTA_SCAN_SPEEDUP = 2.0            # at 4096 rows (measures 3-6x)
MAX_DELTA_VS_FULL_HEARTBEAT = 1.35      # steady state must not regress
MIN_DELTA_CYCLE_FRACTION = 0.8          # steady state must run deltas
MAX_PIPELINED_SYNC_RATIO = 2.0          # pipelining must not hurt
MIN_PARTITIONED_JOIN_SPEEDUP = 3.0      # PR-2 gain must not rot
MIN_DELTA_JOIN_SPEEDUP = 3.0            # at 4096 rows (measures 10-20x)
MIN_DELTA_JOIN_FRACTION = 0.8           # steady state must carry rids
MAX_DELTA_VS_FULL_JOIN_HEARTBEAT = 1.35  # carried rids must not regress
# PR-5: the reseed scan work ONE device pays after 4-way row sharding
# must keep beating the single-shard reseed scan (measures ~2x on a
# 2-core host whose single-device op already multi-threads; a real
# mesh converts the 4x work split into wall clock — the gate trips
# when the sharded lowering stops splitting the row ranges), and the
# sharded steady state must stay on the shard-local delta path.
MIN_SHARDED_RESEED_SPEEDUP = 1.3
MIN_SHARDED_DELTA_FRACTION = 0.8
# engine-level beats on FORCED host devices time-slice 2 cores, so they
# get a looser absolute ceiling than the single-device records
SHARDED_HEARTBEAT_BUDGET_US = 8_000_000
# PR-6: the fused delta mega-kernel.  A steady-state delta beat must
# stay ONE fused launch (exact — a second chained op means the fused
# path silently stopped engaging) and must not run slower than the
# chained PR-4/5 path it replaced (measures < 1.0x; 1.1 absorbs
# shared-CPU noise on an interleaved beat-for-beat measurement).  The
# END-TO-END sharded/single delta-beat ratio is gated too — not just
# the per-shard scan speedup: with the on-device cross-shard merge,
# collect() is a device-to-host copy, so the sharded beat may pay
# shard_map dispatch overhead (forced host devices time-slicing 2 CI
# cores) but must never fall off a cliff the way a host-side key-merge
# regression would show (measures ~1.5-2.5x on 2 cores).
MAX_FUSED_VS_CHAINED_DELTA = 1.25
MAX_SHARDED_DELTA_RATIO = 4.0
# the BEAT-level fused/chained wall ratio is a cliff guard only: at the
# acceptance geometry both beats are dominated by the full-width
# group-by/sort post stages that run identically on both sides
# (~290ms of a ~295ms beat), so the ratio sits at ~1.0 with per-beat
# noise of +-10% on shared CI cores — 1.25 catches a structural
# regression (e.g. the fused path re-materializing full-width work)
# without flaking on host noise.  The STRICTLY-FASTER claim is gated
# on the DELTA-PHASE carry chain (benchmarks/fused_bench.delta_phase),
# which isolates the fused work from the shared post stages: the fused
# op must beat the chained op sequence it replaced (measures ~1.3-1.4x
# on 2 CI cores — cond-skipped panes/rescans/probes for every
# untouched stage); 1.05 leaves noise margin while still failing a
# fusion regression.
MIN_DELTA_PHASE_SPEEDUP = 1.05
# PR-8: dynamic plan folding must not stop — or visibly stall — the
# world.  Beats served WHILE the background fold builds + jit-warms the
# extended plan are compared (median vs median, same trickle shape,
# same engine) against the pre-fold steady state; 1.5x absorbs the fold
# thread stealing compile cycles on a 2-core host while still failing a
# fold that serializes against serving (a blocking build shows up as a
# multi-second beat, orders of magnitude past this gate).  The swap
# itself must leave the engine on the fused single-launch path
# (launch counts are asserted inside benchmarks/fold_bench.py; the
# post-fold steady beat is gated against the absolute ceiling here).
MAX_FOLD_SERVING_RATIO = 1.5


def _load(path, name):
    if os.environ.get("REPRO_KERNELS", "jnp") not in ("jnp", "ref",
                                                      "auto", ""):
        pytest.skip("SLA record is measured on the jnp backend — other "
                    "kernel legs would gate a stale record")
    if not os.path.exists(path):
        pytest.fail(f"{name} missing — the SLA gate has nothing to "
                    "gate.  The record is committed and CI regenerates "
                    "it; run `python -m benchmarks.run --smoke` to "
                    "restore it.")
    with open(path) as f:
        return json.load(f)


def _require(record, name, *path):
    """Walk ``record[path[0]][path[1]]...``; a missing key is a HARD
    failure (a bench that stopped emitting a row must not pass)."""
    cur = record
    for i, key in enumerate(path):
        try:
            cur = cur[key]
        except (KeyError, IndexError, TypeError):
            pytest.fail(
                f"{name} is missing key {'.'.join(map(str, path[:i + 1]))!r}"
                f" — the benchmark stopped emitting this row; the gate "
                f"refuses to pass vacuously")
    return cur


@pytest.fixture(scope="module")
def record():
    return _load(BENCH, "BENCH_PR3.json")


@pytest.fixture(scope="module")
def record_pr4():
    return _load(BENCH_PR4, "BENCH_PR4.json")


@pytest.fixture(scope="module")
def record_pr5():
    return _load(BENCH_PR5, "BENCH_PR5.json")


@pytest.fixture(scope="module")
def record_pr6():
    return _load(BENCH_PR6, "BENCH_PR6.json")


@pytest.fixture(scope="module")
def record_pr8():
    return _load(BENCH_PR8, "BENCH_PR8.json")


def test_delta_scan_speedup_floor(record):
    """The incremental scan must keep beating the full rescan at the
    acceptance point (4096 rows, 13-template TPC-W window)."""
    curve = _require(record, "BENCH_PR3.json", "delta_scan", "curve")
    big = [c for c in curve if _require(c, "curve point", "rows") >= 4096]
    assert big, "curve lost its 4096-row point"
    assert _require(big[0], "curve point", "speedup") \
        >= MIN_DELTA_SCAN_SPEEDUP, big[0]


def test_steady_state_heartbeat_runs_delta_and_stays_flat(record):
    hb = _require(record, "BENCH_PR3.json", "delta_scan", "heartbeat")
    assert _require(hb, "heartbeat", "delta_cycle_fraction") \
        >= MIN_DELTA_CYCLE_FRACTION, hb
    assert hb["delta_heartbeat_us"] <= (MAX_DELTA_VS_FULL_HEARTBEAT
                                        * hb["full_heartbeat_us"]), hb
    assert hb["delta_heartbeat_us"] <= SMOKE_HEARTBEAT_BUDGET_US, hb
    assert hb["full_heartbeat_us"] <= SMOKE_HEARTBEAT_BUDGET_US, hb


def test_cycle_time_within_budget(record):
    cyc = _require(record, "BENCH_PR3.json", "cycle")
    assert _require(cyc, "cycle", "mean_cycle_us_sync") \
        <= SMOKE_HEARTBEAT_BUDGET_US, cyc
    assert cyc["mean_cycle_us_pipelined"] <= SMOKE_HEARTBEAT_BUDGET_US, cyc
    assert cyc["pipelined_sync_ratio"] <= MAX_PIPELINED_SYNC_RATIO, cyc


def test_partitioned_join_speedup_floor(record):
    curve = _require(record, "BENCH_PR3.json", "join_scaling")
    big = [c for c in curve if _require(c, "join point", "keys") >= 4096]
    assert big, "join curve lost its 4096-key point"
    assert _require(big[0], "join point", "speedup") \
        >= MIN_PARTITIONED_JOIN_SPEEDUP, big[0]


def test_delta_join_speedup_floor(record_pr4):
    """The carried-rid join phase must keep beating the full partitioned
    re-probe at the acceptance point (4096-row tables, TPC-W window)."""
    curve = _require(record_pr4, "BENCH_PR4.json", "delta_join", "curve")
    big = [c for c in curve if _require(c, "curve point", "rows") >= 4096]
    assert big, "delta-join curve lost its 4096-row point"
    assert _require(big[0], "curve point", "speedup") \
        >= MIN_DELTA_JOIN_SPEEDUP, big[0]


def test_steady_state_heartbeat_carries_join_rids(record_pr4):
    hb = _require(record_pr4, "BENCH_PR4.json", "delta_join",
                  "heartbeat")
    assert _require(hb, "heartbeat", "delta_join_fraction") \
        >= MIN_DELTA_JOIN_FRACTION, hb
    assert hb["delta_heartbeat_us"] <= (MAX_DELTA_VS_FULL_JOIN_HEARTBEAT
                                        * hb["full_heartbeat_us"]), hb
    assert hb["delta_heartbeat_us"] <= SMOKE_HEARTBEAT_BUDGET_US, hb
    assert hb["full_heartbeat_us"] <= SMOKE_HEARTBEAT_BUDGET_US, hb


def test_sharded_reseed_speedup_floor(record_pr5):
    """PR-5 acceptance: the reseed-beat scan work one device pays after
    4-way row sharding must keep beating the single-shard reseed scan
    at the real item-stage geometry — a regression here means the
    sharded lowering stopped scattering the bounded worst case across
    the row ranges."""
    rs = _require(record_pr5, "BENCH_PR5.json", "sharded_reseed")
    assert _require(rs, "sharded_reseed", "shards") >= 4, rs
    # layout sanity: the per-shard slice really is 1/S of the table
    assert _require(rs, "sharded_reseed", "rows_shard") * rs["shards"] \
        == _require(rs, "sharded_reseed", "rows_full"), rs
    assert _require(rs, "sharded_reseed", "speedup") \
        >= MIN_SHARDED_RESEED_SPEEDUP, rs


def test_sharded_steady_state_stays_shard_local_and_bounded(record_pr5):
    """The mesh must not knock the steady state off its fast path:
    delta beats on the sharded engine keep engaging (shard-local — no
    collectives, proven by tests/test_sharding_locality.py) and every
    engine-level beat stays under the forced-host-device ceiling."""
    e = _require(record_pr5, "BENCH_PR5.json", "sharded_engine")
    assert _require(e, "sharded_engine", "delta_cycle_fraction") \
        >= MIN_SHARDED_DELTA_FRACTION, e
    assert _require(e, "sharded_engine", "delta_join_fraction") \
        >= MIN_SHARDED_DELTA_FRACTION, e
    for key in ("single_reseed_us", "sharded_reseed_us",
                "delta_heartbeat_us"):
        assert _require(e, "sharded_engine", key) \
            <= SHARDED_HEARTBEAT_BUDGET_US, (key, e)


def test_fused_delta_beat_is_one_launch_and_beats_chained(record_pr6):
    """PR-6 acceptance: the steady-state delta beat is a SINGLE fused
    backend launch (plus group-by post stages only) and its wall time
    does not regress past the chained PR-4/5 path it replaced."""
    fu = _require(record_pr6, "BENCH_PR6.json", "fused")
    ops = _require(fu, "fused record", "fused", "backend_ops_per_beat")
    assert ops.get("fused_delta") == 1, ops
    for op in ("scan", "scan_delta", "join_delta", "join_partitioned",
               "join_block"):
        assert ops.get(op, 0) == 0, (op, ops)
    assert _require(fu, "fused record", "fused_vs_chained") \
        <= MAX_FUSED_VS_CHAINED_DELTA, fu
    assert _require(fu, "fused record", "fused", "wall_us") \
        <= SMOKE_HEARTBEAT_BUDGET_US, fu
    # the fused delta work itself must be strictly faster than the
    # chained op sequence (compiled carry chain, low-noise)
    assert _require(fu, "fused record", "delta_phase", "speedup") \
        >= MIN_DELTA_PHASE_SPEEDUP, fu


def test_sharded_delta_beat_ratio_bounded_end_to_end(record_pr6):
    """The END-TO-END sharded/single delta-beat ratio (not just the
    per-shard scan speedup): collect() performing no host-side
    key-merge is what keeps this bounded — a host-merge regression
    shows up as the sharded beat diverging from the single-device one
    far past shard_map dispatch overhead."""
    sd = _require(record_pr6, "BENCH_PR6.json", "sharded_delta")
    assert _require(sd, "sharded_delta", "ratio") \
        <= MAX_SHARDED_DELTA_RATIO, sd
    assert _require(sd, "sharded_delta", "sharded_delta_heartbeat_us") \
        <= SHARDED_HEARTBEAT_BUDGET_US, sd


def test_fold_keeps_serving_within_ratio(record_pr8):
    """PR-8 acceptance: beats served during a background fold stay
    within MAX_FOLD_SERVING_RATIO of the steady-state beat wall, the
    engine kept serving while the extended plan built (at least one
    beat landed inside the build window), and the post-fold steady beat
    is back under the absolute ceiling on the fused single launch."""
    fo = _require(record_pr8, "BENCH_PR8.json", "fold")
    assert _require(fo, "fold", "fold_serving_ratio") \
        <= MAX_FOLD_SERVING_RATIO, fo
    assert _require(fo, "fold", "beats_during_build") >= 1, fo
    for key in ("steady_us", "during_fold_us", "post_steady_us",
                "migration_beat_us"):
        assert _require(fo, "fold", key) <= SMOKE_HEARTBEAT_BUDGET_US, \
            (key, fo)
    # the swap must not knock the engine off the single fused launch:
    # launch counts are asserted while measuring (fold_bench), and the
    # recorded totals must stay equal across the fold (fused_delta +
    # the same group-by post stages)
    assert _require(fo, "fold", "post_fold_launches") \
        >= _require(fo, "fold", "pre_fold_launches"), fo


def test_fused_beat_roofline_footprint_recorded(record_pr6):
    """The analytic fused-beat footprint must keep being emitted (the
    roofline wiring is part of the record, not a side channel)."""
    rf = _require(record_pr6, "BENCH_PR6.json", "fused", "roofline")
    assert _require(rf, "roofline", "bytes") > 0, rf
    assert _require(rf, "roofline", "int_ops") > 0, rf
    assert _require(rf, "roofline", "dominant") in ("compute", "memory",
                                                    "collective"), rf

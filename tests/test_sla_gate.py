"""Cycle-time SLA regression gate (ROADMAP item).

``python -m benchmarks.run --smoke`` writes ``BENCH_PR3.json`` (delta vs
full-rescan scan curve, steady-state heartbeat wall time, critical-path
record) and ``BENCH_PR4.json`` (delta vs full JOIN probe curve,
index-less steady-state heartbeat); this suite fails when either record
regresses past the STORED thresholds below instead of silently
drifting.  CI regenerates the records right before running the tests
(see .github/workflows/ci.yml); locally the committed records gate
until you regenerate them.

The thresholds are deliberately looser than freshly measured numbers
(scan-phase speedup measures 3-6x, join-phase 10-20x, heartbeats tens
of milliseconds) so the gate trips on order-of-magnitude regressions —
a delta path that stopped engaging, a heartbeat that went quadratic —
not on shared-CPU noise.
"""
import json
import os

import pytest

BENCH = os.path.join(os.path.dirname(__file__), os.pardir,
                     "BENCH_PR3.json")
BENCH_PR4 = os.path.join(os.path.dirname(__file__), os.pardir,
                         "BENCH_PR4.json")

# stored thresholds — the gate
SMOKE_HEARTBEAT_BUDGET_US = 3_000_000   # absolute ceiling per heartbeat
MIN_DELTA_SCAN_SPEEDUP = 2.0            # at 4096 rows (measures 3-6x)
MAX_DELTA_VS_FULL_HEARTBEAT = 1.35      # steady state must not regress
MIN_DELTA_CYCLE_FRACTION = 0.8          # steady state must run deltas
MAX_PIPELINED_SYNC_RATIO = 2.0          # pipelining must not hurt
MIN_PARTITIONED_JOIN_SPEEDUP = 3.0      # PR-2 gain must not rot
MIN_DELTA_JOIN_SPEEDUP = 3.0            # at 4096 rows (measures 10-20x)
MIN_DELTA_JOIN_FRACTION = 0.8           # steady state must carry rids
MAX_DELTA_VS_FULL_JOIN_HEARTBEAT = 1.35  # carried rids must not regress


def _load(path, name):
    if os.environ.get("REPRO_KERNELS", "jnp") not in ("jnp", "ref",
                                                      "auto", ""):
        pytest.skip("SLA record is measured on the jnp backend — other "
                    "kernel legs would gate a stale record")
    if not os.path.exists(path):
        pytest.skip(f"{name} missing — run "
                    "`python -m benchmarks.run --smoke` first")
    with open(path) as f:
        return json.load(f)


@pytest.fixture(scope="module")
def record():
    return _load(BENCH, "BENCH_PR3.json")


@pytest.fixture(scope="module")
def record_pr4():
    return _load(BENCH_PR4, "BENCH_PR4.json")


def test_delta_scan_speedup_floor(record):
    """The incremental scan must keep beating the full rescan at the
    acceptance point (4096 rows, 13-template TPC-W window)."""
    big = [c for c in record["delta_scan"]["curve"] if c["rows"] >= 4096]
    assert big, "curve lost its 4096-row point"
    assert big[0]["speedup"] >= MIN_DELTA_SCAN_SPEEDUP, big[0]


def test_steady_state_heartbeat_runs_delta_and_stays_flat(record):
    hb = record["delta_scan"]["heartbeat"]
    assert hb["delta_cycle_fraction"] >= MIN_DELTA_CYCLE_FRACTION, hb
    assert hb["delta_heartbeat_us"] <= (MAX_DELTA_VS_FULL_HEARTBEAT
                                        * hb["full_heartbeat_us"]), hb
    assert hb["delta_heartbeat_us"] <= SMOKE_HEARTBEAT_BUDGET_US, hb
    assert hb["full_heartbeat_us"] <= SMOKE_HEARTBEAT_BUDGET_US, hb


def test_cycle_time_within_budget(record):
    cyc = record["cycle"]
    assert cyc["mean_cycle_us_sync"] <= SMOKE_HEARTBEAT_BUDGET_US, cyc
    assert cyc["mean_cycle_us_pipelined"] <= SMOKE_HEARTBEAT_BUDGET_US, cyc
    assert cyc["pipelined_sync_ratio"] <= MAX_PIPELINED_SYNC_RATIO, cyc


def test_partitioned_join_speedup_floor(record):
    big = [c for c in record["join_scaling"] if c["keys"] >= 4096]
    assert big, "join curve lost its 4096-key point"
    assert big[0]["speedup"] >= MIN_PARTITIONED_JOIN_SPEEDUP, big[0]


def test_delta_join_speedup_floor(record_pr4):
    """The carried-rid join phase must keep beating the full partitioned
    re-probe at the acceptance point (4096-row tables, TPC-W window)."""
    big = [c for c in record_pr4["delta_join"]["curve"]
           if c["rows"] >= 4096]
    assert big, "delta-join curve lost its 4096-row point"
    assert big[0]["speedup"] >= MIN_DELTA_JOIN_SPEEDUP, big[0]


def test_steady_state_heartbeat_carries_join_rids(record_pr4):
    hb = record_pr4["delta_join"]["heartbeat"]
    assert hb["delta_join_fraction"] >= MIN_DELTA_JOIN_FRACTION, hb
    assert hb["delta_heartbeat_us"] <= (MAX_DELTA_VS_FULL_JOIN_HEARTBEAT
                                        * hb["full_heartbeat_us"]), hb
    assert hb["delta_heartbeat_us"] <= SMOKE_HEARTBEAT_BUDGET_US, hb
    assert hb["full_heartbeat_us"] <= SMOKE_HEARTBEAT_BUDGET_US, hb

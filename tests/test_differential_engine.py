"""Differential stateful harness: randomized interleaved insert / update
/ delete / select streams over many heartbeats, asserting SharedDBEngine
— on BOTH operator backends, with incremental scans on — stays
ticket-for-ticket equal to the QueryAtATimeEngine oracle.  This is the
regression net under the delta scan path: every heartbeat after the
first carries scan words forward, so any stale-carry bug surfaces as a
ticket mismatch here.

The hypothesis ``RuleBasedStateMachine`` explores arbitrary
interleavings when hypothesis is installed; a deterministic seeded
stream over the same world always runs.
"""
import numpy as np
import pytest

from repro.core.baseline import QueryAtATimeEngine
from repro.core.executor import SharedDBEngine
from repro.workloads import tpcw

try:
    from hypothesis import settings, strategies as st
    from hypothesis.stateful import RuleBasedStateMachine, rule
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

SCALE_I, SCALE_C = 64, 128
BACKENDS = ("jnp", "pallas")
INT_MAX = tpcw.INT_MAX


def _compare(backend, ticket, want):
    if "rows" in ticket.result:
        a = set(int(x) for x in np.asarray(ticket.result["rows"]) if x >= 0)
        b = set(int(x) for x in want["rows"] if x >= 0)
        assert a == b, (backend, ticket.template, ticket.params,
                        sorted(a)[:5], sorted(b)[:5])
    else:
        np.testing.assert_allclose(
            np.sort(np.asarray(ticket.result["scores"])),
            np.sort(np.asarray(want["scores"])), rtol=1e-6,
            err_msg=f"{backend}:{ticket.template}")


class _World:
    """Two shared engines (one per backend) + the query-at-a-time oracle,
    driven by interleaved updates/selects and compared every heartbeat.

    Updates queue on the shared engines and mirror into the oracle at
    heartbeat time — the oracle's immediate auto-commit then equals the
    engines' batch-at-cycle-start semantics, because every compared query
    is also admitted at (or after) that heartbeat.  Mutations only touch
    keys committed by an earlier heartbeat (watermarks), matching the
    engine's delete->update->insert intra-batch ordering contract.

    ``dense_pk_index=False`` forces every join onto the index-less
    access paths (partitioned/block), which is the configuration that
    exercises the delta-JOIN carry: item writes are PK-side writes for
    the order_line->item and cart->item joins (full-probe fallback
    beats), customer writes leave every PK table untouched (carried-rid
    beats).
    """

    def __init__(self, dense_pk_index: bool = True):
        rng = np.random.default_rng(0)
        self.plan = tpcw.build_tpcw_plan(SCALE_I, SCALE_C,
                                         dense_pk_index=dense_pk_index)
        data = tpcw.generate_data(rng, SCALE_I, SCALE_C)
        self.engines = {
            k: SharedDBEngine(self.plan, tpcw.DEFAULT_UPDATE_SLOTS, data,
                              jit=False, kernels=k) for k in BACKENDS}
        self.baseline = QueryAtATimeEngine(self.plan, data, jit=False)
        self.pending_updates = []
        self.pending_queries = []
        self.next_item = SCALE_I
        self.next_cust = SCALE_C
        # keys committed by a past heartbeat (safe to update/delete)
        self.item_watermark = SCALE_I
        self.cust_watermark = SCALE_C
        self.heartbeats = 0

    # ------------------------------------------------------------- ops
    def queue_update(self, update):
        self.pending_updates.append(update)
        for eng in self.engines.values():
            eng.submit_update(*update)

    def insert_item(self, subject, cost):
        i = self.next_item
        self.next_item += 1
        self.queue_update(("item", "insert", {
            "i_id": i, "i_a_id": i % max(SCALE_I // 4, 1),
            "i_subject": subject, "i_title": i % tpcw.N_TITLE_TOKENS,
            "i_pub_date": 11500, "i_cost": cost, "i_srp": cost + 100,
            "i_stock": 5, "i_related1": 0}))

    def insert_customer(self):
        c = self.next_cust
        self.next_cust += 1
        self.queue_update(("customer", "insert", {
            "c_id": c, "c_uname": c, "c_passwd": c * 7,
            "c_addr_id": c % SCALE_C, "c_discount": c % 50,
            "c_since": 11000, "c_expiration": 13000}))

    def submit(self, name, params):
        tickets = {k: eng.submit(name, params)
                   for k, eng in self.engines.items()}
        self.pending_queries.append((name, params, tickets))

    def heartbeat(self):
        for u in self.pending_updates:
            self.baseline.apply_update(*u)
        self.pending_updates = []
        for eng in self.engines.values():
            eng.run_until_drained()
        for name, params, tickets in self.pending_queries:
            want = self.baseline.execute(name, params).result
            for backend, t in tickets.items():
                assert t.result is not None, (backend, name)
                _compare(backend, t, want)
        self.pending_queries = []
        self.item_watermark = self.next_item
        self.cust_watermark = self.next_cust
        self.heartbeats += 1
        # snapshot parity: the engines' storage equals the oracle's
        for table in ("item", "customer"):
            want_t = self.baseline.state[table]
            for backend, eng in self.engines.items():
                got_t = eng.state[table]
                for col in self.plan.catalog.schemas[table].columns:
                    assert (np.asarray(got_t[col])
                            == np.asarray(want_t[col])).all(), \
                        (backend, table, col)
                assert (np.asarray(got_t["_valid"])
                        == np.asarray(want_t["_valid"])).all(), \
                    (backend, table)


# ---------------------------------------------------------------- driver
if HAVE_HYPOTHESIS:
    class DifferentialEngineMachine(RuleBasedStateMachine):
        def __init__(self):
            super().__init__()
            self.w = _World()

        # mutations (committed keys only — see _World watermarks)
        @rule(key=st.integers(0, SCALE_I - 1), val=st.integers(0, 9999))
        def update_item_cost(self, key, val):
            self.w.queue_update(("item", "update", {
                "key": key, "col": "i_cost", "val": val}))

        @rule(key=st.integers(0, SCALE_I - 1),
              subj=st.integers(0, tpcw.N_SUBJECTS - 1))
        def update_item_subject(self, key, subj):
            self.w.queue_update(("item", "update", {
                "key": key, "col": "i_subject", "val": subj}))

        @rule(key=st.integers(0, SCALE_I + 16))
        def delete_item(self, key):              # sometimes already gone
            if key < self.w.item_watermark:
                self.w.queue_update(("item", "delete", {"key": key}))

        @rule(subj=st.integers(0, tpcw.N_SUBJECTS - 1),
              cost=st.integers(100, 9999))
        def insert_item(self, subj, cost):
            self.w.insert_item(subj, cost)

        @rule()
        def insert_customer(self):
            self.w.insert_customer()

        @rule(key=st.integers(0, SCALE_C - 1),
              val=st.integers(12000, 15000))
        def update_customer_expiration(self, key, val):
            self.w.queue_update(("customer", "update", {
                "key": key, "col": "c_expiration", "val": val}))

        # selects
        @rule(name=st.sampled_from(["admin_item", "get_book",
                                    "get_related"]),
              i=st.integers(0, SCALE_I + 16))
        def select_item(self, name, i):
            self.w.submit(name, {0: (i, i)})

        @rule(c=st.integers(0, SCALE_C + 8))
        def select_customer(self, c):
            self.w.submit("get_customer", {0: (c, c)})

        @rule(s=st.integers(0, tpcw.N_SUBJECTS - 1))
        def search_subject(self, s):
            self.w.submit("search_subject", {0: (s, s)})

        @rule(s=st.integers(0, tpcw.N_SUBJECTS - 1))
        def best_sellers(self, s):
            self.w.submit("best_sellers", {0: (0, INT_MAX), 1: (s, s)})

        @rule(c=st.integers(0, SCALE_C - 1))
        def order_display(self, c):
            self.w.submit("order_display", {0: (c, c)})

        @rule()
        def heartbeat(self):
            self.w.heartbeat()

        def teardown(self):
            self.w.heartbeat()               # flush + final comparison

    DifferentialEngineMachine.TestCase.settings = settings(
        max_examples=3, stateful_step_count=10, deadline=None)
    TestDifferentialEngine = DifferentialEngineMachine.TestCase

    class IndexlessDeltaJoinMachine(RuleBasedStateMachine):
        """Random interleavings over the INDEX-LESS world, where every
        join runs a partitioned access path and heartbeats carry rid
        arrays: item writes are PK-side writes (full-probe fallback
        beats), customer writes leave all PK tables untouched
        (carried-rid beats), and the slot-stable ``joins_beat`` rule
        keeps the delta-join path engaging between mutations.  Every
        heartbeat still compares ticket-for-ticket against the oracle
        plus snapshot equality, whatever path ran."""

        def __init__(self):
            super().__init__()
            self.w = _World(dense_pk_index=False)

        # PK-side mutations (partition rebuild -> full-probe fallback)
        @rule(key=st.integers(0, SCALE_I - 1), val=st.integers(0, 9999))
        def update_item_cost(self, key, val):
            self.w.queue_update(("item", "update", {
                "key": key, "col": "i_cost", "val": val}))

        @rule(key=st.integers(0, SCALE_I + 16))
        def delete_item(self, key):
            if key < self.w.item_watermark:
                self.w.queue_update(("item", "delete", {"key": key}))

        @rule(subj=st.integers(0, tpcw.N_SUBJECTS - 1),
              cost=st.integers(100, 9999))
        def insert_item(self, subj, cost):
            self.w.insert_item(subj, cost)

        # spine-only mutations (PK tables untouched -> carried rids)
        @rule(key=st.integers(0, SCALE_C - 1),
              val=st.integers(12000, 15000))
        def update_customer_expiration(self, key, val):
            self.w.queue_update(("customer", "update", {
                "key": key, "col": "c_expiration", "val": val}))

        # slot-stable join admission: the same three templates, varying
        # only one template's params (rotating whole templates would
        # sweep the PK-side scan windows and overflow the admission pane
        # every beat, silently keeping the delta-join path cold)
        @rule(o=st.integers(0, 40))
        def joins_beat(self, o):
            self.w.submit("order_lines", {0: (o, o)})
            self.w.submit("get_cart", {0: (12, 12)})
            self.w.submit("get_book", {0: (5, 5)})
            self.w.heartbeat()

        @rule(c=st.integers(0, SCALE_C + 8))
        def select_customer(self, c):
            self.w.submit("get_customer", {0: (c, c)})

        @rule()
        def heartbeat(self):
            self.w.heartbeat()

        def teardown(self):
            self.w.heartbeat()               # flush + final comparison

    IndexlessDeltaJoinMachine.TestCase.settings = settings(
        max_examples=2, stateful_step_count=8, deadline=None)
    TestIndexlessDeltaJoin = IndexlessDeltaJoinMachine.TestCase


def test_deterministic_interleaved_stream_stays_equal():
    """The always-on fallback: a seeded interleaving of every operation
    kind across several heartbeats (runs without hypothesis)."""
    rng = np.random.default_rng(42)
    w = _World()
    for beat in range(4):
        for _ in range(int(rng.integers(2, 6))):
            op = rng.integers(0, 6)
            if op == 0:
                w.queue_update(("item", "update", {
                    "key": int(rng.integers(0, SCALE_I)),
                    "col": "i_cost", "val": int(rng.integers(0, 9999))}))
            elif op == 1 and w.item_watermark > 0:
                w.queue_update(("item", "delete", {
                    "key": int(rng.integers(0, w.item_watermark))}))
            elif op == 2:
                w.insert_item(int(rng.integers(0, tpcw.N_SUBJECTS)),
                              int(rng.integers(100, 9999)))
            elif op == 3:
                w.insert_customer()
            elif op == 4:
                w.queue_update(("customer", "update", {
                    "key": int(rng.integers(0, SCALE_C)),
                    "col": "c_expiration",
                    "val": int(rng.integers(12000, 15000))}))
            else:
                w.queue_update(("item", "update", {
                    "key": int(rng.integers(0, SCALE_I)),
                    "col": "i_subject",
                    "val": int(rng.integers(0, tpcw.N_SUBJECTS))}))
        w.submit("admin_item", {0: (int(rng.integers(0, SCALE_I)),) * 2})
        w.submit("get_customer",
                 {0: (int(rng.integers(0, SCALE_C)),) * 2})
        w.submit("search_subject",
                 {0: (int(rng.integers(0, tpcw.N_SUBJECTS)),) * 2})
        if beat % 2:
            s = int(rng.integers(0, tpcw.N_SUBJECTS))
            w.submit("best_sellers", {0: (0, INT_MAX), 1: (s, s)})
        w.heartbeat()
    # steady-state tail: slot-stable trickle beats engage the delta path
    # (the second consecutive single-template beat carries words forward)
    for _ in range(3):
        k = int(rng.integers(0, SCALE_I))
        w.queue_update(("item", "update", {"key": k, "col": "i_cost",
                                           "val": int(rng.integers(0,
                                                                   999))}))
        w.submit("admin_item", {0: (k, k)})
        w.heartbeat()
    assert any(eng.delta_cycles > 0 for eng in w.engines.values())


def test_deterministic_stream_indexless_delta_join_parity():
    """Ticket-for-ticket parity on the delta-JOIN path, both backends:
    an index-less world (every join partitioned) driven through

      * carried-rid beats — customer updates leave all PK tables
        untouched, so non-gather joins merge dirty spine rids into the
        carry;
      * PK-side-write beats — item updates rebuild the item partitions
        and force the full-probe fallback;
      * a dirty-overflow beat — more item rows than ``dirty_cap`` forces
        the full rescan, reseeding both carry halves.

    Every heartbeat's tickets are compared against the query-at-a-time
    oracle and the snapshots checked for column equality (see _World).
    """
    rng = np.random.default_rng(7)
    w = _World(dense_pk_index=False)

    def submit_joins(o_id):
        # slot-stable admission: the same three join templates every
        # beat, varying only order_lines' parameter.  A PK-side scan
        # stage covers every template that JOINS into the table, so
        # rotating whole templates would sweep the item stage's window
        # and overflow the contiguous admission pane — varying one
        # template's params keeps the changed span to its own slot word.
        w.submit("order_lines", {0: (o_id, o_id)})
        w.submit("get_cart", {0: (12, 12)})
        w.submit("get_book", {0: (5, 5)})

    # seed + two PK-side-write beats (item partitions rebuild)
    for beat in range(3):
        if beat:
            w.queue_update(("item", "update", {
                "key": int(rng.integers(0, SCALE_I)), "col": "i_cost",
                "val": int(rng.integers(100, 9999))}))
        submit_joins(10 + beat)
        w.heartbeat()
        assert all(eng.last_join_path == "full"
                   for eng in w.engines.values())
    # carried-rid beats: customer-only updates, join templates active
    for beat in range(4):
        w.queue_update(("customer", "update", {
            "key": int(rng.integers(0, SCALE_C)), "col": "c_expiration",
            "val": int(rng.integers(12000, 15000))}))
        submit_joins(20 + beat)
        w.heartbeat()
    assert all(eng.delta_join_cycles >= 3 for eng in w.engines.values())
    # dirty-overflow beat: touch more item rows than dirty_cap holds in
    # ONE cycle (updates + deletes on distinct keys, since either kind's
    # slot budget alone is below the dirty capacity)
    dirty_cap = w.plan.catalog.schemas["item"].dirty_cap
    slots = tpcw.DEFAULT_UPDATE_SLOTS
    n_upd = min(slots.n_update, dirty_cap)
    for k in range(n_upd):
        w.queue_update(("item", "update", {"key": k, "col": "i_stock",
                                           "val": 1}))
    for k in range(n_upd, dirty_cap + 1):
        w.queue_update(("item", "delete", {"key": k}))
    submit_joins(30)
    w.heartbeat()
    assert all(eng.last_scan_path == "full"
               for eng in w.engines.values())
    # recovery: the full beat reseeded everything — delta joins resume
    w.queue_update(("customer", "update", {
        "key": 1, "col": "c_expiration", "val": 14999}))
    submit_joins(31)
    w.heartbeat()
    assert all(eng.last_join_path == "delta"
               for eng in w.engines.values())

"""Collective-locality proofs for the sharded heartbeat (PR-5).

The sharding contract (core/sharding.py) is that a DELTA beat is
entirely shard-local — dirty rows route to their owning shard, panes
and carried rids refresh without communication — while a full/reseed
beat scatters the rescan across every shard exactly once and
re-assembles the replicated probe-side words with one all_gather per
mirrored predicated stage.  These tests prove it structurally, on three
independent surfaces:

  * the JAXPR of both delta-cycle flavours contains NO collective
    primitive (and the full cycle contains exactly one ``all_gather``
    per mirrored predicated scan stage, over that stage's per-shard
    row slice);
  * the OPTIMIZED multi-device HLO of the compiled delta beat contains
    no collective instruction at all (so GSPMD didn't sneak one in
    either), while the compiled reseed contains the all-gathers;
  * a recording backend run through the real engine shows the reseed's
    compare kernel executing at per-shard width (every shard rescans
    its own rows exactly once) and the steady-state delta beat never
    invoking the full-window compare.
"""
import numpy as np
import pytest

import jax

from repro.analysis_static.diagnostics import errors_in
from repro.analysis_static.jaxpr_passes import (lint_delta_collectives,
                                                lint_delta_hlo,
                                                lint_reseed_collectives)
from repro.core import backends
from repro.core.executor import SharedDBEngine
from repro.core.lowering import lower_plan
from repro.core.storage import empty_update_batch
from repro.workloads import tpcw

SCALE_I, SCALE_C = 64, 128


@pytest.fixture(scope="module")
def sharded_cycles():
    """spec + the three cycle flavours + concrete args at 4 shards over
    the index-less TPC-W plan (every join on a carried access path)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core.sharding import (build_shard_spec, build_sharded_cycle,
                                     build_sharded_delta_cycle,
                                     init_sharded_state, make_row_mesh)

    if jax.default_backend() != "cpu" or jax.device_count() < 4:
        pytest.skip("needs 4 CPU host devices")
    rng = np.random.default_rng(0)
    plan = tpcw.build_tpcw_plan(SCALE_I, SCALE_C, dense_pk_index=False)
    data = tpcw.generate_data(rng, SCALE_I, SCALE_C)
    mesh = make_row_mesh(4)
    spec = build_shard_spec(plan, mesh)
    lowered = lower_plan(plan)
    be = backends.get_backend("jnp")
    full = build_sharded_cycle(lowered, be, spec)
    delta = build_sharded_delta_cycle(lowered, be, spec)
    delta_j = build_sharded_delta_cycle(lowered, be, spec,
                                        delta_joins=True)
    state = init_sharded_state(spec, data)
    put = lambda a: jax.device_put(a, NamedSharding(mesh, P()))  # noqa
    queries = {"params": put(np.zeros((plan.qcap, plan.n_params_max, 2),
                                      np.int32)),
               "active": put(np.zeros((plan.qcap,), bool))}
    updates = {t: jax.tree.map(put, empty_update_batch(
        s, tpcw.DEFAULT_UPDATE_SLOTS, xp=np))
        for t, s in plan.catalog.schemas.items()}
    state2, carry, results = jax.jit(full)(state, queries, updates)
    queries_d = dict(queries, changed=put(np.zeros((plan.qcap,), bool)))
    return {"plan": plan, "spec": spec, "lowered": lowered, "full": full,
            "delta": delta, "delta_j": delta_j,
            "args_full": (state, queries, updates),
            "args_delta": (state2, carry, queries_d, updates),
            "args_delta_j": (state2, carry, results["_join_rids"],
                             queries_d, updates)}


def test_delta_beat_executes_no_cross_shard_collective(sharded_cycles):
    """Both delta flavours — shard-local by construction: no collective
    primitive anywhere in the traced beat (proven by the planlint
    collective detector), and none in the compiled 4-device HLO (GSPMD
    added none behind our back)."""
    c = sharded_cycles
    jd = jax.make_jaxpr(c["delta"])(*c["args_delta"])
    jdj = jax.make_jaxpr(c["delta_j"])(*c["args_delta_j"])
    assert errors_in(lint_delta_collectives(jd)) == []
    assert errors_in(lint_delta_collectives(jdj)) == []
    hlo = jax.jit(c["delta_j"]).lower(
        *c["args_delta_j"]).compile().as_text()
    assert errors_in(lint_delta_hlo(hlo)) == []


def test_reseed_beat_allgathers_each_mirrored_stage_exactly_once(
        sharded_cycles):
    """The full/reseed beat's only collective is ONE all_gather per
    mirrored predicated scan stage, and each gathers that stage's
    per-shard row slice — i.e. the rescan touched every shard exactly
    once before re-assembly.  Proven by the planlint reseed analyzer
    (which checks count AND operand shapes), plus the vacuity guard
    that this plan has mirrored predicated stages at all."""
    c = sharded_cycles
    spec, lowered = c["spec"], c["lowered"]
    mi_pred = [st for st in lowered.scans
               if spec.is_mirrored(st.table) and st.cols]
    assert mi_pred, "plan has no mirrored predicated stage to prove"
    jf = jax.make_jaxpr(c["full"])(*c["args_full"])
    assert errors_in(lint_reseed_collectives(jf, lowered, spec)) == []
    hlo = jax.jit(c["full"]).lower(*c["args_full"]).compile().as_text()
    assert "all-gather" in hlo


def _recording_backend(record):
    """jnp backend recording every compare-kernel invocation's
    (rows, query-width) — trace-time, so it pairs with jit engines whose
    cycles trace exactly once per flavour."""
    base = backends.get_backend("jnp")

    def scan(cols, lo, hi, valid):
        record.append((int(cols.shape[1]), int(lo.shape[1])))
        return base.scan(cols, lo, hi, valid)

    backends.register_backend(backends.OperatorBackend(
        name="recording-sharded", scan=scan, join_block=base.join_block,
        join_partitioned=base.join_partitioned, groupby=base.groupby,
        scan_delta=base.scan_delta, join_delta=base.join_delta))
    return "recording-sharded"


def test_reseed_rescans_per_shard_and_delta_skips_full_compare(row_mesh):
    """Engine-level recording proof, 4 shards: the seeding full beat's
    compare kernels all run at PER-SHARD row width (the rescan is
    spread over the shards — each scans its own range once), and the
    steady-state delta beat never invokes the full-window compare at
    the big item stage — only its admission pane."""
    mesh = row_mesh(4)
    rng = np.random.default_rng(3)
    plan = tpcw.build_tpcw_plan(SCALE_I, SCALE_C)
    data = tpcw.generate_data(rng, SCALE_I, SCALE_C)
    record = []
    name = _recording_backend(record)
    eng = SharedDBEngine(plan, tpcw.DEFAULT_UPDATE_SLOTS, data,
                         kernels=name, mesh=mesh)
    spec = eng._shard_spec
    lowered = lower_plan(plan)
    item_st = next(s for s in lowered.scans if s.table == "item")
    full_width = item_st.q_window
    pane_width = 32 * item_st.delta_words
    assert pane_width < full_width

    eng.submit("admin_item", {0: (1, 1)})
    eng.run_until_drained()                  # traces + runs the reseed
    assert eng.last_scan_path == "full"
    shard_widths = {spec.shard_rows[st.table] for st in lowered.scans
                    if st.cols}
    rows_seen = {r for r, _ in record}
    assert rows_seen == shard_widths, (rows_seen, shard_widths)
    # the item stage's full-width compare ran at its SHARD row count
    assert (spec.shard_rows["item"], full_width) in record

    record.clear()
    for i in range(3):
        eng.submit_update("customer", "update",
                          {"key": 2 + i, "col": "c_expiration",
                           "val": 14000 + i})
        eng.submit("admin_item", {0: (1, 1)})
        eng.run_until_drained()
        assert eng.last_scan_path == "delta"
    # the delta beat's compares are panes only — never the full window
    assert record, "delta trace recorded no compare at all?"
    assert all(q < full_width for _, q in record), record
    assert (spec.shard_rows["item"], pane_width) in record \
        or (spec.padded["item"], pane_width) in record

"""Incremental scan deltas: dirty-row tracking in storage, delete-then-
update batches, dirty-set overflow -> full-rescan fallback, admission
windows overlapping dirty rows, empty batches carrying words unchanged,
jnp-vs-pallas delta-kernel parity on padded tails, and the acceptance
property — a steady-state heartbeat runs the delta path WITHOUT invoking
the full-width compare kernel."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import backends
from repro.core.baseline import QueryAtATimeEngine
from repro.core.executor import SharedDBEngine
from repro.core.lowering import lower_plan
from repro.core.plan import Pred, QueryTemplate, compile_plan
from repro.core.storage import (Catalog, TableSchema, UpdateSlots,
                                apply_updates, bulk_load,
                                empty_update_batch)
from repro.kernels import ref
from repro.kernels.fused_delta import delta_scan_pallas
from repro.workloads import tpcw


# ------------------------------------------------- storage dirty tracking
def _table_world(dirty_cap=8):
    schema = TableSchema("t", ("k", "v"), 32, pk="k", key_space=64,
                         dirty_cap=dirty_cap)
    t = bulk_load(schema, {"k": np.arange(16), "v": np.arange(16) * 10})
    return schema, t


def test_apply_updates_tracks_dirty_rows():
    schema, t = _table_world()
    b = empty_update_batch(schema, UpdateSlots(2, 2, 2))
    b["del_key"] = b["del_key"].at[0].set(3)        # row 3
    b["del_mask"] = b["del_mask"].at[0].set(True)
    b["del_key"] = b["del_key"].at[1].set(55)       # absent: not dirty
    b["del_mask"] = b["del_mask"].at[1].set(True)
    b["upd_key"] = b["upd_key"].at[0].set(7)        # row 7
    b["upd_col"] = b["upd_col"].at[0].set(1)
    b["upd_val"] = b["upd_val"].at[0].set(999)
    b["upd_mask"] = b["upd_mask"].at[0].set(True)
    b["ins_rows"]["k"] = b["ins_rows"]["k"].at[0].set(40)   # row 16
    b["ins_rows"]["v"] = b["ins_rows"]["v"].at[0].set(1)
    b["ins_mask"] = b["ins_mask"].at[0].set(True)
    t2 = apply_updates(schema, t, b)
    rows = np.asarray(t2["_dirty_rows"])
    assert rows[rows < schema.capacity].tolist() == [3, 7, 16]  # sorted
    assert int(t2["_dirty_n"]) == 3
    assert not bool(t2["_dirty_overflow"])
    # a fresh table and an empty batch are fully clean (pad sentinel ==
    # the table capacity, keeping the set sorted for the fast scatter)
    assert (np.asarray(t["_dirty_rows"]) == schema.capacity).all()
    t3 = apply_updates(schema, t2, empty_update_batch(schema,
                                                      UpdateSlots(2, 2, 2)))
    assert (np.asarray(t3["_dirty_rows"]) == schema.capacity).all()
    assert int(t3["_dirty_n"]) == 0


def test_delete_then_update_same_key_one_batch_marks_row_dirty_once():
    """Arrival order: the update finds nothing post-delete, so the row is
    dirtied by the delete alone and stays deleted."""
    schema, t = _table_world()
    b = empty_update_batch(schema, UpdateSlots(1, 1, 1))
    b["del_key"] = b["del_key"].at[0].set(5)
    b["del_mask"] = b["del_mask"].at[0].set(True)
    b["upd_key"] = b["upd_key"].at[0].set(5)
    b["upd_col"] = b["upd_col"].at[0].set(1)
    b["upd_val"] = b["upd_val"].at[0].set(123)
    b["upd_mask"] = b["upd_mask"].at[0].set(True)
    t2 = apply_updates(schema, t, b)
    assert not bool(t2["_valid"][5])
    assert int(t2["v"][5]) == 50                    # update found nothing
    rows = np.asarray(t2["_dirty_rows"])
    assert rows[rows < schema.capacity].tolist() == [5]
    assert int(t2["_dirty_n"]) == 1


def test_dirty_set_overflow_flag():
    schema, t = _table_world(dirty_cap=2)
    b = empty_update_batch(schema, UpdateSlots(1, 4, 1))
    for i, key in enumerate((1, 2, 9)):
        b["upd_key"] = b["upd_key"].at[i].set(key)
        b["upd_col"] = b["upd_col"].at[i].set(1)
        b["upd_val"] = b["upd_val"].at[i].set(7)
        b["upd_mask"] = b["upd_mask"].at[i].set(True)
    t2 = apply_updates(schema, t, b)
    assert bool(t2["_dirty_overflow"])
    assert int(t2["_dirty_n"]) == 2                 # capacity-clamped
    stored = np.asarray(t2["_dirty_rows"])
    assert set(stored[stored < schema.capacity].tolist()) <= {1, 2, 9}


# ---------------------------------------------------- delta kernel parity
@pytest.mark.parametrize("seed,C,T,Q,D", [
    (0, 1, 37, 64, 9),       # odd table size, pad slots in rows
    (1, 3, 200, 96, 16),     # multi-column
    (2, 2, 5, 32, 7),        # D > T: duplicate dirty rows
    (3, 4, 131, 416, 33),    # TPC-W-sized window, non-multiple D
    (4, 1, 1, 32, 1),        # degenerate single row
])
def test_delta_kernel_jnp_pallas_parity_padded_tails(seed, C, T, Q, D):
    rng = np.random.default_rng(seed)
    cols = jnp.asarray(rng.integers(0, 50, (C, T)), jnp.int32)
    lo = jnp.asarray(rng.integers(0, 50, (C, Q)), jnp.int32)
    hi = lo + jnp.asarray(rng.integers(0, 20, (C, Q)), jnp.int32)
    valid = jnp.asarray(rng.random(T) > 0.2)
    # pad sentinels both below and above range: callers drop them
    rows = jnp.asarray(rng.choice(
        np.concatenate([np.arange(T), [-1, T, T + 3, T]]), D), jnp.int32)
    want = ref.delta_scan_ref(cols, lo, hi, valid, rows)
    got = delta_scan_pallas(cols, lo, hi, valid, rows)
    keep = (np.asarray(rows) >= 0) & (np.asarray(rows) < T)
    assert (np.asarray(got)[keep] == np.asarray(want)[keep]).all()
    # the freshly scanned words agree with the full-table oracle rows
    full = ref.clockscan_ref(cols, lo, hi, valid)
    safe = np.clip(np.asarray(rows), 0, T - 1)
    assert (np.asarray(want)[keep] == np.asarray(full)[safe][keep]).all()


# ------------------------------------------------------- engine-level path
SCALE_I, SCALE_C = 128, 256


@pytest.fixture(scope="module")
def tpcw_world():
    rng = np.random.default_rng(5)
    plan = tpcw.build_tpcw_plan(SCALE_I, SCALE_C)
    data = tpcw.generate_data(rng, SCALE_I, SCALE_C)
    return plan, data


def _recording_backend(record):
    """The jnp backend with every compare-kernel invocation's query width
    recorded (trace-time: pair with jit=False engines)."""
    base = backends.get_backend("jnp")

    def scan(cols, lo, hi, valid):
        record.append(int(lo.shape[1]))
        return base.scan(cols, lo, hi, valid)

    backends.register_backend(backends.OperatorBackend(
        name="recording-jnp", scan=scan, join_block=base.join_block,
        join_partitioned=base.join_partitioned, groupby=base.groupby,
        scan_delta=base.scan_delta, join_delta=base.join_delta))
    return "recording-jnp"


def test_steady_state_runs_delta_without_full_width_compare(tpcw_world):
    """Acceptance: a steady-state heartbeat (<=1% dirty rows, trickle
    admission) takes the delta path — the full-table compare at the item
    stage's full window width is never invoked after the seeding cycle,
    only panes of 32 * delta_words slots."""
    plan, data = tpcw_world
    record = []
    name = _recording_backend(record)
    eng = SharedDBEngine(plan, tpcw.DEFAULT_UPDATE_SLOTS, data, jit=False,
                         kernels=name)
    item_stage = next(s for s in lower_plan(plan).scans
                      if s.table == "item")
    full_width = item_stage.q_window
    pane_width = 32 * item_stage.delta_words
    assert pane_width < full_width

    eng.submit("admin_item", {0: (1, 1)})
    eng.run_cycle()                                  # seeds the carry
    assert eng.last_scan_path == "full"
    assert full_width in record
    record.clear()

    base = QueryAtATimeEngine(plan, data, jit=False)
    for i in range(4):                               # steady state
        upd = ("item", "update", {"key": 10 + i, "col": "i_cost",
                                  "val": 1000 + i})
        eng.submit_update(*upd)
        base.apply_update(*upd)
        t = eng.submit("admin_item", {0: (10 + i, 10 + i)})
        eng.run_cycle()
        assert eng.last_scan_path == "delta"
        assert eng.last_delta_overflow == 0
        want = base.execute(t.template, t.params).result
        assert (np.asarray(t.result["rows"])
                == np.asarray(want["rows"])).all()
    assert eng.delta_cycles == 4
    assert full_width not in record                  # panes only
    assert pane_width in record


def test_admission_window_overlap_with_dirty_rows(tpcw_world):
    """A query admitted in the same heartbeat that dirties the row it
    matches: the dirty-row refresh must evaluate the NEW query's
    predicate, not the carried (pre-admission) words."""
    plan, data = tpcw_world
    eng = SharedDBEngine(plan, tpcw.DEFAULT_UPDATE_SLOTS, data, jit=False)
    base = QueryAtATimeEngine(plan, data, jit=False)
    eng.submit("search_subject", {0: (3, 3)})
    eng.run_cycle()                                  # seed carry
    # move item 50 into subject 3 and immediately search subject 3
    upd = ("item", "update", {"key": 50, "col": "i_subject", "val": 3})
    eng.submit_update(*upd)
    base.apply_update(*upd)
    t = eng.submit("search_subject", {0: (3, 3)})
    eng.run_cycle()
    assert eng.last_scan_path == "delta"
    rows = set(int(x) for x in np.asarray(t.result["rows"]) if x >= 0)
    want = base.execute("search_subject", {0: (3, 3)}).result
    assert rows == set(int(x) for x in want["rows"] if x >= 0)
    assert 50 in rows


def test_delete_then_update_same_key_through_delta_engine(tpcw_world):
    """The delta heartbeat honours arrival order inside one batch: a
    delete-then-update of the same key leaves the row deleted, and the
    carried words drop it from every standing result."""
    plan, data = tpcw_world
    eng = SharedDBEngine(plan, tpcw.DEFAULT_UPDATE_SLOTS, data, jit=False)
    t0 = eng.submit("admin_item", {0: (20, 20)})
    eng.run_cycle()
    assert (np.asarray(t0.result["rows"]) >= 0).sum() == 1
    eng.submit_update("item", "delete", {"key": 20})
    eng.submit_update("item", "update",
                      {"key": 20, "col": "i_cost", "val": 1})
    t1 = eng.submit("admin_item", {0: (20, 20)})
    eng.run_cycle()
    assert eng.last_scan_path == "delta"
    assert (np.asarray(t1.result["rows"]) >= 0).sum() == 0


def test_empty_update_batches_carry_words_unchanged(tpcw_world):
    """Heartbeats with no updates (and repeat admission) must carry the
    scan words forward bit-identically to a full rescan."""
    plan, data = tpcw_world

    def drive(eng):
        eng.submit("search_subject", {0: (3, 3)})
        eng.run_cycle()
        for _ in range(2):                           # empty batches
            eng.submit("search_subject", {0: (3, 3)})
            eng.run_cycle()
        return eng

    a = drive(SharedDBEngine(plan, tpcw.DEFAULT_UPDATE_SLOTS, data,
                             jit=False))
    b = drive(SharedDBEngine(plan, tpcw.DEFAULT_UPDATE_SLOTS, data,
                             jit=False, delta_scans=False))
    assert a.delta_cycles == 2 and b.delta_cycles == 0
    assert set(a._carry["scan"]) == set(b._carry["scan"])
    for table in a._carry["scan"]:
        assert (np.asarray(a._carry["scan"][table])
                == np.asarray(b._carry["scan"][table])).all(), table


def _overflow_world():
    cat = Catalog([TableSchema("t", ("k", "v"), 64, pk="k", key_space=64,
                               dirty_cap=2)])
    tpl = QueryTemplate("by_v", "t", preds=(Pred("t", "v"),), limit=64)
    plan = compile_plan(cat, [tpl], {"by_v": 32}, max_results=64)
    data = {"t": {"k": np.arange(32), "v": np.arange(32) % 8}}
    return plan, SharedDBEngine(plan, UpdateSlots(4, 4, 4), data,
                                jit=False, kernels="jnp")


def test_dirty_overflow_falls_back_to_full_rescan():
    """A batch touching more rows than the dirty set holds must run the
    (safe) full rescan — and the results stay exact."""
    plan, eng = _overflow_world()
    t0 = eng.submit("by_v", {0: (5, 5)})
    eng.run_cycle()                                  # seed carry
    # 1 update fits the dirty set: delta
    eng.submit_update("t", "update", {"key": 5, "col": "v", "val": 5})
    eng.run_cycle()
    assert eng.last_scan_path == "delta"
    # 3 updates overflow dirty_cap=2: host falls back before dispatch
    for key in (1, 2, 9):
        eng.submit_update("t", "update", {"key": key, "col": "v",
                                          "val": 5})
    t1 = eng.submit("by_v", {0: (5, 5)})
    eng.run_cycle()
    assert eng.last_scan_path == "full"
    rows = set(int(x) for x in np.asarray(t1.result["rows"]) if x >= 0)
    assert rows == {1, 2, 5, 9, 13, 21, 29}          # v == 5 rows
    # the fallback reseeded the carry: the next light beat is delta again
    eng.submit("by_v", {0: (5, 5)})
    eng.run_cycle()
    assert eng.last_scan_path == "delta"


def test_admission_pane_overflow_falls_back_to_full_rescan(tpcw_world):
    """Admission churn across more words than a stage's pane holds must
    also fall back (many templates flip at once)."""
    plan, data = tpcw_world
    eng = SharedDBEngine(plan, tpcw.DEFAULT_UPDATE_SLOTS, data, jit=False)
    eng.submit("get_book", {0: (1, 1)})
    eng.run_cycle()
    # activate slots across many item-window words in one heartbeat
    for name in ("get_book", "get_related", "search_subject",
                 "search_title", "new_products", "order_lines"):
        eng.submit(name, {0: (2, 2)})
    eng.run_cycle()
    assert eng.last_scan_path == "full"


def test_cycle_result_reports_path_and_counts(tpcw_world):
    """Satellite: run_until_drained attributes each heartbeat — admitted
    queries, dirty touches, and which scan path ran."""
    plan, data = tpcw_world
    eng = SharedDBEngine(plan, tpcw.DEFAULT_UPDATE_SLOTS, data, jit=False)
    eng.submit("get_book", {0: (1, 1)})
    first = eng.run_until_drained()
    assert [d.scan_path for d in first] == ["full"]
    assert first[0].admitted == 1 and first[0].dirty == 0
    eng.submit("get_book", {0: (2, 2)})
    eng.submit_update("item", "update", {"key": 2, "col": "i_cost",
                                         "val": 42})
    second = eng.run_until_drained()
    assert [d.scan_path for d in second] == ["delta"]
    assert second[0].admitted == 1 and second[0].dirty == 1

"""Lowering layer (staged operator graph) + backend registry tests:
stage metadata tightness, jnp/pallas resolution, kernel-path parity with
the reference path over the TPC-W templates, and bounded-union overflow
accounting."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import backends, operators as ops
from repro.core.executor import SharedDBEngine
from repro.core.lowering import build_cycle, lower_plan
from repro.core.plan import (Join, Pred, QueryTemplate, GroupAgg,
                             compile_plan)
from repro.core.storage import Catalog, TableSchema, UpdateSlots
from repro.workloads import tpcw

INT_MAX = 2147483647


# ------------------------------------------------------------- lowering IR
def test_lowered_graph_covers_plan_and_routes_every_template():
    plan = tpcw.build_tpcw_plan(400, 1200)
    low = lower_plan(plan)
    assert {s.table for s in low.scans} == set(plan.scans)
    assert len(low.joins) == len(plan.joins)
    assert len(low.sorts) == len(plan.sorts)
    assert len(low.groups) == len(plan.groups)
    # every template gets exactly one result-producing stage
    producers = [name for st in low.sorts + low.groups + low.routes
                 for name, _, _ in st.slots]
    assert sorted(producers) == sorted(plan.templates)
    # stage order is the paper's pipeline: scans, joins, sorts/groups,
    # routing
    kinds = [k for k, _ in low.stages()]
    assert kinds == sorted(kinds, key=["scan", "join", "sort", "group",
                                       "route"].index)


def test_word_range_windows_are_tight():
    """Per-node word windows cover exactly the subscribers' slot words:
    the per-operator mask work scales with the operator's own capacity,
    never the global query capacity."""
    plan = tpcw.build_tpcw_plan(400, 1200)
    subscriber_sets = (
        [n.referencing for n in plan.scans.values()]
        + [n.subscribers for n in plan.joins + plan.sorts + plan.groups])
    for names in subscriber_sets:
        wlo, whi = plan.word_range(names)
        lo = min(plan.offsets[n] for n in names)
        hi = max(plan.offsets[n] + plan.caps[n] for n in names)
        assert wlo == lo // 32
        assert whi == -(-hi // 32)
        sub = plan.sub_mask(names)
        # boundary words are populated, everything outside is zero
        assert sub[wlo] != 0 and sub[whi - 1] != 0
        assert not sub[:wlo].any() and not sub[whi:].any()


def test_lowered_slots_are_window_relative():
    plan = tpcw.build_tpcw_plan(400, 1200)
    low = lower_plan(plan)
    for st in low.sorts + low.groups + low.routes:
        for name, o, c in st.slots:
            assert o == plan.offsets[name] - st.wlo * 32
            assert 0 <= o and o + c <= (st.whi - st.wlo) * 32


# ------------------------------------------------------ backend resolution
def test_backend_registry_resolution(monkeypatch):
    monkeypatch.delenv("REPRO_KERNELS", raising=False)
    assert backends.resolve_backend("jnp").name == "jnp"
    assert backends.resolve_backend("ref").name == "jnp"
    assert backends.resolve_backend("pallas").name == "pallas"
    # auto on CPU -> the reference backend
    assert backends.resolve_backend("auto").name == "jnp"
    monkeypatch.setenv("REPRO_KERNELS", "pallas")
    assert backends.resolve_backend("auto").name == "pallas"
    monkeypatch.setenv("REPRO_KERNELS", "ref")
    assert backends.resolve_backend("auto").name == "jnp"
    # "auto" in the env var falls through to device-based choice
    monkeypatch.setenv("REPRO_KERNELS", "auto")
    assert backends.resolve_backend("auto").name == "jnp"
    monkeypatch.setenv("REPRO_KERNELS", "cuda")
    with pytest.raises(ValueError, match="REPRO_KERNELS"):
        backends.resolve_backend("auto")
    monkeypatch.delenv("REPRO_KERNELS")
    with pytest.raises(ValueError):
        backends.resolve_backend("cuda")
    with pytest.raises(KeyError):
        backends.get_backend("nope")
    assert set(backends.available_backends()) >= {"jnp", "pallas"}


def test_join_block_backend_parity_non_tile_multiple():
    """The pallas join_block pads to tile multiples; parity with the jnp
    oracle on deliberately awkward (non-multiple-of-256) shapes."""
    rng = np.random.default_rng(11)
    Tl, Tr, W = 300, 130, 2
    keys_r = jnp.asarray(rng.permutation(Tr * 3)[:Tr], jnp.int32)
    keys_l = jnp.asarray(rng.choice(Tr * 4, Tl), jnp.int32)
    mask_l = jnp.asarray(rng.integers(0, 2**32, (Tl, W)), jnp.uint32)
    mask_r = jnp.asarray(rng.integers(0, 2**32, (Tr, W)), jnp.uint32)
    valid_r = jnp.asarray(rng.random(Tr) > 0.25)
    r1, m1 = backends.get_backend("jnp").join_block(
        keys_l, mask_l, keys_r, mask_r, valid_r)
    r2, m2 = backends.get_backend("pallas").join_block(
        keys_l, mask_l, keys_r, mask_r, valid_r)
    assert (np.asarray(r1) == np.asarray(r2)).all()
    assert (np.asarray(m1) == np.asarray(m2)).all()


# -------------------------------------- block-join access path (no index)
def _block_join_world(kernels: str):
    """A PK table with key_space=0: no dense index, so lowering picks the
    blocked key-equality join instead of the index gather."""
    cat = Catalog([
        TableSchema("fact", ("f_id", "f_ref", "f_val"), 64),
        TableSchema("dim", ("d_key", "d_attr"), 32, pk="d_key",
                    key_space=0),
    ])
    tpl = QueryTemplate("by_val", "fact",
                        preds=(Pred("fact", "f_val"),),
                        joins=(Join("f_ref", "dim"),), limit=64)
    plan = compile_plan(cat, [tpl], {"by_val": 32}, max_results=64)
    rng = np.random.default_rng(4)
    d_key = np.arange(0, 32 * 7, 7)          # sparse, non-dense keys
    data = {
        "fact": {"f_id": np.arange(64),
                 "f_ref": rng.choice(np.concatenate([d_key, [-1, 999]]),
                                     64),
                 "f_val": rng.integers(0, 10, 64)},
        "dim": {"d_key": d_key, "d_attr": np.arange(32)},
    }
    eng = SharedDBEngine(plan, UpdateSlots(2, 2, 2), data, jit=False,
                         kernels=kernels)
    return plan, data, eng


def test_lowering_selects_block_join_without_dense_index():
    plan, data, eng = _block_join_world("jnp")
    low = lower_plan(plan)
    assert [j.kind for j in low.joins] == ["block"]
    t = eng.submit("by_val", {0: (3, 5)})
    eng.run_cycle()
    rows = set(int(r) for r in np.asarray(t.result["rows"]) if r >= 0)
    valid_refs = set(data["dim"]["d_key"].tolist())
    want = {i for i in range(64)
            if 3 <= data["fact"]["f_val"][i] <= 5
            and int(data["fact"]["f_ref"][i]) in valid_refs}
    assert rows == want
    # the query-at-a-time baseline supports the same index-less schema
    from repro.core.baseline import QueryAtATimeEngine
    base = QueryAtATimeEngine(plan, data, jit=False)
    b = base.execute("by_val", {0: (3, 5)})
    assert set(int(r) for r in b.result["rows"] if r >= 0) == want


def test_mutations_apply_without_dense_index():
    """Deletes and point-updates on an index-less PK table locate rows by
    key-equality scan — they must commit, not silently drop."""
    plan, data, eng = _block_join_world("jnp")
    t0 = eng.submit("by_val", {0: (0, 9)})
    eng.run_cycle()
    rows0 = set(int(r) for r in np.asarray(t0.result["rows"]) if r >= 0)
    victim = sorted(rows0)[0]
    victim_key = int(data["fact"]["f_ref"][victim])
    # delete the dim row the victim fact joins to: victim must vanish
    eng.submit_update("dim", "delete", {"key": victim_key})
    t1 = eng.submit("by_val", {0: (0, 9)})
    eng.run_cycle()
    rows1 = set(int(r) for r in np.asarray(t1.result["rows"]) if r >= 0)
    gone = {i for i in rows0 if int(data["fact"]["f_ref"][i]) == victim_key}
    assert rows1 == rows0 - gone
    # point-update a surviving dim row's attribute by key
    other = sorted(rows1)[0]
    other_key = int(data["fact"]["f_ref"][other])
    eng.submit_update("dim", "update",
                      {"key": other_key, "col": "d_attr", "val": 777})
    eng.run_cycle()
    d_row = np.asarray(eng.state["dim"]["d_key"]).tolist().index(other_key)
    assert int(np.asarray(eng.state["dim"]["d_attr"])[d_row]) == 777
    # delete-then-update of the SAME key in one batch: update finds
    # nothing (arrival-order semantics, matching the indexed path)
    eng.submit_update("dim", "delete", {"key": other_key})
    eng.submit_update("dim", "update",
                      {"key": other_key, "col": "d_attr", "val": 888})
    eng.run_cycle()
    assert int(np.asarray(eng.state["dim"]["d_attr"])[d_row]) == 777
    assert not bool(np.asarray(eng.state["dim"]["_valid"])[d_row])


def test_block_join_engine_parity_jnp_vs_pallas():
    _, _, e1 = _block_join_world("jnp")
    _, _, e2 = _block_join_world("pallas")
    t1 = e1.submit("by_val", {0: (0, 9)})
    t2 = e2.submit("by_val", {0: (0, 9)})
    e1.run_cycle()
    e2.run_cycle()
    assert (np.asarray(t1.result["rows"])
            == np.asarray(t2.result["rows"])).all()


# ----------------------------------------- full-stack jnp vs pallas parity
def test_engine_jnp_vs_pallas_parity_over_tpcw_templates():
    """Acceptance: kernels="jnp" and kernels="pallas" (interpret mode on
    CPU) produce identical results across the TPC-W templates."""
    rng = np.random.default_rng(5)
    plan = tpcw.build_tpcw_plan(128, 256)
    data = tpcw.generate_data(rng, 128, 256)
    queries = [
        ("get_customer", {0: (7, 7)}),
        ("get_password", {0: (3, 3)}),
        ("get_book", {0: (5, 5)}),
        ("get_related", {0: (9, 9)}),
        ("admin_item", {0: (1, 1)}),
        ("search_subject", {0: (3, 3)}),
        ("search_title", {0: (40, 60)}),
        ("search_author", {0: (100, 120)}),
        ("new_products", {0: (2, 2)}),
        ("best_sellers", {0: (0, INT_MAX), 1: (2, 2)}),
        ("order_lines", {0: (10, 10)}),
        ("order_display", {0: (17, 17)}),
        ("get_cart", {0: (12, 12)}),
    ]
    engines, tickets = [], []
    for kernels in ("jnp", "pallas"):
        eng = SharedDBEngine(plan, tpcw.DEFAULT_UPDATE_SLOTS, data,
                             jit=False, kernels=kernels)
        tickets.append([eng.submit(n, p) for n, p in queries])
        eng.run_cycle()
        engines.append(eng)
    for a, b in zip(*tickets):
        assert a.template == b.template
        if "rows" in a.result:
            assert (np.asarray(a.result["rows"])
                    == np.asarray(b.result["rows"])).all(), a.template
        else:
            assert (np.asarray(a.result["groups"])
                    == np.asarray(b.result["groups"])).all()
            np.testing.assert_allclose(np.asarray(a.result["scores"]),
                                       np.asarray(b.result["scores"]),
                                       rtol=1e-5)


# -------------------------------------------------- overflow accounting
def _overflow_world(union_cap: int, group_union_cap: int = 1024):
    cat = Catalog([TableSchema("t", ("a", "b", "g"), 256)])
    tpls = [
        QueryTemplate("sorted_all", "t", preds=(Pred("t", "a"),),
                      sort_col="b", limit=8),
        QueryTemplate("grouped_all", "t", preds=(Pred("t", "a"),),
                      group=GroupAgg("g", 8, "b", top_k=4)),
    ]
    plan = compile_plan(cat, tpls, {"sorted_all": 32, "grouped_all": 32},
                        max_results=8, union_cap=union_cap,
                        group_union_cap=group_union_cap)
    rng = np.random.default_rng(0)
    data = {"t": {"a": np.arange(256), "b": rng.integers(0, 100, 256),
                  "g": rng.integers(0, 8, 256)}}
    return SharedDBEngine(plan, UpdateSlots(1, 1, 1), data, jit=False,
                          kernels="jnp")


def test_union_cap_overflow_is_counted():
    eng = _overflow_world(union_cap=16)
    eng.submit("sorted_all", {0: (0, INT_MAX)})    # wants all 256 rows
    eng.run_cycle()
    assert eng.last_overflow == 256 - 16
    # a selective query fits the cap: no overflow
    eng.submit("sorted_all", {0: (0, 4)})
    eng.run_cycle()
    assert eng.last_overflow == 0


def test_group_union_cap_overflow_is_counted():
    eng = _overflow_world(union_cap=1024, group_union_cap=32)
    eng.submit("grouped_all", {0: (0, INT_MAX)})
    eng.run_cycle()
    assert eng.last_overflow == 256 - 32


def test_overflow_sums_across_stages():
    eng = _overflow_world(union_cap=16, group_union_cap=32)
    eng.submit("sorted_all", {0: (0, INT_MAX)})
    eng.submit("grouped_all", {0: (0, INT_MAX)})
    eng.run_cycle()
    assert eng.last_overflow == (256 - 16) + (256 - 32)


def test_compress_union_truncates_deterministically_from_tail():
    mask = jnp.asarray(np.full((40, 1), 1, np.uint32))
    rows, cmask, n_want = ops.compress_union(mask, 8)
    assert int(n_want) == 40
    assert np.asarray(rows).tolist() == list(range(8))

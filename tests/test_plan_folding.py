"""Dynamic plan folding differential suite (PR-8 tentpole acceptance).

A template registered MID-STREAM against a running ``SharedDBEngine``
(through ``QueryCycleServer.register_template``) must be served after at
most one migration (full-rescan) beat, ticket-for-ticket identical to a
COLD engine compiled with the final template set from the start — at
shard counts 1/2/4 on both operator backends.  The streams below drive
every fold beat class:

  * registration while the old compiled heartbeat keeps serving
    (background build leg: base-template beats are served the whole
    time the extended plan compiles on the fold thread);
  * a fold requested while a dirty-overflow reseed beat is IN FLIGHT —
    the commit drains the in-flight beat, migrates the carries, and the
    forced full-rescan migration beat reseeds under the new layout;
  * batched registrations (second/third template arrive while a fold is
    in flight) with queries for not-yet-folded templates HELD at the
    server and flushed after their fold's migration beat;
  * post-fold steady state: slot-stable delta beats back on the single
    fused launch (counting backend: ``fused_delta == 1``, no chained
    delta ops), proving the swap didn't knock the engine off the fast
    path.

Unit tests cover ``extend_plan`` prefix stability + rejection rules and
``migrate_carry`` (zero-padded width extension of carried scan words,
newly-predicated reseed).  A ``python -O`` subprocess proves the
carry/layout guard is a real ``RuntimeError``, not a strippable assert
(the fold migration path routes through the same check).
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import folding
from repro.core.executor import SharedDBEngine, check_carry_layout
from repro.core.lowering import check_extension_prefix, lower_plan
from repro.core.plan import Pred, QueryTemplate, compile_plan
from repro.serving import QueryCycleServer
from repro.workloads import tpcw

SCALE_I, SCALE_C = 64, 128
N_BASE = 10      # templates compiled at startup; the last three
#                  (order_lines / order_display / get_cart) fold in
#                  mid-stream — they add a newly-predicated column on
#                  order_line and shopping_cart_line's first scan stage
#                  while keeping the mirrored PK set unchanged

# delta ops the fused launch must fully absorb (test_fused_delta idiom)
CHAINED_DELTA_OPS = ("scan", "scan_delta", "join_delta",
                     "join_partitioned", "join_block")


def _split_workload(dense_pk_index=False):
    catalog = tpcw.make_catalog(SCALE_I, SCALE_C,
                                dense_pk_index=dense_pk_index)
    items_cap = catalog.schemas["item"].capacity
    templates, caps = tpcw.make_templates(items_cap)
    base = compile_plan(catalog, templates[:N_BASE],
                        {t.name: caps[t.name]
                         for t in templates[:N_BASE]})
    return templates, caps, base


# ------------------------------------------------------------ unit: IR
def test_extend_plan_is_prefix_stable():
    """Extension preserves every admitted template's slot range and cap,
    appends the new ones, and equals the cold compile of the final set
    — the invariant the atomic swap relies on."""
    templates, caps, base = _split_workload()
    new = templates[N_BASE:]
    ext = folding.extend_plan(base, new,
                              {t.name: caps[t.name] for t in new})
    for name in base.templates:
        assert ext.offsets[name] == base.offsets[name]
        assert ext.caps[name] == base.caps[name]
    assert list(ext.templates) == [t.name for t in templates]
    cold = compile_plan(base.catalog, list(templates), caps)
    assert ext.offsets == cold.offsets and ext.qcap == cold.qcap
    # the lowered IR extends prefix-stably too (stage order, windows,
    # join/sort/group keys) — checked by the guard the migration uses
    check_extension_prefix(lower_plan(base), lower_plan(ext))


def test_extend_plan_rejects_bad_folds():
    templates, caps, base = _split_workload()
    t = templates[N_BASE]
    with pytest.raises(folding.FoldError):
        folding.extend_plan(base, [t], {})                # missing cap
    with pytest.raises(folding.FoldError):
        folding.extend_plan(base, [t], {t.name: 0})       # bad cap
    with pytest.raises(folding.FoldError):                # name in use
        folding.extend_plan(base, [templates[0]],
                            {templates[0].name: 8})
    with pytest.raises(folding.FoldError):                # dup in batch
        folding.extend_plan(base, [t, t], {t.name: 8})
    alien = QueryTemplate("alien", "no_such_table",
                          preds=(Pred("no_such_table", "x"),))
    with pytest.raises(folding.FoldError):                # new table
        folding.extend_plan(base, [alien], {"alien": 8})


def test_migrate_carry_width_extends_and_reseeds():
    """Carried scan words are width-extended with an exactly-zero region
    for the appended slots (un-admitted slots bind no rows); a fold that
    newly predicates a table cannot extend and reseeds instead."""
    templates, caps, base = _split_workload()
    eng = SharedDBEngine(base, tpcw.DEFAULT_UPDATE_SLOTS,
                         tpcw.generate_data(np.random.default_rng(0),
                                            SCALE_I, SCALE_C),
                         jit=False, kernels="jnp")
    eng.submit("get_book", {0: (5, 5)})
    eng.submit("search_subject", {0: (2, 2)})
    eng.run_until_drained()
    assert eng._carry is not None

    # a new item-spine template pushes the item stage's slot window past
    # its old word boundary without adding joins: pure width extension
    hot = QueryTemplate("item_hot", "item",
                        preds=(Pred("item", "i_subject"),), limit=5)
    ext = folding.extend_plan(base, [hot], {"item_hot": 32})
    old_l = eng._lowered
    new_l = lower_plan(ext, key_stats=eng._key_stats)
    carry, rids = folding.migrate_carry(old_l, new_l, eng._carry,
                                        eng._rid_carry)
    assert carry is not None and rids is not None
    st = {s.table: s for s in new_l.scans}["item"]
    ost = {s.table: s for s in old_l.scans}["item"]
    old_w = ost.whi - ost.wlo
    w = np.asarray(carry["scan"]["item"])
    assert w.shape[1] == st.whi - st.wlo > old_w
    assert (w[:, old_w:] == 0).all()          # appended slots: no rows
    np.testing.assert_array_equal(
        w[:, :old_w], np.asarray(eng._carry["scan"]["item"]))

    # order_line gains its FIRST predicated column -> no carried words
    # exist for that stage -> the scan half reseeds (returns None)
    probe = QueryTemplate("ol_probe", "order_line",
                          preds=(Pred("order_line", "ol_o_id"),),
                          limit=4)
    ext2 = folding.extend_plan(base, [probe], {"ol_probe": 8})
    carry2, _ = folding.migrate_carry(
        old_l, lower_plan(ext2, key_stats=eng._key_stats),
        eng._carry, eng._rid_carry)
    assert carry2 is None


# ------------------------------------------- unit: carry/layout guard
_O_SCRIPT = textwrap.dedent("""
    import sys
    sys.path.insert(0, "src")
    assert True or sys.exit("asserts must be stripped under -O")
    import numpy as np
    from repro.core.executor import SharedDBEngine, check_carry_layout
    from repro.workloads import tpcw

    try:
        check_carry_layout(("stale",), ("fresh",))
    except RuntimeError:
        print("GUARD_FN_OK")

    plan = tpcw.build_tpcw_plan(16, 32)
    eng = SharedDBEngine(plan, tpcw.DEFAULT_UPDATE_SLOTS,
                         tpcw.generate_data(np.random.default_rng(0),
                                            16, 32),
                         jit=False, kernels="jnp")
    eng.submit("get_book", {0: (5, 5)})
    eng.run_until_drained()
    eng.submit("get_book", {0: (5, 5)})      # delta-eligible beat
    eng._carry_token = ("stale",)            # carry from another layout
    try:
        eng.dispatch()
    except RuntimeError as e:
        assert True or None
        if "admission layout" in str(e):
            print("GUARD_DISPATCH_OK")
""")


def test_carry_layout_guard_survives_python_O():
    """The guard the fold migration routes through must hold with
    assertions disabled: a bare assert would vanish under ``python -O``
    and let a delta beat consume a carry from another layout."""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ, JAX_PLATFORMS="cpu")
    out = subprocess.run([sys.executable, "-O", "-c", _O_SCRIPT],
                         capture_output=True, text=True, timeout=600,
                         cwd=repo, env=env)
    assert "GUARD_FN_OK" in out.stdout, (out.stdout, out.stderr[-2000:])
    assert "GUARD_DISPATCH_OK" in out.stdout, (out.stdout,
                                               out.stderr[-2000:])


def test_carry_layout_guard_in_process():
    with pytest.raises(RuntimeError, match="admission layout"):
        check_carry_layout(("a", 1), ("a", 2))
    check_carry_layout(("a", 1), ("a", 1))    # match passes


# ---------------------------------------------- differential fold world
def _compare(tag, a, b):
    """Fold-engine ticket vs cold-engine ticket (row-id set / score
    multiset — the established sharded-suite idiom)."""
    ra, rb = a.result, b.result
    assert ra is not None and rb is not None, (tag, a.template)
    if "rows" in ra:
        sa = set(int(x) for x in np.asarray(ra["rows"]) if x >= 0)
        sb = set(int(x) for x in np.asarray(rb["rows"]) if x >= 0)
        assert sa == sb, (tag, a.template, a.params,
                          sorted(sa)[:5], sorted(sb)[:5])
    else:
        np.testing.assert_allclose(
            np.sort(np.asarray(ra["scores"]).ravel()),
            np.sort(np.asarray(rb["scores"]).ravel()), rtol=1e-6,
            err_msg=f"{tag}:{a.template}")


class _FoldWorld:
    """A folding engine (base plan + ``QueryCycleServer``) against a
    COLD engine compiled with the final template set, same backend and
    mesh, compared ticket-for-ticket and snapshot-for-snapshot."""

    def __init__(self, mesh, backend: str, background: bool = False):
        self.templates, self.caps, base = _split_workload()
        full = compile_plan(base.catalog, list(self.templates),
                            self.caps)
        self.plan = base
        data = lambda: tpcw.generate_data(  # noqa: E731
            np.random.default_rng(0), SCALE_I, SCALE_C)
        self.eng = SharedDBEngine(base, tpcw.DEFAULT_UPDATE_SLOTS,
                                  data(), kernels=backend, mesh=mesh)
        self.server = QueryCycleServer(self.eng,
                                       background_folds=background)
        self.cold = SharedDBEngine(full, tpcw.DEFAULT_UPDATE_SLOTS,
                                   data(), kernels=backend, mesh=mesh)
        self.pairs = []           # (fold ticket, cold ticket) unserved

    def tmpl(self, name):
        return next(t for t in self.templates if t.name == name)

    def register(self, name):
        return self.server.register_template(self.tmpl(name),
                                             self.caps[name])

    def submit(self, name, params):
        self.pairs.append((self.server.submit(name, params),
                           self.cold.submit(name, params)))

    def queue_update(self, update):
        self.server.submit_update(*update)
        self.cold.submit_update(*update)

    def heartbeat(self, **kw):
        out = self.server.heartbeat(**kw)
        self.cold.run_until_drained()
        still = []
        for a, b in self.pairs:
            assert b.result is not None, b.template
            if a.result is None:      # held across a fold in flight
                still.append((a, b))
            else:
                _compare("fold", a, b)
        self.pairs = still
        return out

    def finish(self):
        assert not self.pairs, [a.template for a, _ in self.pairs]
        for table in ("item", "customer", "order_line"):
            got, want = self.eng.snapshot(table), self.cold.snapshot(table)
            for col in self.plan.catalog.schemas[table].columns:
                assert (got[col] == want[col]).all(), (table, col)
            assert (got["_valid"] == want["_valid"]).all(), table


def _drive_fold_stream(w: _FoldWorld, batched: bool):
    # ---- base-plan beats: seed, then slot-stable carried deltas
    w.submit("get_book", {0: (5, 5)})
    w.submit("search_subject", {0: (2, 2)})
    w.heartbeat()
    assert w.eng.last_scan_path == "full"
    for i in range(2):
        w.queue_update(("customer", "update",
                        {"key": 3 + i, "col": "c_expiration",
                         "val": 900 + i}))
        w.submit("get_customer", {0: (7 + i, 7 + i)})
        w.submit("get_book", {0: (5, 5)})
        w.heartbeat()
    assert w.eng.delta_cycles >= 1

    # ---- dirty-overflow reseed beat DISPATCHED (in flight), then the
    # fold is requested against it: commit must drain the reseed beat,
    # migrate the carries and force the full-rescan migration beat
    dirty_cap = w.plan.catalog.schemas["item"].dirty_cap
    n_upd = min(tpcw.DEFAULT_UPDATE_SLOTS.n_update, dirty_cap)
    for k in range(n_upd):
        w.queue_update(("item", "update",
                        {"key": k, "col": "i_stock", "val": 1}))
    for k in range(n_upd, dirty_cap + 1):
        w.queue_update(("item", "delete", {"key": k}))
    w.submit("get_book", {0: (5, 5)})
    w.eng.dispatch()                      # reseed beat in flight
    assert w.eng.in_flight() == 1

    if batched:
        # registrations arrive one at a time: the first starts a fold,
        # the rest batch behind it (two migration beats total)
        r1 = w.register("order_lines")
        assert r1["status"] == "folding"
        assert "background" in r1["recipe"]["steps"][0]
        assert w.register("order_display")["status"] == "batched"
        assert w.register("get_cart")["status"] == "batched"
    else:
        # the whole final set folds in as ONE batch -> one migration beat
        out = w.server.register_templates(
            [(w.tmpl(n), w.caps[n])
             for n in ("order_lines", "order_display", "get_cart")])
        assert all(r["status"] == "folding" for r in out)

    # queries for the folding templates: order_lines' queue is already
    # open (its fold began); batched templates are HELD at the server
    w.submit("order_lines", {0: (10, 10)})
    w.submit("get_cart", {0: (12, 12)})
    w.submit("order_display", {0: (9, 9)})
    w.heartbeat()
    assert w.eng.folds_done == (2 if batched else 1)
    assert not w.pairs                    # served within one client call
    assert w.eng.last_delta_overflow == 0

    # ---- post-fold steady state: vary ONLY order_lines' params so the
    # changed admission words stay inside each stage's delta pane
    for i in range(3):
        w.queue_update(("customer", "update",
                        {"key": 5 + i, "col": "c_expiration",
                         "val": 40 + i}))
        w.submit("order_lines", {0: (20 + i, 20 + i)})
        w.submit("get_cart", {0: (12, 12)})
        w.submit("get_book", {0: (5, 5)})
        w.heartbeat()
    assert w.eng.last_scan_path == "delta"
    if w.eng._carried_joins:
        assert w.eng.last_join_path == "delta"
    w.finish()


# on a pinned CI leg each backend's configs run on its own matrix
# entry (the test_sharded_engine convention, minus the duplication);
# an unpinned local run covers all six
_LEG = os.environ.get("REPRO_KERNELS", "")


@pytest.mark.parametrize("shards,backend", [
    (1, "jnp"), (2, "jnp"), (4, "jnp"),
    (1, "pallas"), (2, "pallas"), (4, "pallas")])
def test_fold_differential_stream(row_mesh, shards, backend):
    """Mid-stream registration at this shard count and backend:
    ticket-for-ticket + snapshot parity vs the cold final-set engine,
    including the fold-during-reseed-in-flight beat."""
    if _LEG in ("jnp", "pallas") and backend != _LEG:
        pytest.skip(f"{backend} configs run on the {backend} leg")
    w = _FoldWorld(row_mesh(shards), backend)
    _drive_fold_stream(w, batched=(shards == 1 and backend == "jnp"))


def test_background_fold_keeps_serving():
    """The background build leg: base-template beats keep being served
    (every ticket routed the same heartbeat) while the extended plan
    compiles on the fold thread; the held get_cart query is served right
    after the migration beat, identical to the cold engine."""
    if _LEG == "pallas":
        pytest.skip("jnp-pinned engines; runs on the jnp leg")
    w = _FoldWorld(None, "jnp", background=True)
    w.submit("get_book", {0: (5, 5)})
    w.heartbeat()
    assert w.register("get_cart")["status"] == "folding"
    w.submit("get_cart", {0: (12, 12)})   # queued behind the fold
    served_during_build = 0
    for i in range(600):
        if w.eng.folds_done:
            break
        in_flight = w.eng.fold_in_flight() and not w.eng.fold_ready()
        w.queue_update(("customer", "update",
                        {"key": 3 + (i % 8), "col": "c_expiration",
                         "val": 100 + i}))
        w.submit("get_customer", {0: (7, 7)})
        w.submit("get_book", {0: (5, 5)})
        w.heartbeat()                     # old plan keeps serving
        if in_flight:
            served_during_build += 1
    assert w.eng.folds_done == 1
    assert served_during_build >= 1       # never stopped the world
    w.heartbeat()                         # drain the get_cart ticket
    w.finish()


def test_second_fold_while_in_flight_is_rejected():
    """The engine serializes folds — batching is the SERVER's job."""
    templates, caps, base = _split_workload()
    eng = SharedDBEngine(base, tpcw.DEFAULT_UPDATE_SLOTS,
                         tpcw.generate_data(np.random.default_rng(0),
                                            SCALE_I, SCALE_C),
                         jit=False, kernels="jnp")
    t1, t2 = templates[N_BASE], templates[N_BASE + 1]
    eng.begin_fold([t1], {t1.name: caps[t1.name]}, background=False)
    with pytest.raises(RuntimeError, match="fold"):
        eng.begin_fold([t2], {t2.name: caps[t2.name]},
                       background=False)


# ------------------------------------------------- fused-launch parity
def _indexless_fold_engine():
    """No dense PK index -> every join on a carried access path, jit
    off -> per-beat backend op counts (the test_fused_delta idiom);
    ``kernels='auto'`` honors REPRO_KERNELS so both CI legs cover it."""
    templates, caps, base = _split_workload(dense_pk_index=False)
    eng = SharedDBEngine(base, tpcw.DEFAULT_UPDATE_SLOTS,
                         tpcw.generate_data(np.random.default_rng(0),
                                            SCALE_I, SCALE_C),
                         jit=False, kernels="auto")
    return eng, templates, caps


def _assert_single_fused_launch(beat):
    assert beat.scan_path == "delta" and beat.join_path == "delta", \
        (beat.scan_path, beat.join_path)
    assert beat.backend_ops.get("fused_delta", 0) == 1, beat.backend_ops
    for op in CHAINED_DELTA_OPS:
        assert beat.backend_ops.get(op, 0) == 0, (op, beat.backend_ops)


def test_fold_keeps_single_fused_launch():
    """Steady delta beats before AND after a fold run as ONE fused
    launch with no chained delta ops — the swap must not knock the
    engine off the fast path (acceptance gate for PR-8)."""
    eng, templates, caps = _indexless_fold_engine()

    def beat(subs, upd_key=None):
        if upd_key is not None:
            eng.submit_update("customer", "update",
                              {"key": upd_key, "col": "c_expiration",
                               "val": 100 + upd_key})
        for name, params in subs:
            eng.submit(name, params)
        return eng.run_until_drained()

    pre = [("get_book", {0: (5, 5)})]
    beat(pre)                                     # seed (full rescan)
    for i in range(3):
        res = beat(pre, upd_key=3 + i)
    _assert_single_fused_launch(res[-1])          # pre-fold steady

    eng.begin_fold(templates[N_BASE:],
                   {t.name: caps[t.name] for t in templates[N_BASE:]},
                   background=False)
    post = [("order_lines", {0: (7, 7)}), ("get_cart", {0: (12, 12)}),
            ("get_book", {0: (5, 5)})]
    res = beat(post)                              # migration beat
    assert eng.folds_done == 1
    assert res[-1].scan_path == "full"
    for i in range(3):
        res = beat(post, upd_key=6 + i)
    _assert_single_fused_launch(res[-1])          # post-fold steady

"""Partitioned shared join: property tests vs the dense block-join oracle
(duplicate keys, empty buckets, all-invalid rows, capacity-boundary
padding), jnp/pallas kernel parity, lowering access-path selection, and a
full-engine jnp-vs-pallas parity run over index-less TPC-W."""
import jax.numpy as jnp
import numpy as np
import pytest

try:        # property tests engage when hypothesis is available; the
    # deterministic sweep below always runs
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core.executor import SharedDBEngine
from repro.core.lowering import (PARTITIONED_MIN_CAPACITY, lower_plan,
                                 partition_layout)
from repro.core.storage import build_key_partitions
from repro.kernels import ref
from repro.kernels.partitioned_join import partitioned_join_pallas
from repro.workloads import tpcw

INT_MAX = 2147483647


def _world(seed, Tr, Tl, W, valid_frac, n_partitions, bucket_cap):
    rng = np.random.default_rng(seed)
    # unique keys, sparse + shuffled (INT_MAX excluded: reserved sentinel)
    keys_r = jnp.asarray(rng.permutation(Tr * 3)[:Tr] - 2, jnp.int32)
    valid_r = jnp.asarray(rng.random(Tr) < valid_frac)
    keys_l = jnp.asarray(rng.integers(-3, Tr * 3, Tl), jnp.int32)
    mask_l = jnp.asarray(rng.integers(0, 2**32, (Tl, W)), jnp.uint32)
    mask_r = jnp.asarray(rng.integers(0, 2**32, (Tr, W)), jnp.uint32)
    parts = build_key_partitions(keys_r, valid_r, n_partitions, bucket_cap)
    return keys_l, mask_l, keys_r, mask_r, valid_r, parts


def _check_against_oracle(seed, Tr, Tl, W, valid_frac, bucket_cap,
                          extra_parts, pallas=False):
    n_partitions = -(-Tr // bucket_cap) + extra_parts
    keys_l, mask_l, keys_r, mask_r, valid_r, parts = _world(
        seed, Tr, Tl, W, valid_frac, n_partitions, bucket_cap)
    want_rid, want_mask = ref.bitmask_join_ref(keys_l, mask_l, keys_r,
                                               mask_r, valid_r)
    got_rid, got_mask = ref.partitioned_join_ref(keys_l, mask_l, *parts,
                                                 mask_r)
    assert (np.asarray(got_rid) == np.asarray(want_rid)).all()
    assert (np.asarray(got_mask) == np.asarray(want_mask)).all()
    if pallas:
        r2, m2 = partitioned_join_pallas(keys_l, mask_l, *parts, mask_r)
        assert (np.asarray(r2) == np.asarray(want_rid)).all()
        assert (np.asarray(m2) == np.asarray(want_mask)).all()


@pytest.mark.parametrize("seed,Tr,Tl,W,valid_frac,bucket_cap,extra", [
    (0, 160, 120, 2, 0.8, 48, 0),    # plain
    (1, 130, 300, 1, 0.2, 7, 3),     # sparse valid rows -> empty buckets
    (2, 64, 64, 3, 0.0, 16, 1),      # all-invalid table
    (3, 257, 129, 2, 1.0, 32, 0),    # capacity-boundary padding
    (4, 1, 1, 1, 1.0, 1, 2),         # degenerate single row
    (5, 300, 260, 2, 0.9, 256, 0),   # one tile-sized bucket + remainder
])
def test_partitioned_join_matches_block_oracle_sweep(seed, Tr, Tl, W,
                                                     valid_frac,
                                                     bucket_cap, extra):
    """Deterministic edge-case sweep (runs with or without hypothesis):
    empty buckets, all-invalid rows, non-divisible capacities."""
    _check_against_oracle(seed, Tr, Tl, W, valid_frac, bucket_cap, extra,
                          pallas=True)


if HAVE_HYPOTHESIS:
    @settings(max_examples=40, deadline=None)
    @given(seed=st.integers(0, 10_000), Tr=st.integers(1, 160),
           Tl=st.integers(1, 120), W=st.integers(1, 3),
           valid_frac=st.sampled_from([0.0, 0.2, 0.8, 1.0]),
           bucket_cap=st.integers(1, 48), extra_parts=st.integers(0, 3))
    def test_partitioned_join_matches_block_oracle(seed, Tr, Tl, W,
                                                   valid_frac, bucket_cap,
                                                   extra_parts):
        """Any bucket layout whose capacity covers the table is exact."""
        _check_against_oracle(seed, Tr, Tl, W, valid_frac, bucket_cap,
                              extra_parts)

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 10_000), Tr=st.integers(1, 140),
           Tl=st.integers(1, 120), bucket_cap=st.integers(1, 48))
    def test_partitioned_join_pallas_parity(seed, Tr, Tl, bucket_cap):
        """The Pallas kernel (interpret mode) == the jnp reference probe
        on awkward non-tile-multiple shapes."""
        _check_against_oracle(seed, Tr, Tl, 2, 0.7, bucket_cap, 0,
                              pallas=True)


def test_duplicate_valid_keys_resolve_to_max_row():
    """Duplicates sort adjacently with row id ascending, so the probed
    (last) bucket holds the highest-row duplicate — the block join's
    resolution rule — even when duplicates straddle a bucket boundary."""
    keys_r = jnp.asarray([5, 7, 7, 7, 7, 9], jnp.int32)
    valid_r = jnp.ones(6, bool)
    mask_r = jnp.asarray(np.arange(1, 7)[:, None], jnp.uint32)
    keys_l = jnp.asarray([5, 7, 9, 8], jnp.int32)
    mask_l = jnp.full((4, 1), 0xFF, jnp.uint32)
    # bucket_cap=2: sorted keys [5,7 | 7,7 | 7,9] — the 7s straddle two
    # boundaries; the probe must land on the bucket holding row 4
    parts = build_key_partitions(keys_r, valid_r, 3, 2)
    rid, mask = ref.partitioned_join_ref(keys_l, mask_l, *parts, mask_r)
    assert np.asarray(rid).tolist() == [0, 4, 5, -1]
    expect = np.where(np.asarray(rid)[:, None] >= 0,
                      0xFF & np.asarray(mask_r)[np.maximum(rid, 0)], 0)
    assert (np.asarray(mask) == expect).all()
    r2, m2 = partitioned_join_pallas(keys_l, mask_l, *parts, mask_r)
    assert (np.asarray(r2) == np.asarray(rid)).all()
    assert (np.asarray(m2) == np.asarray(mask)).all()


def test_partition_layout_covers_capacity():
    for cap in (1, 7, 255, 256, 257, 512, 4096, 10001):
        n, b = partition_layout(cap)
        assert n * b >= cap
        assert b <= max(cap, 1)


# --------------------------------------------- lowering access-path choice
def test_lowering_selects_partitioned_join_from_capacities():
    """Index-less PK tables pick partitioned vs block by capacity; the
    dense-index configuration keeps the O(1) gather."""
    plan = tpcw.build_tpcw_plan(128, 256, dense_pk_index=False)
    low = lower_plan(plan)
    kinds = {(j.spine, j.pk_table): j.kind for j in low.joins}
    # author/orders/item capacities all exceed the partition threshold
    assert kinds[("item", "author")] == "partitioned"
    assert kinds[("order_line", "orders")] == "partitioned"
    assert kinds[("order_line", "item")] == "partitioned"
    for j in low.joins:
        if j.kind == "partitioned":
            cap = plan.catalog.schemas[j.pk_table].capacity
            assert cap >= PARTITIONED_MIN_CAPACITY
            assert j.n_partitions * j.bucket_cap >= cap
    # with the dense index, every join remains a gather
    low_ix = lower_plan(tpcw.build_tpcw_plan(128, 256))
    assert {j.kind for j in low_ix.joins} == {"gather"}


# ------------------------------------------- full-engine parity over TPC-W
QUERIES = [
    ("get_customer", {0: (7, 7)}),
    ("get_book", {0: (5, 5)}),
    ("search_subject", {0: (3, 3)}),
    ("search_author", {0: (100, 120)}),
    ("new_products", {0: (2, 2)}),
    ("best_sellers", {0: (0, INT_MAX), 1: (2, 2)}),
    ("order_lines", {0: (10, 10)}),
    ("order_display", {0: (17, 17)}),
    ("get_cart", {0: (12, 12)}),
]


@pytest.fixture(scope="module")
def indexless_world():
    rng = np.random.default_rng(5)
    plan = tpcw.build_tpcw_plan(128, 256, dense_pk_index=False)
    data = tpcw.generate_data(rng, 128, 256)
    return plan, data


def test_engine_jnp_vs_pallas_parity_partitioned_tpcw(indexless_world):
    """Acceptance: the full engine produces identical results on both
    backends when every TPC-W join runs the partitioned access path."""
    plan, data = indexless_world
    assert any(j.kind == "partitioned" for j in lower_plan(plan).joins)
    tickets = []
    for kernels in ("jnp", "pallas"):
        eng = SharedDBEngine(plan, tpcw.DEFAULT_UPDATE_SLOTS, data,
                             jit=False, kernels=kernels)
        tickets.append([eng.submit(n, p) for n, p in QUERIES])
        eng.run_cycle()
    for a, b in zip(*tickets):
        assert a.template == b.template
        if "rows" in a.result:
            assert (np.asarray(a.result["rows"])
                    == np.asarray(b.result["rows"])).all(), a.template
        else:
            assert (np.asarray(a.result["groups"])
                    == np.asarray(b.result["groups"])).all()
            np.testing.assert_allclose(np.asarray(a.result["scores"]),
                                       np.asarray(b.result["scores"]),
                                       rtol=1e-5)


def test_partitioned_engine_matches_query_at_a_time(indexless_world):
    """The partitioned path answers exactly like the baseline engine,
    including after updates force a partition rebuild."""
    from repro.core.baseline import QueryAtATimeEngine
    plan, data = indexless_world
    eng = SharedDBEngine(plan, tpcw.DEFAULT_UPDATE_SLOTS, data, jit=False,
                         kernels="jnp")
    base = QueryAtATimeEngine(plan, data, jit=False)
    upd = ("item", "update", {"key": 5, "col": "i_cost", "val": 4242})
    eng.submit_update(*upd)
    base.apply_update(*upd)
    tickets = [eng.submit(n, p) for n, p in QUERIES]
    eng.run_cycle()
    for t in tickets:
        want = base.execute(t.template, t.params).result
        if "rows" in t.result:
            a = set(int(x) for x in np.asarray(t.result["rows"]) if x >= 0)
            b = set(int(x) for x in want["rows"] if x >= 0)
            assert a == b, t.template
        else:
            np.testing.assert_allclose(
                np.sort(np.asarray(t.result["scores"])),
                np.sort(np.asarray(want["scores"])), rtol=1e-6)

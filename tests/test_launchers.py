"""Integration: the real train/serve drivers end-to-end on CPU (reduced
configs, real optimizer steps / real decode cycles)."""
import numpy as np
import pytest

from repro.launch import serve, train


def test_train_driver_loss_improves(tmp_path):
    log = train.main(["--arch", "yi-6b", "--smoke", "--steps", "14",
                      "--batch", "4", "--seq", "32",
                      "--ckpt", str(tmp_path), "--save-every", "5"])
    losses = [m["loss"] for m in log]
    assert len(losses) == 14
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0]


def test_train_driver_resumes_from_checkpoint(tmp_path):
    train.main(["--arch", "mamba2-370m", "--smoke", "--steps", "10",
                "--batch", "4", "--seq", "32", "--ckpt", str(tmp_path),
                "--save-every", "5"])
    # second invocation resumes from step 10 and continues to 16
    log = train.main(["--arch", "mamba2-370m", "--smoke", "--steps", "16",
                      "--batch", "4", "--seq", "32", "--ckpt",
                      str(tmp_path), "--save-every", "5"])
    assert log[0]["step"] == 10
    assert log[-1]["step"] == 15


def test_serve_driver_completes_all_requests():
    done = serve.main(["--arch", "stablelm-1.6b", "--smoke",
                       "--requests", "6", "--capacity", "3",
                       "--max-seq", "48", "--prefill-len", "8",
                       "--new-tokens", "4"])
    assert len(done) == 6
    assert all(len(r.output) == 4 for r in done)

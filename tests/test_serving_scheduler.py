"""CycleServer correctness regressions (serving/scheduler.py).

Two admission/collection bugs fixed in this suite's presence:

  * short prompts are right-padded to the compiled prefill length, and
    the first token used to be read from the final PAD position's
    logits instead of the true last prompt token's;
  * generations reaching the KV-cache capacity used to pin at the last
    cache position, overwriting the same KV entry every step instead of
    finishing the request.

Kept hypothesis-free so the regressions gate on every environment.
"""
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.models.registry import get_model
from repro.serving import CycleServer


def test_short_prompt_first_token_matches_unpadded_prefill():
    """Regression: prompts shorter than prefill_len are right-padded, so
    the first token must come from the TRUE last prompt position — the
    logits at the final pad position belong to a pad token.  Under
    causal attention the unpadded prefill is the exact oracle."""
    cfg = smoke_config("stablelm-1.6b")
    srv = CycleServer(cfg, capacity=2, max_seq=32, prefill_len=8)
    api = get_model(cfg)
    for prompt in ([5, 17, 3], [9], list(range(1, 8))):
        r = srv.submit(list(prompt), max_new_tokens=1)
        srv.run_until_drained()
        logits, _ = api.prefill(
            srv.params, {"tokens": jnp.asarray([prompt], jnp.int32)},
            cache_capacity=32)
        assert r.output[0] == int(jnp.argmax(logits[0])), prompt


def test_empty_prompt_degenerates_to_pad_conditioning():
    """An empty prompt has no last token: it conditions on the single
    pad token at position 0 (last_pos clamps to 0, never -1) and still
    completes cleanly."""
    cfg = smoke_config("stablelm-1.6b")
    srv = CycleServer(cfg, capacity=1, max_seq=16, prefill_len=4)
    api = get_model(cfg)
    r = srv.submit([], max_new_tokens=2)
    srv.run_until_drained(max_cycles=20)
    assert len(r.output) == 2 and r.done_time is not None
    logits, _ = api.prefill(
        srv.params, {"tokens": jnp.asarray([[0]], jnp.int32)},
        cache_capacity=16)
    assert r.output[0] == int(jnp.argmax(logits[0]))


def test_full_length_prompt_unchanged_by_last_pos_fix():
    """A prompt exactly prefill_len long takes the same first token as
    before the fix (last real position == last position)."""
    cfg = smoke_config("stablelm-1.6b")
    srv = CycleServer(cfg, capacity=1, max_seq=32, prefill_len=8)
    api = get_model(cfg)
    prompt = list(range(1, 9))
    r = srv.submit(prompt, max_new_tokens=1)
    srv.run_until_drained()
    logits, _ = api.prefill(
        srv.params, {"tokens": jnp.asarray([prompt], jnp.int32)},
        cache_capacity=32)
    assert r.output[0] == int(jnp.argmax(logits[0]))


def test_cap_hit_force_finishes_cleanly():
    """Regression: a generation reaching max_seq must complete (marked
    truncated) instead of pinning at the last cache position — and the
    freed slot must keep serving new requests."""
    cfg = smoke_config("stablelm-1.6b")
    srv = CycleServer(cfg, capacity=2, max_seq=16, prefill_len=8,
                      prefill_budget=2)
    prompt = list(range(1, 9))
    r = srv.submit(prompt, max_new_tokens=64)     # wants more than fits
    done = srv.run_until_drained(max_cycles=200)
    # positions 8..15 decode (8 steps) + the prefill token = 9 tokens
    assert r in done
    assert r.truncated
    assert r.done_time is not None
    assert len(r.output) == 9 < 64
    assert srv.active() == 0
    # positions never left the cache
    assert (srv._pos < srv.max_seq).all()
    # the slot is reusable and exact afterwards
    r2 = srv.submit(prompt, max_new_tokens=3)
    srv.run_until_drained(max_cycles=50)
    assert len(r2.output) == 3 and not r2.truncated


def test_mixed_cap_and_normal_completion_one_batch():
    """One slot hits the cap while its neighbour finishes normally —
    both route out of the same shared decode heartbeats."""
    cfg = smoke_config("stablelm-1.6b")
    srv = CycleServer(cfg, capacity=2, max_seq=12, prefill_len=4,
                      prefill_budget=2)
    long_r = srv.submit([1, 2, 3, 4], max_new_tokens=99)
    short_r = srv.submit([4, 3, 2], max_new_tokens=2)
    srv.run_until_drained(max_cycles=100)
    assert not short_r.truncated and len(short_r.output) == 2
    assert long_r.truncated
    # prefill token + decodes at positions 4..11 = 9 tokens
    assert len(long_r.output) == 9
    assert np.all(srv._pos < srv.max_seq)

"""Sharded-engine differential suite (PR-5 tentpole acceptance).

Ticket-for-ticket parity of ``SharedDBEngine(mesh=...)`` — shard counts
1/2/4, both operator backends — against the ``QueryAtATimeEngine``
oracle over the deterministic TPC-W stream, extending the PR-3/4
stateful harness with a shard count axis.  The index-less world drives
every carried-join beat class through the sharded data path:

  * carried-rid beats — customer-only updates leave every PK mirror
    untouched, dirty spine rows merge into the per-shard rid carries;
  * PK-write fallback beats — item updates rebuild the (replicated)
    partitions and force the full probe;
  * a dirty-overflow reseed beat — more touched item rows than
    ``dirty_cap`` forces the full rescan, re-seeding both carry halves
    across every shard.

Every heartbeat also checks snapshot parity (the sharded state
re-assembled by row range equals the oracle's tables column for
column), and a 1-shard mesh is asserted BIT-identical to the unsharded
engine — same result arrays in the same order, same scan/join path per
beat, same snapshots.

When hypothesis is installed, a rule-based machine explores random
interleavings with the shard count drawn per example (the "shard-count
rule" on top of the PR-3/4 machines); the deterministic streams below
always run.  ``REPRO_SHARD_STRESS=1`` (the CI sharded leg) lengthens
the deterministic stream.
"""
import os

import numpy as np
import pytest

from repro.core.baseline import QueryAtATimeEngine
from repro.core.executor import SharedDBEngine
from repro.workloads import tpcw

try:
    from hypothesis import settings, strategies as st
    from hypothesis.stateful import (RuleBasedStateMachine, initialize,
                                     rule)
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

SCALE_I, SCALE_C = 64, 128
INT_MAX = tpcw.INT_MAX
STRESS = os.environ.get("REPRO_SHARD_STRESS", "") not in ("", "0")


def _compare(tag, ticket, want):
    if "rows" in ticket.result:
        a = set(int(x) for x in np.asarray(ticket.result["rows"])
                if x >= 0)
        b = set(int(x) for x in want["rows"] if x >= 0)
        assert a == b, (tag, ticket.template, ticket.params,
                        sorted(a)[:5], sorted(b)[:5])
    else:
        np.testing.assert_allclose(
            np.sort(np.asarray(ticket.result["scores"]).ravel()),
            np.sort(np.asarray(want["scores"]).ravel()), rtol=1e-6,
            err_msg=f"{tag}:{ticket.template}")


class _ShardedWorld:
    """One sharded engine + the query-at-a-time oracle, compared
    ticket-for-ticket and snapshot-for-snapshot every heartbeat (the
    PR-3/4 ``_World`` pattern with a mesh under the engine)."""

    def __init__(self, mesh, backend: str, dense_pk_index: bool = False):
        rng = np.random.default_rng(0)
        self.plan = tpcw.build_tpcw_plan(SCALE_I, SCALE_C,
                                         dense_pk_index=dense_pk_index)
        data = tpcw.generate_data(rng, SCALE_I, SCALE_C)
        self.eng = SharedDBEngine(self.plan, tpcw.DEFAULT_UPDATE_SLOTS,
                                  data, kernels=backend, mesh=mesh)
        self.base = QueryAtATimeEngine(self.plan, data, jit=False)
        self.pending_updates = []
        self.pending_queries = []
        self.next_item = SCALE_I
        self.item_watermark = SCALE_I

    def queue_update(self, update):
        self.pending_updates.append(update)
        self.eng.submit_update(*update)

    def insert_item(self, subject, cost):
        i = self.next_item
        self.next_item += 1
        self.queue_update(("item", "insert", {
            "i_id": i, "i_a_id": i % max(SCALE_I // 4, 1),
            "i_subject": subject, "i_title": i % tpcw.N_TITLE_TOKENS,
            "i_pub_date": 11500, "i_cost": cost, "i_srp": cost + 100,
            "i_stock": 5, "i_related1": 0}))

    def submit(self, name, params):
        self.pending_queries.append(
            (name, params, self.eng.submit(name, params)))

    def heartbeat(self, pipelined: bool = False):
        for u in self.pending_updates:
            self.base.apply_update(*u)
        self.pending_updates = []
        self.eng.run_until_drained(pipelined=pipelined)
        for name, params, ticket in self.pending_queries:
            want = self.base.execute(name, params).result
            assert ticket.result is not None, name
            _compare("sharded", ticket, want)
        self.pending_queries = []
        self.item_watermark = self.next_item
        for table in ("item", "customer", "order_line"):
            got = self.eng.snapshot(table)
            want_t = self.base.state[table]
            for col in self.plan.catalog.schemas[table].columns:
                assert (got[col] == np.asarray(want_t[col])).all(), \
                    (table, col)
            assert (got["_valid"] == np.asarray(want_t["_valid"])).all(), \
                table


def _drive_deterministic_stream(w: _ShardedWorld):
    """Seed -> PK-write fallback -> carried-rid beats -> a wide beat
    (sort/group/route merges) -> dirty-overflow reseed -> recovery."""
    rng = np.random.default_rng(7)
    plan = w.plan

    def submit_joins(o_id):
        # slot-stable join admission (see test_differential_engine):
        # vary only one template's params so the PK-side admission pane
        # stays within its contiguous budget
        w.submit("order_lines", {0: (o_id, o_id)})
        w.submit("get_cart", {0: (12, 12)})
        w.submit("get_book", {0: (5, 5)})

    # seed + a PK-side-write beat (partitions rebuild -> full probe)
    submit_joins(10)
    w.heartbeat()
    assert w.eng.last_scan_path == "full"
    w.queue_update(("item", "update", {
        "key": int(rng.integers(0, SCALE_I)), "col": "i_cost",
        "val": int(rng.integers(100, 9999))}))
    submit_joins(11)
    w.heartbeat()
    if w.eng._carried_joins:
        assert w.eng.last_join_path == "full"

    # carried-rid beats: customer-only updates, join templates active
    n_carry = 5 if STRESS else 3
    for beat in range(n_carry):
        w.queue_update(("customer", "update", {
            "key": int(rng.integers(0, SCALE_C)),
            "col": "c_expiration",
            "val": int(rng.integers(12000, 15000))}))
        submit_joins(20 + beat)
        w.heartbeat()
    if w.eng._carried_joins:
        assert w.eng.delta_join_cycles >= n_carry - 1

    # wide beat: sort (mirrored spines), group-by and route merges, an
    # insert landing on the append shard, pipelined drain
    w.insert_item(3, 999)
    w.submit("best_sellers", {0: (0, INT_MAX), 1: (4, 4)})
    w.submit("order_display", {0: (9, 9)})
    w.submit("get_customer", {0: (5, 5)})
    w.submit("search_subject", {0: (2, 2)})
    w.submit("new_products", {0: (3, 3)})
    w.heartbeat(pipelined=True)

    # dirty-overflow reseed beat: touch more item rows than dirty_cap
    # holds in ONE cycle (updates + deletes on distinct committed keys)
    dirty_cap = plan.catalog.schemas["item"].dirty_cap
    slots = tpcw.DEFAULT_UPDATE_SLOTS
    n_upd = min(slots.n_update, dirty_cap)
    for k in range(n_upd):
        w.queue_update(("item", "update",
                        {"key": k, "col": "i_stock", "val": 1}))
    for k in range(n_upd, dirty_cap + 1):
        w.queue_update(("item", "delete", {"key": k}))
    submit_joins(30)
    w.heartbeat()
    assert w.eng.last_scan_path == "full"
    assert w.eng.last_delta_overflow == 0

    # recovery: the reseed re-seeded both carry halves on every shard
    w.queue_update(("customer", "update",
                    {"key": 1, "col": "c_expiration", "val": 14999}))
    submit_joins(31)
    w.heartbeat()
    if w.eng._carried_joins:
        assert w.eng.last_join_path == "delta"


@pytest.mark.parametrize("shards,backend", [
    (1, "jnp"), (2, "jnp"), (4, "jnp"),
    (1, "pallas"), (2, "pallas"), (4, "pallas")])
def test_sharded_differential_indexless_stream(row_mesh, shards, backend):
    """Ticket-for-ticket + snapshot parity vs the oracle over the
    deterministic index-less stream: every join on a carried access
    path, every beat class (carried / PK-write fallback / overflow
    reseed) exercised at this shard count and backend."""
    w = _ShardedWorld(row_mesh(shards), backend,
                      dense_pk_index=False)
    _drive_deterministic_stream(w)


@pytest.mark.parametrize("shards", [2, 4])
def test_sharded_differential_indexed_world(row_mesh, shards):
    """The dense-pk-index world (every join an O(1) gather): sharded
    spines still merge exactly against the oracle."""
    w = _ShardedWorld(row_mesh(shards), "jnp",
                      dense_pk_index=True)
    rng = np.random.default_rng(5)
    for beat in range(4 if STRESS else 3):
        w.queue_update(("customer", "update", {
            "key": int(rng.integers(0, SCALE_C)),
            "col": "c_expiration",
            "val": int(rng.integers(12000, 15000))}))
        # slot-stable admission on the wide item window (varying several
        # item-referencing templates at once would span more words than
        # the contiguous admission pane and legitimately force full
        # rescans); only get_customer's parameter varies — its changed
        # word stays inside the customer stage's own pane
        w.submit("admin_item", {0: (3, 3)})
        w.submit("get_customer",
                 {0: (int(rng.integers(0, SCALE_C)),) * 2})
        w.submit("order_lines", {0: (7, 7)})
        w.heartbeat()
    assert w.eng.delta_cycles >= 1


def test_mesh1_bit_identical_to_unsharded_engine(row_mesh):
    """Acceptance: at mesh size 1 the sharded engine reproduces the
    current engine BIT for bit — identical result arrays (order
    included), identical per-beat scan/join paths, identical snapshots
    — across full, delta, carried-join, insert and delete beats in
    both the indexed and index-less worlds."""
    mesh = row_mesh(1)
    for dense in (True, False):
        rng = np.random.default_rng(0)
        plan = tpcw.build_tpcw_plan(SCALE_I, SCALE_C,
                                    dense_pk_index=dense)
        data = tpcw.generate_data(rng, SCALE_I, SCALE_C)
        ref = SharedDBEngine(plan, tpcw.DEFAULT_UPDATE_SLOTS, data,
                             kernels="jnp")
        eng = SharedDBEngine(plan, tpcw.DEFAULT_UPDATE_SLOTS, data,
                             kernels="jnp", mesh=mesh)
        subs = [("admin_item", {0: (3, 3)}),
                ("get_customer", {0: (5, 5)}),
                ("search_subject", {0: (2, 2)}),
                ("order_lines", {0: (7, 7)}),
                ("get_cart", {0: (12, 12)}),
                ("best_sellers", {0: (0, INT_MAX), 1: (4, 4)}),
                ("order_display", {0: (9, 9)}),
                ("new_products", {0: (3, 3)})]
        for beat in range(4):
            if beat == 1:
                for e in (ref, eng):
                    e.submit_update("customer", "update",
                                    {"key": 2, "col": "c_expiration",
                                     "val": 14999})
            if beat == 2:
                for e in (ref, eng):
                    e.submit_update("item", "update",
                                    {"key": 5, "col": "i_cost",
                                     "val": 1234})
                    e.submit_update("item", "insert", {
                        "i_id": SCALE_I + 1, "i_a_id": 1,
                        "i_subject": 2, "i_title": 3,
                        "i_pub_date": 11500, "i_cost": 500,
                        "i_srp": 600, "i_stock": 5, "i_related1": 0})
                    e.submit_update("customer", "delete", {"key": 7})
            t_ref = {n: ref.submit(n, p) for n, p in subs}
            t_eng = {n: eng.submit(n, p) for n, p in subs}
            ref.run_until_drained()
            eng.run_until_drained()
            assert ref.last_scan_path == eng.last_scan_path
            assert ref.last_join_path == eng.last_join_path
            for n, _ in subs:
                rw, gw = t_ref[n].result, t_eng[n].result
                for k in rw:
                    a, b = np.asarray(rw[k]), np.asarray(gw[k])
                    assert a.shape == b.shape and (a == b).all(), \
                        (dense, beat, n, k)
            for tname in plan.catalog.schemas:
                s_r, s_e = ref.snapshot(tname), eng.snapshot(tname)
                for c in s_r:
                    assert (np.asarray(s_r[c])
                            == np.asarray(s_e[c])).all(), \
                        (dense, beat, tname, c)


def test_sharded_sort_merge_exact_on_sharded_spine(row_mesh):
    """A sort stage whose spine is row-sharded (impossible in TPC-W,
    where every sort spine doubles as a join probe side): duplicate
    sort keys spread across shards must merge in EXACT global order —
    key ties resolve by shard then local row, which is global row
    order, matching the unsharded stable sort."""
    from repro.core.plan import Pred, QueryTemplate, compile_plan
    from repro.core.storage import Catalog, TableSchema, UpdateSlots

    mesh = row_mesh(4)
    T = 64
    cat = Catalog([TableSchema("t", ("k", "g", "v"), T, pk="k")])
    tpl = [QueryTemplate("q", "t", preds=(Pred("t", "g"),),
                         sort_col="v", limit=10),
           QueryTemplate("qd", "t", preds=(Pred("t", "g"),),
                         sort_col="v", sort_desc=True, limit=10)]
    plan = compile_plan(cat, tpl, {"q": 8, "qd": 8}, max_results=16)
    rng = np.random.default_rng(1)
    data = {"t": {"k": np.arange(T), "g": rng.integers(0, 3, T),
                  "v": rng.integers(0, 4, T)}}   # heavy key duplication
    eng = SharedDBEngine(plan, UpdateSlots(4, 4, 4), data,
                         kernels="jnp", mesh=mesh)
    base = QueryAtATimeEngine(plan, data, jit=False)
    for g in (0, 1, 2):
        ta = eng.submit("q", {0: (g, g)})
        tb = eng.submit("qd", {0: (g, g)})
        eng.run_until_drained()
        for name, t in (("q", ta), ("qd", tb)):
            want = base.execute(name, {0: (g, g)}).result["rows"]
            got = np.asarray(t.result["rows"])
            assert (got == np.asarray(want)).all(), \
                (name, g, got, np.asarray(want))


def test_sharded_key_mirror_tracks_pk_rewrites_and_batch_order(row_mesh):
    """The replicated (key, valid) locate mirror of an index-less
    row-sharded PK table must track pk-COLUMN rewrites (the mirror is a
    copy of the column, and updates may rewrite the column itself) and
    honor the delete-then-update arrival order within one batch — both
    invisible to the TPC-W streams, both load-bearing for update
    targeting."""
    from repro.core.plan import Pred, QueryTemplate, compile_plan
    from repro.core.storage import Catalog, TableSchema, UpdateSlots

    mesh = row_mesh(2)
    T = 16
    cat = Catalog([TableSchema("t", ("k", "v"), T, pk="k")])
    tpl = [QueryTemplate("byk", "t", preds=(Pred("t", "k"),), limit=4)]
    plan = compile_plan(cat, tpl, {"byk": 8}, max_results=8)
    data = {"t": {"k": np.arange(T) * 10, "v": np.arange(T)}}
    eng = SharedDBEngine(plan, UpdateSlots(4, 4, 4), data,
                         kernels="jnp", mesh=mesh)
    base = QueryAtATimeEngine(plan, data, jit=False)

    def beat(updates, q_key):
        for u in updates:
            eng.submit_update(*u)
            base.apply_update(*u)
        t = eng.submit("byk", {0: (q_key, q_key)})
        eng.run_until_drained()
        want = base.execute("byk", {0: (q_key, q_key)}).result["rows"]
        got = np.asarray(t.result["rows"])
        assert (got == np.asarray(want)).all(), (q_key, got, want)
        snap = eng.snapshot("t")
        for c in ("k", "v"):
            assert (snap[c] == np.asarray(base.state["t"][c])).all(), c
        assert (snap["_valid"]
                == np.asarray(base.state["t"]["_valid"])).all()

    # rewrite row 3's pk 30 -> 77, then target it by the NEW key
    beat([("t", "update", {"key": 30, "col": "k", "val": 77})], 77)
    beat([("t", "update", {"key": 77, "col": "v", "val": 999})], 77)
    # delete-then-update of the same key in ONE batch: the update must
    # find nothing (arrival order), on whichever shard owned the row
    beat([("t", "delete", {"key": 50}),
          ("t", "update", {"key": 50, "col": "v", "val": 123})], 50)
    # and the key is re-insertable afterwards
    beat([("t", "insert", {"k": 50, "v": 5})], 50)


def test_insert_overflow_never_lands_in_alignment_padding(row_mesh):
    """A capacity NOT divisible by the shard count pads the sharded
    layout with alignment rows — inserts overflowing the ORIGINAL
    capacity must be dropped exactly like the unsharded engine drops
    them, never committed into the padding (which results/materialize
    would then expose as phantom rows)."""
    from repro.core.plan import Pred, QueryTemplate, compile_plan
    from repro.core.storage import Catalog, TableSchema, UpdateSlots

    mesh = row_mesh(4)
    T = 10                                  # ceil(10/4)*4 = 12: 2 pads
    cat = Catalog([TableSchema("t", ("k", "v"), T, pk="k")])
    tpl = [QueryTemplate("byv", "t", preds=(Pred("t", "v"),), limit=T)]
    plan = compile_plan(cat, tpl, {"byv": 8}, max_results=16)
    data = {"t": {"k": np.arange(8) * 10, "v": np.zeros(8, np.int64)}}
    eng = SharedDBEngine(plan, UpdateSlots(4, 4, 4), data,
                         kernels="jnp", mesh=mesh)
    base = QueryAtATimeEngine(plan, data, jit=False)
    # 4 inserts: rows 8, 9 fit; 10, 11 overflow the ORIGINAL capacity
    # (but WOULD fit the 12-row padded layout)
    for i in range(4):
        u = ("t", "insert", {"k": 100 + i, "v": 0})
        eng.submit_update(*u)
        base.apply_update(*u)
    t = eng.submit("byv", {0: (0, 0)})
    eng.run_until_drained()
    want = base.execute("byv", {0: (0, 0)}).result["rows"]
    got = np.asarray(t.result["rows"])
    assert (got == np.asarray(want)).all(), (got, want)
    assert got[got >= 0].max() <= T - 1     # no phantom padding rows
    snap = eng.snapshot("t")
    for c in ("k", "v"):
        assert (snap[c] == np.asarray(base.state["t"][c])).all(), c
    assert (snap["_valid"] == np.asarray(base.state["t"]["_valid"])).all()
    # the padding rows themselves stayed permanently invalid
    assert not np.asarray(eng.state["t"]["_valid"])[T:].any()


def test_overflow_insert_indexes_as_absent():
    """An insert dropped for landing past the commit bound must leave
    its key ABSENT from the dense pk index (-1) — an out-of-range row
    id there would clip onto the last real row in the gather join and
    fabricate a match.  (Storage-level contract shared by the unsharded
    and sharded apply paths.)"""
    from repro.core.storage import (TableSchema, UpdateSlots,
                                    apply_updates, bulk_load,
                                    empty_update_batch)

    schema = TableSchema("t", ("k", "v"), 4, pk="k", key_space=100)
    t = bulk_load(schema, {"k": np.arange(4), "v": np.arange(4)})
    b = empty_update_batch(schema, UpdateSlots(2, 1, 1))
    b["ins_rows"]["k"] = b["ins_rows"]["k"].at[0].set(7)
    b["ins_mask"] = b["ins_mask"].at[0].set(True)
    t2 = apply_updates(schema, t, b)                  # table is full
    assert int(t2["_pk_index"][7]) == -1              # key absent
    assert int(t2["_n"]) == 5                         # cursor advances
    assert not bool(t2["_valid"][3] != t["_valid"][3])


def test_sharded_pipelined_drain_matches_oracle(row_mesh):
    """Double-buffered dispatch/collect over the mesh: staging is
    replicated per-slot and the donated carries never alias in-flight
    results."""
    w = _ShardedWorld(row_mesh(2), "jnp",
                      dense_pk_index=False)
    rng = np.random.default_rng(9)
    for beat in range(3):
        w.queue_update(("customer", "update", {
            "key": int(rng.integers(0, SCALE_C)),
            "col": "c_expiration", "val": 13000 + beat}))
        w.submit("get_book", {0: (beat, beat)})
        w.submit("get_customer", {0: (beat, beat)})
        w.heartbeat(pipelined=True)


if HAVE_HYPOTHESIS:
    class ShardedDifferentialMachine(RuleBasedStateMachine):
        """The PR-3/4 stateful harness with a SHARD-COUNT rule: each
        example draws a mesh size (1/2/4) at initialize time, then
        interleaves spine-side mutations, PK-side mutations and
        slot-stable join beats over the index-less world, comparing
        every heartbeat against the oracle."""

        @initialize(shards=st.sampled_from([1, 2, 4]))
        def setup(self, shards):
            import jax
            if jax.default_backend() != "cpu" \
                    or jax.device_count() < shards:
                pytest.skip(f"needs {shards} CPU host devices")
            from repro.core.sharding import make_row_mesh
            self.w = _ShardedWorld(make_row_mesh(shards), "jnp",
                                   dense_pk_index=False)

        @rule(key=st.integers(0, SCALE_C - 1),
              val=st.integers(12000, 15000))
        def update_customer_expiration(self, key, val):
            self.w.queue_update(("customer", "update", {
                "key": key, "col": "c_expiration", "val": val}))

        @rule(key=st.integers(0, SCALE_I - 1), val=st.integers(0, 9999))
        def update_item_cost(self, key, val):
            self.w.queue_update(("item", "update", {
                "key": key, "col": "i_cost", "val": val}))

        @rule(subj=st.integers(0, tpcw.N_SUBJECTS - 1),
              cost=st.integers(100, 9999))
        def insert_item(self, subj, cost):
            self.w.insert_item(subj, cost)

        @rule(o=st.integers(0, 40))
        def joins_beat(self, o):
            self.w.submit("order_lines", {0: (o, o)})
            self.w.submit("get_cart", {0: (12, 12)})
            self.w.submit("get_book", {0: (5, 5)})
            self.w.heartbeat()

        @rule(c=st.integers(0, SCALE_C + 8))
        def select_customer(self, c):
            self.w.submit("get_customer", {0: (c, c)})

        @rule()
        def heartbeat(self):
            self.w.heartbeat()

        def teardown(self):
            if hasattr(self, "w"):
                self.w.heartbeat()

    ShardedDifferentialMachine.TestCase.settings = settings(
        max_examples=2 if STRESS else 1, stateful_step_count=6,
        deadline=None)
    TestShardedDifferential = ShardedDifferentialMachine.TestCase

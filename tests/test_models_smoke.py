"""Per-arch smoke tests: reduced same-family config, one forward/train
step + prefill/decode on CPU, asserting shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, smoke_config
from repro.models.registry import get_model

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=16):
    k = KEY
    b = {"tokens": jax.random.randint(k, (B, S), 0, cfg.vocab),
         "labels": jax.random.randint(k, (B, S), 0, cfg.vocab)}
    if cfg.enc_dec:
        b["frames"] = jax.random.normal(
            k, (B, S * cfg.dec_ratio, cfg.d_model), jnp.bfloat16)
    if cfg.cross_every:
        b["vision"] = jax.random.normal(
            k, (B, cfg.n_vision_tokens, cfg.d_model), jnp.bfloat16)
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = smoke_config(arch)
    api = get_model(cfg)
    params = api.init_params(KEY)
    batch = _batch(cfg)
    loss = api.loss(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss))
    opt = api.init_opt(params)
    loss2, params2, opt2, gnorm = api.train_step(params, opt, batch)
    assert bool(jnp.isfinite(loss2)) and bool(jnp.isfinite(gnorm))
    assert float(gnorm) > 0
    # params actually moved
    delta = sum(float(jnp.sum(jnp.abs(a.astype(jnp.float32)
                                      - b.astype(jnp.float32))))
                for a, b in zip(jax.tree.leaves(params),
                                jax.tree.leaves(params2)))
    assert delta > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_prefill_decode_shapes(arch):
    cfg = smoke_config(arch)
    api = get_model(cfg)
    params = api.init_params(KEY)
    B, S = 2, 16
    batch = _batch(cfg, B, S)
    batch.pop("labels")
    logits, cache = api.prefill(params, batch, cache_capacity=S + 8)
    Vp = cfg.vocab_padded()
    assert logits.shape == (B, Vp)
    assert bool(jnp.isfinite(logits).all())
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    pos = jnp.full((B,), S, jnp.int32)
    logits2, cache2 = api.decode_step(params, cache, tok, pos)
    assert logits2.shape == (B, Vp)
    assert bool(jnp.isfinite(logits2).all())
    # cache pytree structure is stable across steps (scan-compatible)
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("arch", ["stablelm-1.6b", "mamba2-370m",
                                  "recurrentgemma-2b", "gemma3-27b"])
def test_decode_matches_prefill_logits(arch):
    """Teacher-forced decode step must reproduce the prefill's next-token
    distribution (cache correctness)."""
    cfg = smoke_config(arch)
    api = get_model(cfg)
    params = api.init_params(KEY)
    B, S = 2, 12
    toks = jax.random.randint(KEY, (B, S + 1), 0, cfg.vocab)
    full = {"tokens": toks}
    # prefill S+1 tokens: last-token logits
    want, _ = api.prefill(params, full, cache_capacity=S + 4)
    # prefill S tokens then decode token S
    part = {"tokens": toks[:, :S]}
    _, cache = api.prefill(params, part, cache_capacity=S + 4)
    got, _ = api.decode_step(params, cache, toks[:, S:S + 1],
                             jnp.full((B,), S, jnp.int32))
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=3e-2, atol=3e-2)


def test_full_configs_param_counts_sane():
    """Full (non-smoke) configs report parameter counts in the right
    ballpark for their public specs."""
    expect = {"qwen2-72b": (60e9, 90e9), "yi-6b": (5e9, 8e9),
              "mixtral-8x22b": (120e9, 150e9), "stablelm-1.6b": (1e9, 2.5e9),
              "mamba2-370m": (0.25e9, 0.55e9),
              "recurrentgemma-2b": (2e9, 3.5e9),
              "gemma3-27b": (20e9, 32e9),
              "llama-3.2-vision-90b": (70e9, 105e9),
              "qwen2-moe-a2.7b": (12e9, 17e9),
              "whisper-small": (0.15e9, 0.4e9)}
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]"


def test_moe_dispatch_paths_agree():
    """Sort-based capacity dispatch == one-hot reference dispatch."""
    from repro.configs import MoEConfig
    from repro.models.common import MeshAxes, ParamStore
    from repro.models import moe as moe_lib
    cfg = MoEConfig(num_experts=4, top_k=2, num_shared=0, d_ff_expert=32,
                    capacity_factor=8.0)  # high cf: no drops -> exact match
    store = ParamStore(KEY, jnp.float32)
    moe_lib.init_moe(store, 16, cfg, MeshAxes())
    x = jax.random.normal(KEY, (2, 8, 16), jnp.float32)
    y1, aux1 = moe_lib.apply_moe(store.params, x, cfg, "swiglu", MeshAxes(),
                                 dispatch="sort")
    y2, aux2 = moe_lib.apply_moe(store.params, x, cfg, "swiglu", MeshAxes(),
                                 dispatch="onehot")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-5, atol=1e-5)


def test_moe_sharded_dispatch_matches_sort():
    """The shard-local dispatch (perf variant) is numerically identical on
    one shard; cross-shard it only changes drop behaviour under overflow."""
    from repro.configs import MoEConfig
    from repro.models.common import MeshAxes, ParamStore
    from repro.models import moe as moe_lib
    cfg = MoEConfig(num_experts=4, top_k=2, num_shared=1, d_ff_expert=32,
                    capacity_factor=8.0)
    store = ParamStore(KEY, jnp.float32)
    moe_lib.init_moe(store, 16, cfg, MeshAxes())
    x = jax.random.normal(KEY, (2, 8, 16), jnp.float32)
    y1, a1 = moe_lib.apply_moe(store.params, x, cfg, "swiglu", MeshAxes(),
                               dispatch="sort")
    y2, a2 = moe_lib.apply_moe(store.params, x, cfg, "swiglu", MeshAxes(),
                               dispatch="sharded")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)
    np.testing.assert_allclose(float(a1), float(a2), rtol=1e-6)


def test_moe_capacity_conservation():
    """With finite capacity, every routed token lands in <= capacity slots
    and combine weights are normalized."""
    from repro.models.moe import moe_capacity
    from repro.configs import MoEConfig
    cfg = MoEConfig(num_experts=8, top_k=2, capacity_factor=1.25)
    C = moe_capacity(1024, cfg)
    assert C >= 1024 * 2 // 8
    assert C % 8 == 0

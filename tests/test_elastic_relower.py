"""Elastic scaling: after a simulated shrink, the SAME step function
re-lowers and compiles on the smaller mesh — the drain -> re-mesh ->
restore recipe of runtime/elastic.py, executed for real.

The re-lower test runs in a subprocess because the 8-device
host-platform flag must be set before jax initializes (the test suite
itself stays at 1 device).  The unit tests below cover the hardening
that rode along with plan folding: ladder validation/sorting at
construction, explicit alive-device meshes, never-beaten-host death,
and the shared drain -> re-lower -> resume recipe."""
import subprocess
import sys
import textwrap

import jax
import pytest

from repro.runtime.elastic import ElasticMeshManager, relower_recipe
from repro.runtime.fault_tolerance import HeartbeatBoard, StragglerPolicy

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, "src")
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import smoke_config
    from repro.models.common import MeshAxes
    from repro.models.registry import get_model
    from repro.runtime.elastic import ElasticMeshManager

    mgr = ElasticMeshManager(ladder=[(1, 2, 4), (1, 2, 2), (1, 1, 2),
                                     (1, 1, 1)])
    cfg = smoke_config("yi-6b")

    def lower_on(shape):
        mesh = mgr.make_mesh(shape)
        axes = MeshAxes(mesh=mesh, dp=("data",), fsdp="data", tp="model")
        api = get_model(cfg, axes)
        import jax.numpy as jnp
        batch = {
            "tokens": jax.ShapeDtypeStruct((4, 16), jnp.int32),
            "labels": jax.ShapeDtypeStruct((4, 16), jnp.int32),
        }
        params = api.param_shapes()
        opt = jax.eval_shape(api.init_opt, params)
        fn = jax.jit(api.train_step)
        fn.lower(params, opt, batch).compile()
        return shape

    # full mesh, then simulated loss of half the devices
    assert lower_on(mgr.select(8, global_batch=4)) == (1, 2, 4)
    shrink = mgr.shrink_plan((1, 2, 4), 4, global_batch=4)
    assert shrink["target"] == (1, 2, 2)
    lower_on(shrink["target"])
    print("ELASTIC_OK")
""")


def test_step_relowers_after_mesh_shrink():
    out = subprocess.run([sys.executable, "-c", SCRIPT],
                         capture_output=True, text=True, timeout=420,
                         cwd=".")
    assert "ELASTIC_OK" in out.stdout, out.stderr[-2000:]


def test_elastic_ladder_validated_and_sorted_at_construction():
    """A hand-built unsorted ladder used to silently under-provision:
    select() walks in order and took the first FITTING rung, not the
    largest.  Construction now sorts descending by chip count, so
    select(4) finds the 4-chip rung even when it was listed last."""
    mgr = ElasticMeshManager(ladder=[(1, 1, 1), (1, 2, 2), (1, 1, 2)])
    assert mgr.ladder == [(1, 2, 2), (1, 1, 2), (1, 1, 1)]
    assert mgr.select(4) == (1, 2, 2)
    assert mgr.select(2) == (1, 1, 2)
    for bad in ([(1, 2)], [(1, 2, 0)], [(1, 2, -2)], [(1, 2.5, 2)]):
        with pytest.raises(ValueError):
            ElasticMeshManager(ladder=bad)


def test_make_mesh_excludes_dead_devices():
    """make_mesh with an explicit alive-device list must build the mesh
    from the SURVIVORS — a dead middle device never lands in the mesh
    (the old jax.devices()[:n] slice would have included it)."""
    devs = jax.devices()
    if len(devs) < 8:
        pytest.skip("needs the 8 forced host devices")
    dead = devs[1]
    alive = devs[:1] + devs[2:]
    mgr = ElasticMeshManager(ladder=[(1, 2, 2), (1, 1, 2), (1, 1, 1)])
    mesh = mgr.make_mesh((1, 1, 2), devices=alive)
    assert dead not in mesh.devices.ravel().tolist()
    assert mesh.devices.ravel().tolist() == alive[:2]
    with pytest.raises(RuntimeError):            # survivors too few
        mgr.make_mesh((1, 2, 2), devices=devs[:3])


def test_never_beaten_host_declared_dead():
    """A host that registered but NEVER beat must go dead after
    ``dead_after_s`` of silence — the old board only tracked hosts it
    had heard from, so a node that wedged before its first heartbeat
    was invisible to failure detection forever."""
    pol = StragglerPolicy(dead_after_s=60.0)
    board = HeartbeatBoard()
    board.register(0, now=0.0)
    board.register(7, now=0.0)                   # wedges before beat 1
    board.beat(0, step=0, duration_s=1.0, now=50.0)
    assert board.dead_hosts(pol, now=59.0) == []
    assert board.dead_hosts(pol, now=70.0) == [7]
    assert board.dead_hosts(pol, now=200.0) == [0, 7]


def test_relower_recipe_background_variant():
    """The recipe behind SharedDBEngine.begin_fold: the background
    variant re-lowers while the old heartbeat serves and resumes with a
    full-rescan reseed; the foreground variant keeps the elastic shrink
    steps verbatim."""
    r = relower_recipe(("a", "b"), ("a", "b", "c"),
                       what="the extended always-on plan",
                       background=True)
    assert r["current"] == ("a", "b") and r["target"] == ("a", "b", "c")
    steps = " / ".join(r["steps"])
    assert "background" in steps and "old compiled heartbeat" in steps
    assert "migrate carries" in steps and "full-rescan reseed" in steps
    fg = relower_recipe((2, 16, 16), (1, 16, 16), what="step")
    assert "background" not in " / ".join(fg["steps"])

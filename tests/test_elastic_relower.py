"""Elastic scaling: after a simulated shrink, the SAME step function
re-lowers and compiles on the smaller mesh — the drain -> re-mesh ->
restore recipe of runtime/elastic.py, executed for real.

Runs in a subprocess because the 8-device host-platform flag must be set
before jax initializes (the test suite itself stays at 1 device).
"""
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, "src")
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import smoke_config
    from repro.models.common import MeshAxes
    from repro.models.registry import get_model
    from repro.runtime.elastic import ElasticMeshManager

    mgr = ElasticMeshManager(ladder=[(1, 2, 4), (1, 2, 2), (1, 1, 2),
                                     (1, 1, 1)])
    cfg = smoke_config("yi-6b")

    def lower_on(shape):
        mesh = mgr.make_mesh(shape)
        axes = MeshAxes(mesh=mesh, dp=("data",), fsdp="data", tp="model")
        api = get_model(cfg, axes)
        import jax.numpy as jnp
        batch = {
            "tokens": jax.ShapeDtypeStruct((4, 16), jnp.int32),
            "labels": jax.ShapeDtypeStruct((4, 16), jnp.int32),
        }
        params = api.param_shapes()
        opt = jax.eval_shape(api.init_opt, params)
        fn = jax.jit(api.train_step)
        fn.lower(params, opt, batch).compile()
        return shape

    # full mesh, then simulated loss of half the devices
    assert lower_on(mgr.select(8, global_batch=4)) == (1, 2, 4)
    shrink = mgr.shrink_plan((1, 2, 4), 4, global_batch=4)
    assert shrink["target"] == (1, 2, 2)
    lower_on(shrink["target"])
    print("ELASTIC_OK")
""")


def test_step_relowers_after_mesh_shrink():
    out = subprocess.run([sys.executable, "-c", SCRIPT],
                         capture_output=True, text=True, timeout=420,
                         cwd=".")
    assert "ELASTIC_OK" in out.stdout, out.stderr[-2000:]

"""Per-kernel validation: Pallas (interpret=True) vs ref.py oracles,
swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.bitmask_join import bitmask_join_pallas
from repro.kernels.clockscan import clockscan_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.shared_groupby import shared_groupby_pallas

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("C,T,Q", [
    (1, 256, 32), (3, 512, 64), (4, 1024, 256), (2, 2048, 128),
])
def test_clockscan_matches_ref(C, T, Q):
    cols = jnp.asarray(RNG.integers(-50, 100, (C, T)), jnp.int32)
    lo = jnp.asarray(RNG.integers(-60, 50, (C, Q)), jnp.int32)
    hi = lo + jnp.asarray(RNG.integers(0, 80, (C, Q)), jnp.int32)
    valid = jnp.asarray(RNG.random(T) > 0.15)
    got = clockscan_pallas(cols, lo, hi, valid)
    want = ref.clockscan_ref(cols, lo, hi, valid)
    assert (np.asarray(got) == np.asarray(want)).all()


def test_clockscan_bounds_inclusive():
    cols = jnp.asarray([[5, 6, 7]], jnp.int32)
    lo = jnp.full((1, 32), 5, jnp.int32)
    hi = jnp.full((1, 32), 6, jnp.int32)
    valid = jnp.ones(3, bool)
    got = np.asarray(clockscan_pallas(
        jnp.pad(cols, ((0, 0), (0, 253))), lo, hi,
        jnp.pad(valid, (0, 253))))
    bits = got[:3, 0] & 1
    assert bits.tolist() == [1, 1, 0]


@pytest.mark.parametrize("Tl,Tr,W", [
    (256, 256, 1), (512, 256, 2), (1024, 512, 8), (256, 1024, 4),
])
def test_bitmask_join_matches_ref(Tl, Tr, W):
    keys_r = jnp.asarray(RNG.permutation(Tr * 3)[:Tr], jnp.int32)
    keys_l = jnp.asarray(RNG.choice(Tr * 4, Tl), jnp.int32)
    mask_l = jnp.asarray(RNG.integers(0, 2**32, (Tl, W)), jnp.uint32)
    mask_r = jnp.asarray(RNG.integers(0, 2**32, (Tr, W)), jnp.uint32)
    valid_r = jnp.asarray(RNG.random(Tr) > 0.25)
    r1, m1 = bitmask_join_pallas(keys_l, mask_l, keys_r, mask_r, valid_r)
    r2, m2 = ref.bitmask_join_ref(keys_l, mask_l, keys_r, mask_r, valid_r)
    assert (np.asarray(r1) == np.asarray(r2)).all()
    assert (np.asarray(m1) == np.asarray(m2)).all()


@pytest.mark.parametrize("T,W,G", [
    (512, 1, 50), (512, 2, 100), (1024, 8, 300), (2048, 4, 1000),
])
def test_shared_groupby_matches_ref(T, W, G):
    gc = jnp.asarray(RNG.integers(0, G, (T,)), jnp.int32)
    vals = jnp.asarray(RNG.integers(-20, 50, (T,)), jnp.int32)
    mask = jnp.asarray(RNG.integers(0, 2**32, (T, W)), jnp.uint32)
    c1, s1 = shared_groupby_pallas(gc, vals, mask, G)
    c2, s2 = ref.shared_groupby_ref(gc, vals, mask, G)
    np.testing.assert_allclose(c1, c2, rtol=1e-6)
    np.testing.assert_allclose(s1, s2, rtol=1e-5, atol=1e-3)


@pytest.mark.parametrize("B,Sq,Sk,H,KV,D,causal,window", [
    (1, 128, 128, 4, 4, 64, True, 0),
    (2, 256, 256, 8, 2, 64, True, 0),
    (2, 256, 256, 8, 4, 32, True, 64),
    (1, 128, 256, 4, 1, 128, False, 0),   # cross-attention-like
    (2, 128, 128, 4, 4, 64, True, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(B, Sq, Sk, H, KV, D, causal, window,
                                     dtype):
    q = jnp.asarray(RNG.standard_normal((B, Sq, H, D)), dtype)
    k = jnp.asarray(RNG.standard_normal((B, Sk, KV, D)), dtype)
    v = jnp.asarray(RNG.standard_normal((B, Sk, KV, D)), dtype)
    got = flash_attention_pallas(q, k, v, causal=causal, window=window)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol * 5)


def test_flash_attention_matches_model_block_attention():
    """The Pallas kernel and the model-side chunked attention agree."""
    from repro.models.common import block_attention
    B, S, H, KV, D = 2, 256, 8, 4, 64
    q = jnp.asarray(RNG.standard_normal((B, S, H, D)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((B, S, KV, D)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((B, S, KV, D)), jnp.float32)
    a = flash_attention_pallas(q, k, v, causal=True, window=0)
    b = block_attention(q, k, v, causal=True, window=0)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-5, atol=1e-5)


def test_ssd_chunked_matches_naive_recurrence():
    from repro.models.ssm import ssd_chunked
    b, s, h, p, n = 2, 64, 4, 8, 16
    x = jnp.asarray(RNG.standard_normal((b, s, h, p)), jnp.float32)
    dt = jnp.asarray(RNG.random((b, s, h)) * 0.5 + 0.1, jnp.float32)
    A = -jnp.asarray(RNG.random(h) + 0.2, jnp.float32)
    B = jnp.asarray(RNG.standard_normal((b, s, n)), jnp.float32)
    C = jnp.asarray(RNG.standard_normal((b, s, n)), jnp.float32)
    y1, f1 = ssd_chunked(x, dt, A, B, C, chunk=16)
    y2, f2 = ref.ssd_scan_ref(x, dt, A, B, C)
    np.testing.assert_allclose(y1, y2, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(f1, f2, rtol=2e-4, atol=2e-4)

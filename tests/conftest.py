import os
import sys

# smoke tests / benches must see ONE device (the dry-run sets its own flag)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import os
import sys

# smoke tests / benches must see the CPU platform (the dry-run sets its
# own flag)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# Multi-device substrate for the sharding tests: the host-platform
# device-count flag must be set BEFORE jax initializes, so it lives here
# rather than in a fixture body.  Appending (not overwriting) keeps any
# caller-provided XLA_FLAGS, the flag is inert on real accelerator
# platforms, and subprocess-based tests (test_elastic_relower, the
# launch dry-runs, the sharded benchmark) overwrite XLA_FLAGS in their
# own environment — so this is subprocess-safe in both directions.
_DEVICES_FLAG = "--xla_force_host_platform_device_count"
if _DEVICES_FLAG not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = " ".join(
        [os.environ.get("XLA_FLAGS", ""), f"{_DEVICES_FLAG}=8"]).strip()

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import pytest  # noqa: E402


@pytest.fixture
def row_mesh():
    """Factory for 1-D row meshes over the forced host devices; skips
    cleanly when the substrate is unavailable (real accelerator
    platform, or the flag failed to take)."""
    import jax

    def make(n_shards: int):
        if jax.default_backend() != "cpu" or jax.device_count() < n_shards:
            pytest.skip(f"sharding tests need {n_shards} CPU host "
                        f"devices (have {jax.device_count()} "
                        f"{jax.default_backend()} devices)")
        from repro.core.sharding import make_row_mesh
        return make_row_mesh(n_shards)

    return make

"""Fused delta-heartbeat mega-kernel suite (PR-6 tentpole acceptance).

Three proof obligations for ``backend.fused_delta``:

  * launch count — a steady-state delta-join beat through the engine
    issues exactly ONE fused backend op (counted at trace time by the
    counting backend every engine wraps around its operator backend):
    no chained pane / scan_delta / join_delta / full-probe launches
    hide behind it.  The chained fallback (a backend WITHOUT
    fused_delta) still works and still produces identical tickets.
  * kernel parity — ``fused_delta_pallas`` (interpret mode) is
    bit-identical to the ``fused_delta_ref`` oracle on padded tails
    (table heights straddling the 256-row pane tile), empty dirty
    sets, pane-boundary dirty rows, pseudo-partitioned (block-join)
    probe sides, and — when hypothesis is installed — randomized
    geometries.
  * engine parity — jnp vs pallas full-engine ticket parity through
    the sharded differential harness at shard counts 1 / 2 / 4 (the
    fused op runs INSIDE shard_map, so per-shard slicing must not
    perturb the merged rids or scan words).
"""
import dataclasses

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.backends import (FusedJoinIn, FusedScanIn, get_backend,
                                 register_backend)
from repro.core.executor import SharedDBEngine
from repro.core.storage import INT_SENTINEL, build_key_partitions
from repro.kernels import ref
from repro.kernels.fused_delta import fused_delta_pallas
from repro.workloads import tpcw

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

SCALE_I, SCALE_C = 64, 128

# delta ops the fused launch must fully absorb: a steady-state beat
# issuing ANY of these has fallen off the fused path
CHAINED_DELTA_OPS = ("scan", "scan_delta", "join_delta",
                     "join_partitioned", "join_block")


# ------------------------------------------------------------ builders
def mk_scan(T, C, Q, A, D, dn, span, seed, boundary_rows=()):
    r = np.random.default_rng(seed)
    cols = jnp.asarray(r.integers(0, 50, (C, T)), jnp.int32)
    lo = jnp.asarray(r.integers(0, 30, (C, Q)), jnp.int32)
    hi = lo + jnp.asarray(r.integers(0, 30, (C, Q)), jnp.int32)
    w = Q // 32
    w0 = int(r.integers(0, max(1, w - A + 1)))
    lo_p = jnp.asarray(np.array(lo)[:, w0 * 32:(w0 + A) * 32])
    hi_p = jnp.asarray(np.array(hi)[:, w0 * 32:(w0 + A) * 32])
    valid = jnp.asarray(r.random(T) < 0.9)
    carry = jnp.asarray(
        r.integers(0, 2**32, (T, w), dtype=np.uint64).astype(np.uint32))
    pool = [b for b in boundary_rows if b < T]
    extra = [x for x in r.choice(T, size=D, replace=False)
             if x not in pool][:max(dn - len(pool), 0)]
    rows = np.sort(np.asarray(pool + extra, np.int32)[:dn])
    rows = jnp.asarray(np.concatenate(
        [rows, np.full(D - len(rows), T, np.int32)]))
    return FusedScanIn(cols, lo, hi, lo_p, hi_p, valid, carry,
                       jnp.int32(w0), jnp.int32(span), rows,
                       jnp.int32(min(dn, D)))


def mk_join(Tl, Tr, D, dn, seed, pseudo=False):
    r = np.random.default_rng(seed)
    keys = jnp.asarray(r.integers(0, Tr, Tl), jnp.int32)
    kr = jnp.asarray(r.permutation(Tr), jnp.int32)
    vr = jnp.asarray(r.random(Tr) < 0.9)
    if pseudo:
        # the block-join probe side as lowering builds it: ONE bucket
        # covering the whole pk table (see lowering._pseudo_partitions)
        bkeys = jnp.where(vr, kr, INT_SENTINEL)[None, :]
        brows = jnp.where(vr, jnp.arange(Tr, dtype=jnp.int32), -1)[None, :]
        bounds = jnp.full((1,), np.iinfo(np.int32).min, jnp.int32)
    else:
        bkeys, brows, bounds = build_key_partitions(kr, vr, 2, Tr // 2 + 8)
    rows = np.sort(r.choice(Tl, size=dn, replace=False)).astype(np.int32)
    rows = jnp.asarray(np.concatenate([rows, np.full(D - dn, Tl,
                                                     np.int32)]))
    rid_carry = jnp.asarray(r.integers(-1, Tr, Tl), jnp.int32)
    return FusedJoinIn(keys, rows, jnp.int32(dn), bkeys, brows, bounds,
                       rid_carry)


def _assert_fused_matches_ref(scan_in, join_in, tag=""):
    wr, rr = ref.fused_delta_ref(scan_in, join_in)
    wp, rp = fused_delta_pallas(scan_in, join_in, interpret=True)
    assert len(wr) == len(wp) and len(rr) == len(rp)
    for i, (a, b) in enumerate(zip(wr, wp)):
        np.testing.assert_array_equal(np.array(a), np.array(b),
                                      err_msg=f"{tag}:words[{i}]")
    for i, (a, b) in enumerate(zip(rr, rp)):
        np.testing.assert_array_equal(np.array(a), np.array(b),
                                      err_msg=f"{tag}:rids[{i}]")


# ------------------------------------------------------- kernel parity
def test_fused_kernel_matches_ref_mixed_stages():
    """Three scan stages (padded tail at T=300, exact tile at T=256,
    two-tile tail at T=700) + a partitioned and a pseudo-partitioned
    probe, all in one launch."""
    _assert_fused_matches_ref(
        (mk_scan(300, 2, 64, 1, 8, 5, 1, 1),
         mk_scan(256, 3, 96, 2, 16, 0, 0, 2),
         mk_scan(700, 1, 32, 1, 4, 4, 1, 3)),
        (mk_join(300, 128, 8, 3, 4),
         mk_join(256, 64, 8, 8, 5, pseudo=True)),
        "mixed")


def test_fused_kernel_pane_boundary_dirty_rows():
    """Dirty rows pinned to the pane-tile seams (255 / 256) and the
    last real row — the gathered compare must land in the right grid
    step on both sides of every tile boundary."""
    _assert_fused_matches_ref(
        (mk_scan(300, 2, 64, 1, 8, 5, 1, 11,
                 boundary_rows=(0, 255, 256, 299)),
         mk_scan(512, 1, 64, 2, 8, 4, 1, 12,
                 boundary_rows=(255, 256, 511)),),
        (mk_join(300, 64, 4, 2, 13),), "boundary")


def test_fused_kernel_empty_dirty_and_zero_span():
    """dn == 0 and span == 0 everywhere: the fused op must be an exact
    identity on the carried words and rids (the cond-skip contract the
    lowering relies on for untouched stages)."""
    si = (mk_scan(128, 2, 64, 2, 8, 0, 0, 9),)
    ji = (mk_join(128, 32, 4, 0, 10),)
    _assert_fused_matches_ref(si, ji, "empty_dirty")
    words, rids = fused_delta_pallas(si, ji, interpret=True)
    np.testing.assert_array_equal(np.array(words[0]),
                                  np.array(si[0].carry))
    np.testing.assert_array_equal(np.array(rids[0]),
                                  np.array(ji[0].rid_carry))


def test_fused_kernel_scan_only_join_only_and_empty():
    _assert_fused_matches_ref((mk_scan(64, 1, 32, 1, 4, 2, 1, 7),), (),
                              "scan_only")
    _assert_fused_matches_ref((), (mk_join(100, 50, 4, 4, 8),),
                              "join_only")
    assert fused_delta_pallas((), ()) == ((), ())


if HAVE_HYPOTHESIS:
    @settings(max_examples=15, deadline=None)
    @given(t1=st.integers(16, 520), c1=st.integers(1, 3),
           dn1=st.integers(0, 6), span1=st.integers(0, 1),
           tr=st.integers(8, 70), dnj=st.integers(0, 6),
           pseudo=st.booleans(), seed=st.integers(0, 2**16))
    def test_fused_kernel_matches_ref_randomized(t1, c1, dn1, span1, tr,
                                                 dnj, pseudo, seed):
        _assert_fused_matches_ref(
            (mk_scan(t1, c1, 64, 1, 8, min(dn1, t1), span1, seed),),
            (mk_join(t1, tr, 8, min(dnj, t1), seed + 1, pseudo=pseudo),),
            "rand")


# --------------------------------------------------- engine launch count
def _indexless_engine(kernels="auto"):
    # "auto" follows the REPRO_KERNELS override, so each CI leg proves
    # the launch-count contract on ITS backend (jnp and pallas alike)
    rng = np.random.default_rng(0)
    plan = tpcw.build_tpcw_plan(SCALE_I, SCALE_C, dense_pk_index=False)
    data = tpcw.generate_data(rng, SCALE_I, SCALE_C)
    return SharedDBEngine(plan, tpcw.DEFAULT_UPDATE_SLOTS, data,
                          jit=False, kernels=kernels)


def _steady_delta_join_beats(eng, beats=3):
    """Seed, then drive slot-stable trickle beats (customer-only writes,
    fixed join templates) until the engine is on the delta-join path;
    returns the CycleResults of the steady-state beats."""
    eng.submit("order_lines", {0: (10, 10)})
    eng.submit("get_cart", {0: (12, 12)})
    eng.submit("get_book", {0: (5, 5)})
    eng.run_until_drained()                              # seed (full)
    out = []
    for i in range(beats):
        eng.submit_update("customer", "update",
                          {"key": 3 + i, "col": "c_expiration",
                           "val": 13000 + i})
        eng.submit("order_lines", {0: (10, 10)})
        eng.submit("get_cart", {0: (12, 12)})
        eng.submit("get_book", {0: (5, 5)})
        out.extend(eng.run_until_drained())
    return out


def test_steady_state_delta_beat_is_one_fused_launch():
    """The PR-6 contract, proven through the engine's own counting
    backend: every steady-state delta-join beat issues EXACTLY one
    fused_delta op and zero chained delta / full-path operator
    launches (group-by post stages are the only other backend ops a
    beat may carry)."""
    eng = _indexless_engine()
    beats = _steady_delta_join_beats(eng)
    steady = [b for b in beats if b.join_path == "delta"]
    assert len(steady) >= 2, [
        (b.scan_path, b.join_path) for b in beats]
    for b in steady:
        assert b.backend_ops.get("fused_delta") == 1, b.backend_ops
        for op in CHAINED_DELTA_OPS:
            assert b.backend_ops.get(op, 0) == 0, (op, b.backend_ops)
        leftovers = set(b.backend_ops) - {"fused_delta", "groupby"}
        assert all(b.backend_ops[op] == 0 for op in leftovers), \
            b.backend_ops


def test_full_rescan_beat_never_uses_fused_op():
    """The seed / reseed beat runs the full scan + probe chain — the
    fused op is a delta-path-only construct."""
    eng = _indexless_engine()
    eng.submit("get_book", {0: (5, 5)})
    done = eng.run_until_drained()
    assert done and done[-1].scan_path == "full"
    assert done[-1].backend_ops.get("fused_delta", 0) == 0
    assert done[-1].backend_ops.get("scan", 0) >= 1


def test_chained_fallback_backend_matches_fused_tickets():
    """A backend WITHOUT fused_delta falls back to the chained
    pane/scan_delta/join_delta ops, still runs the delta path, and
    produces tickets equal to the fused engine's."""
    chained = dataclasses.replace(get_backend("jnp"),
                                  name="jnp-chained-test",
                                  fused_delta=None)
    register_backend(chained)
    eng_f = _indexless_engine(kernels="jnp")
    eng_c = _indexless_engine(kernels="jnp-chained-test")
    beats_f = _steady_delta_join_beats(eng_f)
    beats_c = _steady_delta_join_beats(eng_c)
    assert [b.scan_path for b in beats_f] == \
        [b.scan_path for b in beats_c]
    assert [b.join_path for b in beats_f] == \
        [b.join_path for b in beats_c]
    assert any(b.join_path == "delta" for b in beats_c)
    for bf, bc in zip(beats_f, beats_c):
        if bf.join_path == "delta":
            assert bc.backend_ops.get("fused_delta", 0) == 0
            assert bc.backend_ops.get("join_delta", 0) >= 1
        for name in bf.tickets:
            for tf, tc in zip(bf.tickets[name], bc.tickets[name]):
                for k in tf.result:
                    np.testing.assert_array_equal(
                        np.asarray(tf.result[k]),
                        np.asarray(tc.result[k]), err_msg=(name, k))


# ------------------------------------------- sharded jnp-vs-pallas parity
@pytest.mark.parametrize("shards", [1, 2, 4])
def test_sharded_fused_parity_jnp_vs_pallas(row_mesh, shards):
    """Full-engine ticket parity, jnp vs pallas, through the sharded
    differential geometry: the fused op runs inside shard_map on
    shard-local slices, so the merged rids / scan words must agree
    across backends at every shard count."""
    mesh = row_mesh(shards)
    rng = np.random.default_rng(0)
    plan = tpcw.build_tpcw_plan(SCALE_I, SCALE_C, dense_pk_index=False)
    data = tpcw.generate_data(rng, SCALE_I, SCALE_C)
    engines = {k: SharedDBEngine(plan, tpcw.DEFAULT_UPDATE_SLOTS, data,
                                 kernels=k, mesh=mesh)
               for k in ("jnp", "pallas")}

    def beat(updates, subs):
        tickets = {}
        for k, eng in engines.items():
            for u in updates:
                eng.submit_update(*u)
            tickets[k] = [eng.submit(n, p) for n, p in subs]
            eng.run_until_drained()
        assert engines["jnp"].last_scan_path == \
            engines["pallas"].last_scan_path
        assert engines["jnp"].last_join_path == \
            engines["pallas"].last_join_path
        for tj, tp in zip(tickets["jnp"], tickets["pallas"]):
            for k in tj.result:
                a, b = np.asarray(tj.result[k]), np.asarray(tp.result[k])
                assert a.shape == b.shape and (a == b).all(), \
                    (tj.template, k)

    subs = [("order_lines", {0: (10, 10)}), ("get_cart", {0: (12, 12)}),
            ("get_book", {0: (5, 5)})]
    beat([], subs)                                       # seed (full)
    for i in range(2):                                   # carried-rid
        beat([("customer", "update",
               {"key": 3 + i, "col": "c_expiration",
                "val": 13000 + i})], subs)
    beat([("item", "update",                             # PK-side write
           {"key": 7, "col": "i_cost", "val": 4242})], subs)
    assert engines["jnp"].delta_join_cycles >= 1
    assert engines["pallas"].delta_join_cycles >= 1

"""Delta-aware shared joins: carried rid arrays across heartbeats.

Covers the PR-4 tentpole end to end — kernel parity of the dirty-row
probe (jnp oracle vs Pallas, padded tails), the conditional partition
refresh in storage, and the engine-level path machinery: steady-state
heartbeats re-probe ONLY dirty spine rows (the full partitioned probe is
never invoked), PK-side writes / dirty overflow / the first heartbeat
fall back to the full probe and reseed the carry, and the carry-layout
assertion refuses a carry from a different admission layout.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import backends
from repro.core.baseline import QueryAtATimeEngine
from repro.core.executor import SharedDBEngine
from repro.core.lowering import lower_plan
from repro.core.storage import (TableSchema, UpdateSlots, apply_updates,
                                build_key_partitions, bulk_load,
                                empty_update_batch,
                                refresh_key_partitions)
from repro.kernels import ref
from repro.kernels.fused_delta import delta_join_pallas
from repro.workloads import tpcw

INT_MAX = tpcw.INT_MAX


# ---------------------------------------------------- kernel-level parity
@pytest.mark.parametrize("seed,Tr,Tl,n_parts,bucket_cap,D", [
    (0, 160, 120, 4, 48, 9),      # plain
    (1, 5, 7, 2, 3, 11),          # D > Tl: duplicate dirty rows
    (2, 257, 300, 9, 32, 33),     # capacity-boundary padding
    (3, 1, 1, 1, 1, 1),           # degenerate single row
    (4, 130, 260, 23, 7, 16),     # sparse valid rows -> empty buckets
])
def test_delta_join_kernel_parity_padded_tails(seed, Tr, Tl, n_parts,
                                               bucket_cap, D):
    rng = np.random.default_rng(seed)
    keys_r = jnp.asarray(rng.permutation(Tr * 3)[:Tr] - 2, jnp.int32)
    valid_r = jnp.asarray(rng.random(Tr) > 0.3)
    keys_l = jnp.asarray(rng.integers(-3, Tr * 3, Tl), jnp.int32)
    parts = build_key_partitions(keys_r, valid_r, n_parts, bucket_cap)
    # pad sentinels both below and above range: callers drop them
    rows = jnp.asarray(rng.choice(
        np.concatenate([np.arange(Tl), [-1, Tl, Tl + 5, Tl]]), D),
        jnp.int32)
    want = ref.delta_join_ref(keys_l, rows, *parts)
    got = delta_join_pallas(keys_l, rows, *parts)
    assert (np.asarray(got) == np.asarray(want)).all()
    # fresh rids agree with the FULL partitioned probe at those rows
    W = 2
    mask_l = jnp.asarray(rng.integers(0, 2**32, (Tl, W)), jnp.uint32)
    mask_r = jnp.asarray(rng.integers(0, 2**32, (Tr, W)), jnp.uint32)
    full_rid, _ = ref.partitioned_join_ref(keys_l, mask_l, *parts, mask_r)
    safe = np.clip(np.asarray(rows), 0, Tl - 1)
    assert (np.asarray(want) == np.asarray(full_rid)[safe]).all()


# --------------------------------------------- conditional partition refresh
def test_refresh_key_partitions_skips_clean_rebuilds_dirty():
    schema = TableSchema("t", ("k", "v"), 32, pk="k", dirty_cap=8)
    t = bulk_load(schema, {"k": np.arange(16) * 3, "v": np.arange(16)})
    parts0 = build_key_partitions(t["k"], t["_valid"], 4, 8)
    # clean batch: carried partitions pass through, no rebuild
    t1 = apply_updates(schema, t, empty_update_batch(schema,
                                                     UpdateSlots(2, 2, 2)))
    parts1, rebuilt1 = refresh_key_partitions(t1, "k", 4, 8, parts0)
    assert not bool(rebuilt1)
    for a, b in zip(parts1, parts0):
        assert (np.asarray(a) == np.asarray(b)).all()
    # dirty batch: rebuild fires and reflects the new snapshot
    b2 = empty_update_batch(schema, UpdateSlots(2, 2, 2))
    b2["del_key"] = b2["del_key"].at[0].set(9)       # delete key 9 (row 3)
    b2["del_mask"] = b2["del_mask"].at[0].set(True)
    t2 = apply_updates(schema, t1, b2)
    parts2, rebuilt2 = refresh_key_partitions(t2, "k", 4, 8, parts1)
    assert bool(rebuilt2)
    want = build_key_partitions(t2["k"], t2["_valid"], 4, 8)
    for a, b in zip(parts2, want):
        assert (np.asarray(a) == np.asarray(b)).all()
    assert 3 not in np.asarray(parts2[1]).ravel().tolist()


# ------------------------------------------------------ engine-level paths
SCALE_I, SCALE_C = 128, 256


@pytest.fixture(scope="module")
def indexless_world():
    rng = np.random.default_rng(5)
    plan = tpcw.build_tpcw_plan(SCALE_I, SCALE_C, dense_pk_index=False)
    data = tpcw.generate_data(rng, SCALE_I, SCALE_C)
    return plan, data


def _probe_recording_backend(full_probes, delta_probes):
    """The jnp backend with every partitioned-probe invocation recorded
    (trace-time: pair with jit=False engines)."""
    base = backends.get_backend("jnp")

    def join_partitioned(*args):
        full_probes.append(args[0].shape[0])
        return base.join_partitioned(*args)

    def join_delta(*args):
        delta_probes.append(args[1].shape[0])
        return base.join_delta(*args)

    backends.register_backend(backends.OperatorBackend(
        name="probe-recording-jnp", scan=base.scan,
        join_block=base.join_block, join_partitioned=join_partitioned,
        groupby=base.groupby, scan_delta=base.scan_delta,
        join_delta=join_delta))
    return "probe-recording-jnp"


def test_steady_state_runs_delta_join_without_full_probe(indexless_world):
    """Acceptance: steady-state heartbeats (spine-side trickle, PK sides
    untouched) merge carried rids — the O(Tl x B) full probe is never
    invoked after the seeding cycle, only O(D x B) dirty probes — and
    stay ticket-for-ticket equal to the query-at-a-time oracle."""
    plan, data = indexless_world
    full_probes, delta_probes = [], []
    name = _probe_recording_backend(full_probes, delta_probes)
    eng = SharedDBEngine(plan, tpcw.DEFAULT_UPDATE_SLOTS, data, jit=False,
                         kernels=name)
    base = QueryAtATimeEngine(plan, data, jit=False)
    eng.submit("get_book", {0: (1, 1)})
    eng.run_cycle()                                   # seeds both carries
    assert eng.last_scan_path == "full"
    assert eng.last_join_path == "full"
    assert full_probes and not delta_probes
    assert all(eng.last_parts_rebuilt.values())
    full_probes.clear()

    for i in range(4):
        # customer is no join's PK table: spine-side only
        upd = ("customer", "update", {"key": 10 + i,
                                      "col": "c_expiration",
                                      "val": 13000 + i})
        eng.submit_update(*upd)
        base.apply_update(*upd)
        t = eng.submit("get_book", {0: (10 + i, 10 + i)})
        eng.run_cycle()
        assert eng.last_scan_path == "delta"
        assert eng.last_join_path == "delta"
        assert eng.last_delta_overflow == 0
        assert not any(eng.last_parts_rebuilt.values())
        want = base.execute(t.template, t.params).result
        assert (np.asarray(t.result["rows"])
                == np.asarray(want["rows"])).all()
    assert eng.delta_join_cycles == 4
    assert not full_probes                            # dirty probes only
    assert delta_probes


def test_pk_side_write_falls_back_to_full_probe_and_reseeds(
        indexless_world):
    """An item write is a PK-side write for the order_line->item and
    cart->item joins: that heartbeat must run full probes (partitions
    rebuild), then the NEXT clean heartbeat is back on the delta path
    with rids reseeded from the full probe."""
    plan, data = indexless_world
    eng = SharedDBEngine(plan, tpcw.DEFAULT_UPDATE_SLOTS, data, jit=False)
    base = QueryAtATimeEngine(plan, data, jit=False)
    eng.submit("order_lines", {0: (10, 10)})
    eng.run_cycle()                                   # seed
    eng.submit("order_lines", {0: (10, 10)})
    eng.run_cycle()                                   # steady: delta joins
    assert eng.last_join_path == "delta"
    # PK-side write: move item 50's cost (item is order_lines' join PK)
    upd = ("item", "update", {"key": 50, "col": "i_cost", "val": 7777})
    eng.submit_update(*upd)
    base.apply_update(*upd)
    t = eng.submit("order_lines", {0: (10, 10)})
    eng.run_cycle()
    assert eng.last_scan_path == "delta"              # scans still delta
    assert eng.last_join_path == "full"               # joins fell back
    assert eng.last_parts_rebuilt["item"]
    assert not eng.last_parts_rebuilt["orders"]
    want = base.execute("order_lines", {0: (10, 10)}).result
    assert set(int(x) for x in np.asarray(t.result["rows"]) if x >= 0) \
        == set(int(x) for x in want["rows"] if x >= 0)
    # clean beat: carried rids were reseeded by the full probe
    t2 = eng.submit("order_lines", {0: (10, 10)})
    eng.run_cycle()
    assert eng.last_join_path == "delta"
    want = base.execute("order_lines", {0: (10, 10)}).result
    assert set(int(x) for x in np.asarray(t2.result["rows"]) if x >= 0) \
        == set(int(x) for x in want["rows"] if x >= 0)


def test_admission_change_rides_carried_rids_exactly(indexless_world):
    """Rids are admission-invariant: a NEW template admitted on a
    delta-join heartbeat (no dirty rows at all) must be answered
    entirely from carried rids — the masks change, the rids don't."""
    plan, data = indexless_world
    eng = SharedDBEngine(plan, tpcw.DEFAULT_UPDATE_SLOTS, data, jit=False)
    base = QueryAtATimeEngine(plan, data, jit=False)
    eng.submit("get_cart", {0: (3, 3)})
    eng.run_cycle()                                   # seed
    t = eng.submit("get_cart", {0: (12, 12)})         # different params
    eng.run_cycle()
    assert eng.last_join_path == "delta"
    want = base.execute("get_cart", {0: (12, 12)}).result
    assert set(int(x) for x in np.asarray(t.result["rows"]) if x >= 0) \
        == set(int(x) for x in want["rows"] if x >= 0)


def _overflow_world():
    from repro.core.plan import Join, Pred, QueryTemplate, compile_plan
    from repro.core.storage import Catalog
    cat = Catalog([
        TableSchema("fact", ("f_id", "f_dim", "f_v"), 640, pk="f_id",
                    dirty_cap=2),
        TableSchema("dim", ("d_id", "d_v"), 640, pk="d_id", dirty_cap=2),
    ])
    tpl = QueryTemplate("by_v", "fact", preds=(Pred("fact", "f_v"),),
                        joins=(Join("f_dim", "dim"),), limit=64)
    plan = compile_plan(cat, [tpl], {"by_v": 32}, max_results=64)
    data = {
        "fact": {"f_id": np.arange(320), "f_dim": np.arange(320) % 64,
                 "f_v": np.arange(320) % 8},
        "dim": {"d_id": np.arange(64), "d_v": np.arange(64)},
    }
    return plan, SharedDBEngine(plan, UpdateSlots(4, 4, 4), data,
                                jit=False, kernels="jnp")


def test_dirty_overflow_forces_full_scan_and_join():
    """A batch overflowing a dirty set cannot trust EITHER carry half:
    the heartbeat runs the full rescan (which reseeds scan words, parts
    and rids) and the next clean beat is delta again."""
    plan, eng = _overflow_world()
    assert any(j.kind == "partitioned"
               for j in lower_plan(plan).joins)
    t0 = eng.submit("by_v", {0: (5, 5)})
    eng.run_cycle()
    assert eng.last_join_path == "full"               # first heartbeat
    eng.submit("by_v", {0: (5, 5)})
    eng.run_cycle()
    assert eng.last_join_path == "delta"
    # 3 updates overflow fact.dirty_cap=2 -> full everything
    for key in (1, 2, 9):
        eng.submit_update("fact", "update", {"key": key, "col": "f_v",
                                             "val": 5})
    t1 = eng.submit("by_v", {0: (5, 5)})
    eng.run_cycle()
    assert eng.last_scan_path == "full"
    assert eng.last_join_path == "full"
    rows1 = set(int(x) for x in np.asarray(t1.result["rows"]) if x >= 0)
    assert {1, 2, 9} <= rows1
    # reseeded: clean beat back to delta, same answer as a fresh engine
    t2 = eng.submit("by_v", {0: (5, 5)})
    eng.run_cycle()
    assert eng.last_join_path == "delta"
    rows2 = set(int(x) for x in np.asarray(t2.result["rows"]) if x >= 0)
    assert rows2 == rows1


def test_delta_joins_flag_forces_full_probes(indexless_world):
    """delta_joins=False keeps delta SCANS but full probes — the
    benchmark baseline — and both engines answer identically."""
    plan, data = indexless_world

    def drive(eng):
        out = []
        eng.submit("get_book", {0: (3, 3)})
        eng.run_cycle()
        for i in range(2):
            t = eng.submit("get_book", {0: (3 + i, 3 + i)})
            eng.run_cycle()
            out.append(np.asarray(t.result["rows"]))
        return out

    a = SharedDBEngine(plan, tpcw.DEFAULT_UPDATE_SLOTS, data, jit=False)
    b = SharedDBEngine(plan, tpcw.DEFAULT_UPDATE_SLOTS, data, jit=False,
                       delta_joins=False)
    ra, rb = drive(a), drive(b)
    assert a.delta_join_cycles == 2 and a.full_join_cycles == 1
    assert b.delta_join_cycles == 0 and b.full_join_cycles == 3
    assert b.last_join_path == "full"
    for x, y in zip(ra, rb):
        assert (x == y).all()


def test_carry_layout_assertion_refuses_foreign_carry(indexless_world):
    """Satellite audit: a delta heartbeat must never consume a carry
    produced under a different admission layout.  The guard is an
    always-on RuntimeError (not a strippable assert) so it survives
    ``python -O`` — plan folding swaps layouts at runtime."""
    plan, data = indexless_world
    eng = SharedDBEngine(plan, tpcw.DEFAULT_UPDATE_SLOTS, data, jit=False)
    eng.submit("get_book", {0: (1, 1)})
    eng.run_cycle()
    eng._carry_token = ("other-layout",)              # simulate re-lower
    eng.submit("get_book", {0: (1, 1)})
    with pytest.raises(RuntimeError, match="admission layout"):
        eng.run_cycle()


def test_cycle_result_reports_join_path(indexless_world):
    """CycleResult attribution: join_path rides along with scan_path."""
    plan, data = indexless_world
    eng = SharedDBEngine(plan, tpcw.DEFAULT_UPDATE_SLOTS, data, jit=False)
    eng.submit("get_book", {0: (1, 1)})
    first = eng.run_until_drained()
    assert [d.join_path for d in first] == ["full"]
    eng.submit("get_book", {0: (2, 2)})
    second = eng.run_until_drained()
    assert [d.join_path for d in second] == ["delta"]
    # dense-index plans have no carried joins: join_path stays empty
    dense_plan = tpcw.build_tpcw_plan(SCALE_I, SCALE_C)
    dense = SharedDBEngine(dense_plan, tpcw.DEFAULT_UPDATE_SLOTS, data,
                           jit=False)
    dense.submit("get_book", {0: (1, 1)})
    assert [d.join_path for d in dense.run_until_drained()] == [""]


def test_jnp_pallas_delta_join_engine_parity(indexless_world):
    """Both backends produce identical tickets across seed, delta-join
    and PK-fallback heartbeats."""
    plan, data = indexless_world
    engines = {k: SharedDBEngine(plan, tpcw.DEFAULT_UPDATE_SLOTS, data,
                                 jit=False, kernels=k)
               for k in ("jnp", "pallas")}
    queries = [("get_book", {0: (5, 5)}), ("order_lines", {0: (10, 10)}),
               ("get_cart", {0: (12, 12)})]
    beats = [
        [],                                           # seed
        [("customer", "update", {"key": 3, "col": "c_expiration",
                                 "val": 13333})],     # delta joins
        [("item", "update", {"key": 50, "col": "i_cost",
                             "val": 4242})],          # PK fallback
        [],                                           # delta again
    ]
    for updates in beats:
        tickets = {}
        for k, eng in engines.items():
            for u in updates:
                eng.submit_update(*u)
            tickets[k] = [eng.submit(n, p) for n, p in queries]
            eng.run_cycle()
        assert (engines["jnp"].last_join_path
                == engines["pallas"].last_join_path)
        for a, b in zip(tickets["jnp"], tickets["pallas"]):
            assert (np.asarray(a.result["rows"])
                    == np.asarray(b.result["rows"])).all(), a.template
    assert engines["pallas"].delta_join_cycles >= 2

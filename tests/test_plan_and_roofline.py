"""Plan compiler semantics (operator merging, Fig. 2/3) and roofline
machinery (collective parsing, term derivation)."""
import numpy as np
import pytest

from repro.core.plan import compile_plan
from repro.roofline.analysis import (HW, model_flops, parse_collectives,
                                     roofline_terms)
from repro.workloads import tpcw


def test_templates_sharing_join_merge_to_one_node():
    plan = tpcw.build_tpcw_plan(400, 1200)
    # get_book and search_author both join item->author: ONE shared node
    ia = [j for j in plan.joins
          if j.spine == "item" and j.pk_table == "author"]
    assert len(ia) == 1
    assert set(ia[0].subscribers) >= {"get_book", "search_author"}
    # search_subject / search_title share the item.i_title sort node
    ts = [s for s in plan.sorts if s.spine == "item" and s.col == "i_title"]
    assert len(ts) == 1
    assert set(ts[0].subscribers) >= {"search_subject", "search_title",
                                      "search_author"}
    # one scan node per base table, regardless of template count
    assert len(plan.scans) <= len(plan.catalog.schemas)


def test_slot_ranges_disjoint_and_within_capacity():
    plan = tpcw.build_tpcw_plan(400, 1200)
    seen = set()
    for name, cap in plan.caps.items():
        o = plan.offsets[name]
        rng = set(range(o, o + cap))
        assert not (rng & seen)
        seen |= rng
    assert max(seen) < plan.qcap
    assert plan.qcap % 32 == 0


def test_sub_mask_and_word_range_consistent():
    plan = tpcw.build_tpcw_plan(400, 1200)
    for node in plan.sorts + plan.groups:
        names = node.subscribers
        sub = plan.sub_mask(names)
        wlo, whi = plan.word_range(names)
        # all set bits fall inside the word window
        assert all(sub[w] == 0 for w in range(len(sub))
                   if not wlo <= w < whi)


# ---------------------------------------------------------------- roofline
HLO_SAMPLE = """
  %all-gather.1 = f32[2048,352]{1,0} all-gather(%x), channel_id=1, replica_groups=[16,16]<=[256], dimensions={0}
  %all-reduce.7 = bf16[128,64]{1,0} all-reduce(%y), channel_id=2, replica_groups=[32,8]<=[256], to_apply=%add
  %reduce-scatter.2 = f32[64,64]{1,0} reduce-scatter(%z), channel_id=3, replica_groups=[16,16]<=[256], dimensions={0}
  %all-to-all.3 = f32[16,16]{1,0} all-to-all(%w), channel_id=4, replica_groups=[1,256]<=[256]
  %collective-permute.9 = u32[8]{0} collective-permute(%v), channel_id=5
  %fusion.1 = f32[10]{0} fusion(%all-gather.1), kind=kLoop
"""


def test_parse_collectives_kinds_and_sizes():
    out = parse_collectives(HLO_SAMPLE, default_group=256)
    assert out["counts"] == {"all-gather": 1, "all-reduce": 1,
                             "reduce-scatter": 1, "all-to-all": 1,
                             "collective-permute": 1}
    ag = 2048 * 352 * 4
    assert out["bytes_by_kind"]["all-gather"] == ag
    # ring traffic: ag output * (gs-1)/gs with gs=16
    np.testing.assert_allclose(out["link_traffic_by_kind"]["all-gather"],
                               ag * 15 / 16)
    ar = 128 * 64 * 2
    np.testing.assert_allclose(out["link_traffic_by_kind"]["all-reduce"],
                               2 * ar * 7 / 8)
    rs = 64 * 64 * 4
    np.testing.assert_allclose(
        out["link_traffic_by_kind"]["reduce-scatter"], rs * 15)
    assert out["link_traffic_by_kind"]["collective-permute"] == 8 * 4


def test_parse_collectives_skips_async_done_and_fusion_refs():
    txt = """
  %all-gather-start.1 = (f32[8]{0}, f32[128]{0}) all-gather-start(%x), replica_groups=[16,16]<=[256]
  %all-gather-done.1 = f32[128]{0} all-gather-done(%all-gather-start.1)
"""
    out = parse_collectives(txt)
    assert out["counts"] == {"all-gather": 1}
    assert out["bytes_by_kind"]["all-gather"] == 128 * 4  # result, not operand


def test_roofline_terms_dominance():
    t = roofline_terms(flops=1e15, bytes_accessed=1e12,
                       collective_bytes=1e10, n_chips=256)
    assert t["dominant"] == "compute"
    assert t["roofline_fraction"] == 1.0
    t2 = roofline_terms(flops=1e12, bytes_accessed=1e15,
                        collective_bytes=0, n_chips=256)
    assert t2["dominant"] == "memory"
    assert 0 < t2["roofline_fraction"] < 0.01


def test_model_flops_moe_counts_active_only():
    from repro.configs import get_config, SHAPES
    mix = get_config("mixtral-8x22b")
    dense = get_config("qwen2-72b")
    f_mix = model_flops(mix, SHAPES["train_4k"])
    # active ~39B of 141B params
    assert f_mix < 6 * mix.param_count() * 4096 * 256 * 0.45
    f_dense = model_flops(dense, SHAPES["train_4k"])
    assert f_dense == pytest.approx(
        6 * (dense.active_param_count()
             - dense.vocab_padded() * dense.d_model) * 4096 * 256)


def test_workload_generator_covers_all_interactions():
    rng = np.random.default_rng(0)
    gen = tpcw.WorkloadGenerator(rng, 400, 1200)
    for kind in tpcw.MIXES["shopping"]:
        it = gen.interaction(kind)
        assert it.kind == kind
        assert it.queries or it.updates
        for name, params in it.queries:
            assert name in {t for t in
                            tpcw.build_tpcw_plan(400, 1200).templates}


def test_mix_probabilities_sum_to_100():
    for mix, probs in tpcw.MIXES.items():
        assert abs(sum(probs.values()) - 100.0) < 0.6, mix

"""Mutation: a full-window range compare reachable on the delta path.

The mutant is the REAL unsharded delta cycle plus one (capacity,
q_window) ``ge`` over the widest predicated stage — the full-rescan
work shape a botched pane-slicing refactor would reintroduce.  The
width classifier must flag it.
"""
EXPECT = "jaxpr-delta-width"


def findings(ctx):
    import jax
    import jax.numpy as jnp

    from repro.analysis_static.jaxpr_passes import lint_delta_width

    tr = ctx["traced"]()
    lowered, delta = ctx["lowered"], tr["delta"]
    st = max((s for s in lowered.scans
              if s.cols and 32 * s.delta_words < s.q_window),
             key=lambda s: s.q_window)
    cap = lowered.plan.catalog.schemas[st.table].capacity

    def mutant(state, carry, queries, updates):
        out = delta(state, carry, queries, updates)
        col = state[st.table][st.cols[0]]
        full = col[:, None] >= jnp.zeros((1, st.q_window), col.dtype)
        return out, full.any()

    jx = jax.make_jaxpr(mutant)(*tr["args_delta"])
    fs = lint_delta_width(jx, lowered, location="mutant delta")
    assert cap  # geometry sanity: the stage exists at this scale
    return fs

"""Mutation: two templates' admission slot ranges overlap.

A plan whose offsets collide would route two templates' parameters into
the same admission bits — queries of one template would answer with the
other's predicate.  ``lint_slot_layout`` must refuse the layout.
"""
import dataclasses

EXPECT = "ir-slot-overlap"


def findings(ctx):
    from repro.analysis_static.ir_passes import lint_slot_layout
    plan = ctx["plan"]
    names = sorted(plan.offsets, key=plan.offsets.get)
    a, b = names[0], names[1]
    offsets = dict(plan.offsets)
    offsets[b] = plan.offsets[a] + max(1, plan.caps[a] // 2)
    return lint_slot_layout(dataclasses.replace(plan, offsets=offsets))

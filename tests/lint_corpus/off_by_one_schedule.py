"""Mutation: the fused kernel's schedule drops its last row.

A truncated schedule leaves one pane tile / probe slot with no owning
grid program — that block's output is whatever garbage the buffer held.
Both the coverage rule and the grid-length rule must fire.
"""
EXPECT = "kernel-schedule-coverage"


def findings(ctx):
    from repro.analysis_static.kernel_passes import lint_fused_schedule
    from repro.kernels.fused_delta import build_schedule
    sgeom, jgeom = ctx["geometry"]()
    schedule = build_schedule(sgeom, jgeom)
    truncated = schedule[:-1]
    return lint_fused_schedule(sgeom, jgeom, truncated,
                               grid_len=truncated.shape[0],
                               location="mutant fused")

"""Mutation: an ``all_gather`` smuggled onto the delta path.

The mutant is the REAL 2-shard delta cycle plus one extra shard_map'd
all_gather over a row-sharded carry leaf — exactly what an accidental
cross-shard dependency would trace to.  The collective detector must
flag the beat at every shard count.
"""
EXPECT = "jaxpr-delta-collective"


def findings(ctx):
    import jax
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.analysis_static.jaxpr_passes import lint_delta_collectives

    sh = ctx["sharded"]()
    spec, delta = sh["spec"], sh["delta"]

    def mutant(state, carry, queries, updates):
        out = delta(state, carry, queries, updates)
        words = next(iter(carry["scan"].values()))
        gathered = shard_map(
            lambda w: jax.lax.all_gather(w, spec.axis),
            mesh=spec.mesh, in_specs=P(spec.axis),
            out_specs=P(), check_rep=False)(words)
        return out, gathered.sum()

    jx = jax.make_jaxpr(mutant)(*sh["args_delta"])
    return lint_delta_collectives(jx, location="mutant delta")

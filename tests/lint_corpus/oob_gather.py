"""Mutation: a dirty-row gather index one past the padded pane extent.

The BlockSpec index map would DMA a block outside the gathered rows
buffer (or clamp onto the last real tile — someone else's rows).  The
gather-bounds rule must fire.
"""
EXPECT = "kernel-gather-bounds"


def findings(ctx):
    import numpy as np

    from repro.analysis_static.kernel_passes import (lint_gather_bounds,
                                                     synthesize_sdesc)
    from repro.kernels.fused_delta import _DIRTY
    sgeom, jgeom = ctx["geometry"]()
    sdesc = np.array(synthesize_sdesc(sgeom, jgeom))
    dirty = np.flatnonzero(sdesc[:, 0] == _DIRTY)
    row = int(dirty[0])
    owner = int(sdesc[row, 1])
    sdesc[row, 3] = sgeom[owner].nt * sgeom[owner].R  # one past the end
    return lint_gather_bounds(sgeom, jgeom, sdesc,
                              location="mutant fused")

"""Mutation: two grid programs own the same pane tile.

Duplicating a pane tile index in the descriptor makes the shipped
output index maps route two programs' writes to one real block — a
device-order-dependent race.  The garbage-park pass (which evaluates
the REAL ``make_out_specs`` index maps against the descriptor) must
report a multi-writer block.
"""
EXPECT = "kernel-garbage-park"


def findings(ctx):
    import numpy as np

    from repro.analysis_static.kernel_passes import (lint_garbage_park,
                                                     synthesize_sdesc)
    from repro.kernels.fused_delta import _PANE
    sgeom, jgeom = ctx["geometry"]()
    sdesc = np.array(synthesize_sdesc(sgeom, jgeom))
    panes = np.flatnonzero(sdesc[:, 0] == _PANE)
    first = next(o for o in range(len(sgeom))
                 if (sdesc[panes, 1] == o).sum() >= 2 or len(sgeom) == 1)
    mine = panes[sdesc[panes, 1] == first]
    if len(mine) >= 2:
        sdesc[mine[1], 2] = sdesc[mine[0], 2]   # both write tile 0
    else:
        # single-tile scan: clone the row so two programs own tile 0
        sdesc = np.vstack([sdesc, sdesc[mine[0]]])
    return lint_garbage_park(sgeom, jgeom, sdesc,
                             location="mutant fused")

"""Mutation: the rid carry gets donated.

The rid carry's arrays double as the previous heartbeat's in-flight
``results["_join_rids"]`` — donating them frees buffers the collector
is still reading (the bug class PR 4 fixed).  The use-after-donate
checker must flag a donation spec that includes argument 2 of the
delta-join flavour.
"""
EXPECT = "jaxpr-donated-alias"


def findings(ctx):
    from repro.analysis_static.jaxpr_passes import lint_donation
    tr = ctx["traced"]()
    return lint_donation(
        tr["delta_j"], tr["args_dj"], (0, 1, 2),
        {2: "rid carry (aliases the previous beat's in-flight results)"},
        location="mutant delta_join")

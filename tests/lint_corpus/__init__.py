"""Seeded-mutation corpus for planlint (tests/test_planlint.py).

Each module plants ONE class of bug the static verifier must catch:
``EXPECT`` names the rule id that must fire, and ``findings(ctx)``
builds the mutated artifact and runs the relevant pass against it.
``ctx`` is the shared fixture dict built once per test session (plan,
key_stats, lowered IR, and — for the sharded mutations — a 2-shard
traced cycle setup).  A mutation that stops producing its rule id means
the verifier regressed, not the corpus.
"""

CORPUS = (
    "overlapping_slots",
    "smuggled_all_gather",
    "aliased_donated_carry",
    "off_by_one_schedule",
    "oob_gather",
    "double_writer",
    "full_width_compare",
)

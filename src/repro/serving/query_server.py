"""SharedDB-facing serving front end with runtime template registration.

``QueryCycleServer`` wraps a ``SharedDBEngine`` with the client protocol
of the paper's middleware tier — submit / heartbeat / collect — plus the
one operation the always-on plan could not offer before dynamic plan
folding (core/folding.py): ``register_template()``, which admits a NEW
query shape into the running shared plan without stopping the world.

Fold-in-flight admission rules
------------------------------
* No fold in flight — a registration starts one immediately (background
  build; the current compiled heartbeat keeps serving).
* Fold in flight — the registration BATCHES: it is queued and folded in
  one shot right after the in-flight fold commits (one migration beat
  per batch, not per template).  ``heartbeat()`` advances the batch.
* Queries for a registered-but-not-yet-folded template are ACCEPTED and
  held; they flush into the engine's admission queues the moment the
  template's fold opens them, and are served after the fold's single
  migration (full-rescan) beat.  Already-admitted clients never see the
  fold: their templates keep their slot ranges (prefix-stable
  extension), and every beat until the swap runs the old compiled plan.
* Re-registering a known template is a no-op (idempotent client retry).
"""
from __future__ import annotations

import collections
from typing import Dict, List, Optional, Tuple

from repro.core.executor import CycleResult, SharedDBEngine, Ticket
from repro.core.plan import QueryTemplate


class QueryCycleServer:
    def __init__(self, engine: SharedDBEngine,
                 background_folds: bool = True):
        self.engine = engine
        self._background = background_folds
        # registrations batched while a fold is in flight
        self._pending_reg: List[Tuple[QueryTemplate, int]] = []
        # tickets held for templates the engine cannot queue yet
        self._held: Dict[str, collections.deque] = {}
        self.registered = set(engine.plan.templates)
        self.folds_started = 0

    # ------------------------------------------------------ registration
    def register_template(self, template: QueryTemplate,
                          cap: int) -> dict:
        """Admit a new query template into the running plan."""
        return self.register_templates([(template, cap)])[0]

    def register_templates(
            self, batch: List[Tuple[QueryTemplate, int]]) -> List[dict]:
        """Admit several templates in ONE fold — one migration beat for
        the whole batch (or one batched registration if a fold is
        already in flight)."""
        out: List[dict] = []
        todo: List[Tuple[QueryTemplate, int]] = []
        for template, cap in batch:
            if template.name in self.registered:
                out.append({"status": "already-registered",
                            "template": template.name})
                continue
            self.registered.add(template.name)
            self._held.setdefault(template.name, collections.deque())
            todo.append((template, cap))
        if not todo:
            return out
        if self.engine.fold_in_flight():
            self._pending_reg.extend(todo)
            out.extend({"status": "batched", "template": t.name,
                        "behind": len(self._pending_reg)}
                       for t, _ in todo)
            return out
        recipe = self.engine.begin_fold(
            [t for t, _ in todo], {t.name: c for t, c in todo},
            background=self._background)
        self.folds_started += 1
        self._flush_held()
        out.extend({"status": "folding", "template": t.name,
                    "recipe": recipe} for t, _ in todo)
        return out

    def _advance_folds(self) -> None:
        """Start the next batched fold once the engine is free, and
        flush held queries for any template whose queue now exists."""
        if self._pending_reg and not self.engine.fold_in_flight():
            batch, self._pending_reg = self._pending_reg, []
            self.engine.begin_fold(
                [t for t, _ in batch], {t.name: c for t, c in batch},
                background=self._background)
            self.folds_started += 1
        self._flush_held()

    def _flush_held(self) -> None:
        for name in list(self._held):
            if self.engine.accepts(name):
                q = self._held.pop(name)
                while q:
                    self.engine.submit_ticket(q.popleft())

    # --------------------------------------------------------- admission
    def submit(self, template: str, params) -> Ticket:
        if template not in self.registered:
            raise KeyError(
                f"unknown template {template!r} — register_template() "
                "first")
        if self.engine.accepts(template):
            return self.engine.submit(template, params)
        t = self.engine.make_ticket(template, params)
        self._held[template].append(t)
        return t

    def submit_update(self, table: str, kind: str, payload: dict) -> None:
        self.engine.submit_update(table, kind, payload)

    def pending(self) -> int:
        return self.engine.pending() + sum(
            len(q) for q in self._held.values())

    # --------------------------------------------------------- heartbeat
    def heartbeat(self, max_cycles: int = 1000,
                  pipelined: bool = False) -> List[CycleResult]:
        """Run the engine until drained, advancing batched folds at the
        beat boundaries (a fold can only start/commit between beats)."""
        self._advance_folds()
        out = list(self.engine.run_until_drained(max_cycles=max_cycles,
                                                 pipelined=pipelined))
        # a fold that committed during the drain may have unblocked a
        # batched registration (and its held queries): serve those too
        # within the same client call
        self._advance_folds()
        if self.engine.pending():
            out.extend(self.engine.run_until_drained(
                max_cycles=max_cycles, pipelined=pipelined))
        return out

"""SharedDB-cycle LLM serving (the paper's architecture, applied to LMs).

Requests queue while a cycle runs; each heartbeat admits up to
``prefill_budget`` queued requests into free slots (shared batched prefill)
and then executes ONE decode step for ALL active slots — one always-on
compiled plan, reused for the server's lifetime.  Per-cycle work is a
static function of (capacity, max_seq) — never of the queue length — so
worst-case first-token latency is bounded by 2 cycles, the paper's §3.5
guarantee verbatim.  Idle slots still flow through the plan (bounded
computation: the cycle cost is CONSTANT, which is what makes the SLA hold
under any load).
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ArchConfig
from repro.models import transformer
from repro.models.common import MeshAxes
from repro.models.registry import get_model


@dataclasses.dataclass
class Request:
    id: int
    prompt: List[int]
    max_new_tokens: int
    arrival: float
    output: List[int] = dataclasses.field(default_factory=list)
    first_token_time: Optional[float] = None
    done_time: Optional[float] = None
    slot: int = -1
    # the request hit the KV-cache capacity (max_seq) before producing
    # max_new_tokens and was force-finished to protect the cache
    truncated: bool = False


def _cache_insert(cache, cache1, slot):
    """Insert a batch-1 prefill cache into slot `slot` of the slot cache.

    Stacked group entries ("g*") carry batch at axis 1; leftover entries
    ("x*") at axis 0.
    """
    def ins(dst, src, axis):
        idx = [0] * dst.ndim
        idx[axis] = slot
        return jax.lax.dynamic_update_slice(dst, src.astype(dst.dtype),
                                            tuple(idx))

    out = {}
    for key, entry in cache.items():
        axis = 1 if key.startswith("g") else 0
        out[key] = {k: ins(entry[k], cache1[key][k], axis)
                    for k in entry}
    return out


class CycleServer:
    def __init__(self, cfg: ArchConfig, axes: MeshAxes = MeshAxes(), *,
                 capacity: int = 8, max_seq: int = 256,
                 prefill_budget: int = 2, prefill_len: int = 64,
                 greedy: bool = True, params=None, seed: int = 0):
        self.cfg = cfg
        self.axes = axes
        self.capacity = capacity
        self.max_seq = max_seq
        self.prefill_budget = prefill_budget
        self.prefill_len = prefill_len
        self.greedy = greedy
        api = get_model(cfg, axes)
        self.api = api
        self.params = params if params is not None else \
            api.init_params(jax.random.PRNGKey(seed))
        self.cache = transformer.init_cache(
            cfg, capacity, max_seq, axes, ctx_len=self._ctx_len())
        # always-on plans: compiled once, reused every heartbeat
        self._decode = jax.jit(
            lambda p, c, t, pos: api.decode_step(p, c, t, pos),
            donate_argnums=(1,))
        # ``last`` (traced scalar) is the index of the prompt's true last
        # token inside the right-padded prefill window: passing it as
        # runtime data keeps ONE compiled prefill for every prompt length
        self._prefill = jax.jit(
            lambda p, batch, last: api.prefill(batch=batch, params=p,
                                               cache_capacity=max_seq,
                                               last_pos=last))
        self._insert = jax.jit(_cache_insert, donate_argnums=(0,),
                               static_argnums=(2,))
        self._queue: collections.deque = collections.deque()
        self._ids = itertools.count()
        self._slots: List[Optional[Request]] = [None] * capacity
        self._pos = np.zeros(capacity, np.int64)
        self._last_tok = np.zeros(capacity, np.int64)
        self._pending_logits = None
        self.cycles = 0
        self.completed: List[Request] = []
        # per-cycle wall times / admitted-prefill / active-slot counts of
        # the last run_until_drained (latency + load accounting parity
        # with the relational engine's CycleResult fields)
        self.last_drain_walls: List[float] = []
        self.last_drain_admitted: List[int] = []
        self.last_drain_active: List[int] = []
        self.last_admitted = 0       # prefills admitted by the last beat

    def _ctx_len(self) -> int:
        if self.cfg.enc_dec:
            return self.prefill_len * self.cfg.dec_ratio
        if self.cfg.cross_every:
            return self.cfg.n_vision_tokens
        return 0

    # ---------------------------------------------------------------- API
    def submit(self, prompt: List[int], max_new_tokens: int = 16) -> Request:
        r = Request(next(self._ids), list(prompt), max_new_tokens,
                    time.time())
        self._queue.append(r)
        return r

    def pending(self) -> int:
        return len(self._queue)

    def active(self) -> int:
        return sum(1 for s in self._slots if s is not None)

    # ---------------------------------------------------------- heartbeat
    def _admit(self) -> int:
        budget = self.prefill_budget
        admitted = 0
        for slot in range(self.capacity):
            if budget == 0 or not self._queue:
                break
            if self._slots[slot] is not None:
                continue
            req = self._queue.popleft()
            budget -= 1
            admitted += 1
            P = self.prefill_len
            toks = np.asarray(req.prompt[-P:] if len(req.prompt) >= P
                              else req.prompt + [0] * (P - len(req.prompt)),
                              np.int32)
            # short prompts are RIGHT-padded to the compiled prefill
            # shape, so the first token must come from the true last
            # prompt position — position P - 1 holds a pad token, and
            # its logits are garbage for the continuation.  Causal
            # attention makes position n_real - 1 identical to an
            # unpadded prefill's last position (it never sees the pads).
            # An EMPTY prompt has no last token; it degenerates to
            # conditioning on the single pad token at position 0 (the
            # clamp keeps last_pos in range) rather than indexing at -1.
            n_real = max(1, min(len(req.prompt), P))
            batch = {"tokens": jnp.asarray(toks[None])}
            if self.cfg.enc_dec:
                batch["frames"] = jnp.zeros(
                    (1, self._ctx_len(), self.cfg.d_model), jnp.bfloat16)
            if self.cfg.cross_every:
                batch["vision"] = jnp.zeros(
                    (1, self.cfg.n_vision_tokens, self.cfg.d_model),
                    jnp.bfloat16)
            logits, cache1 = self._prefill(self.params, batch,
                                           jnp.int32(n_real - 1))
            self.cache = self._insert(self.cache, cache1, slot)
            tok = int(jnp.argmax(logits[0]))
            req.slot = slot
            req.output.append(tok)
            req.first_token_time = time.time()
            self._slots[slot] = req
            self._pos[slot] = n_real
            self._last_tok[slot] = tok
        return admitted

    def dispatch(self) -> None:
        """Admit + prefill, then launch ONE shared decode step for all
        active slots.  Returns while the device still computes (JAX async
        dispatch) — the same dispatch/collect heartbeat protocol as
        core/executor.SharedDBEngine, so host-side routing of cycle N can
        overlap device execution."""
        if self._pending_logits is not None:
            raise RuntimeError(
                "dispatch() with a decode step already in flight: decode "
                "N+1 consumes N's tokens, collect() the previous cycle "
                "first")
        self.last_admitted = self._admit()
        tokens = jnp.asarray(self._last_tok[:, None], jnp.int32)
        positions = jnp.asarray(self._pos, jnp.int32)
        logits, self.cache = self._decode(self.params, self.cache, tokens,
                                          positions)
        self._pending_logits = logits

    def collect(self) -> List[Request]:
        """Synchronize on the in-flight decode step and route tokens.

        Unlike the relational engine, decode step N+1 consumes step N's
        argmax (the token feedback loop), so the serving pipeline depth is
        one: dispatch/collect split the heartbeat but cannot run two
        device cycles concurrently."""
        if self._pending_logits is None:
            return []          # nothing in flight (mirrors SharedDBEngine)
        logits = self._pending_logits
        self._pending_logits = None
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        finished = []
        now = time.time()
        for slot, req in enumerate(self._slots):
            if req is None:
                continue
            tok = int(nxt[slot])
            req.output.append(tok)
            # the decode step that just ran wrote KV at self._pos[slot];
            # the next step would write at +1.  A request whose next
            # position would leave the cache is FORCE-FINISHED: clamping
            # the position instead would overwrite the same KV entry
            # every subsequent step, silently corrupting the context of
            # a still-running generation.
            hit_cap = self._pos[slot] + 1 >= self.max_seq
            if len(req.output) >= req.max_new_tokens or hit_cap:
                req.truncated = hit_cap and \
                    len(req.output) < req.max_new_tokens
                req.done_time = now
                finished.append(req)
                self.completed.append(req)
                self._slots[slot] = None
                # park the freed slot at position 0: idle slots still
                # flow through the shared decode step (bounded
                # computation), and their dummy KV writes must stay in
                # bounds; admission overwrites the slot's cache wholesale
                self._pos[slot] = 0
                self._last_tok[slot] = 0
            else:
                self._pos[slot] += 1
                self._last_tok[slot] = tok
        self.cycles += 1
        return finished

    def run_cycle(self) -> List[Request]:
        """One heartbeat: admit + prefill, ONE shared decode step, route."""
        self.dispatch()
        return self.collect()

    def run_until_drained(self, max_cycles: int = 10000) -> List[Request]:
        """Heartbeat until idle; ``max_cycles`` bounds cycles run.

        Per-cycle wall times land in ``self.last_drain_walls``, admitted
        prefills in ``last_drain_admitted`` and post-admission active
        slots in ``last_drain_active`` — the same latency + load
        accounting the relational engine's run_until_drained returns via
        CycleResult (protocol parity for benchmarks and the SLA gate)."""
        out = []
        self.last_drain_walls = []
        self.last_drain_admitted = []
        self.last_drain_active = []
        while (self.pending() or self.active()) \
                and len(self.last_drain_walls) < max_cycles:
            t0 = time.time()
            self.dispatch()
            self.last_drain_admitted.append(self.last_admitted)
            self.last_drain_active.append(self.active())
            out.extend(self.collect())
            self.last_drain_walls.append(time.time() - t0)
        return out

from repro.serving.scheduler import CycleServer, Request  # noqa: F401
from repro.serving.query_server import QueryCycleServer  # noqa: F401

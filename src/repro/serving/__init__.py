from repro.serving.scheduler import CycleServer, Request  # noqa: F401

"""Three-term roofline analysis from compiled dry-run artifacts.

  compute term    = HLO_FLOPs / (chips x peak_FLOP/s)
  memory term     = HLO_bytes / (chips x HBM_bw)
  collective term = collective_bytes / (chips x link_bw)

Hardware constants (TPU v5e class, per the assignment):
  197 TFLOP/s bf16 per chip; 819 GB/s HBM; ~50 GB/s/link ICI.

Collective bytes are NOT in cost_analysis: we parse the post-SPMD
``compiled.as_text()`` and sum operand sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute.

Scan bodies appear ONCE in the HLO text and in ``cost_analysis`` even though
they execute ``n_groups`` times — the dry-run therefore lowers each step at
two reduced depths (G=2 and G=4) and extrapolates linearly:
  per_group = (T(4) - T(2)) / 2;   total(G) = T(2) + (G - 2) * per_group.
"""
from __future__ import annotations

import re
from collections import Counter
from typing import Dict

HW = {
    "peak_flops": 197e12,   # bf16 FLOP/s per chip
    "hbm_bw": 819e9,        # bytes/s per chip
    "ici_bw": 50e9,         # bytes/s per link
}

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?:\([^)]*\)|[a-z0-9]+\[[^\]]*\][^\s]*)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"([a-z]+[0-9]+|pred)\[([0-9,]*)\]")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_V1_RE = re.compile(r"replica_groups=\{\{([0-9, ]*)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_V2_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_V1_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip()])
    return default


def _ring_traffic(kind: str, out_bytes: int, gs: int) -> float:
    """Bytes crossing each device's link for one ring execution.

    Sizes come from the op's *output* in the partitioned (per-device)
    module: all-gather output is the gathered (full) tensor, all-reduce
    output the full partial, reduce-scatter output the local shard.
    """
    if gs <= 1:
        return 0.0
    if kind == "all-gather":
        return out_bytes * (gs - 1) / gs
    if kind == "all-reduce":
        return 2.0 * out_bytes * (gs - 1) / gs
    if kind == "reduce-scatter":
        return float(out_bytes * (gs - 1))
    if kind == "all-to-all":
        return out_bytes * (gs - 1) / gs
    return float(out_bytes)  # collective-permute


def parse_collectives(hlo_text: str, default_group: int = 256) -> Dict:
    """Collective schedule from post-SPMD HLO: per-kind output bytes,
    counts, and per-link ring traffic (bytes through each chip's link)."""
    per_kind = Counter()
    counts = Counter()
    traffic = Counter()
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        if "-done(" in line:
            continue  # async pair: count the -start only
        kind = m.group(1)
        # output shape(s) precede the op name; for (operand, result)
        # tuples of async starts, the result is the last shape.
        shapes = list(_SHAPE_RE.finditer(m.group(0)))
        if not shapes:
            continue
        out_bytes = _shape_bytes(shapes[-1].group(1), shapes[-1].group(2))
        gs = _group_size(line, default_group)
        per_kind[kind] += out_bytes
        counts[kind] += 1
        traffic[kind] += _ring_traffic(kind, out_bytes, gs)
    return {"bytes_by_kind": dict(per_kind),
            "counts": dict(counts),
            "link_traffic_by_kind": {k: float(v) for k, v in traffic.items()},
            "total_bytes": sum(per_kind.values()),
            "total_link_traffic": float(sum(traffic.values()))}


def roofline_terms(flops: float, bytes_accessed: float,
                   collective_bytes: float, n_chips: int) -> Dict:
    """flops / bytes_accessed are GLOBAL (summed over chips);
    collective_bytes is global link traffic (per-link traffic x chips) so
    the spec formula collective_bytes/(chips*link_bw) equals per-link time.
    """
    t_comp = flops / (n_chips * HW["peak_flops"])
    t_mem = bytes_accessed / (n_chips * HW["hbm_bw"])
    t_coll = collective_bytes / (n_chips * HW["ici_bw"])
    terms = {"compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll}
    dom = max(terms, key=terms.get)
    bound = max(t_comp, t_mem, t_coll)
    terms.update(
        dominant=dom.replace("_s", ""),
        step_time_s=bound,
        # fraction of the roofline-limited time spent doing useful compute
        roofline_fraction=(t_comp / bound) if bound > 0 else 0.0,
    )
    return terms


def fused_delta_footprint(lowered, shards: int = 1) -> Dict:
    """Analytic per-beat footprint of the fused delta mega-kernel.

    Counts the bytes moved and integer compare-ops one steady-state
    delta beat pays through ``backend.fused_delta``, from the lowered
    plan's static geometry (worst case: every stage's admission pane at
    its full ``delta_words`` span and every dirty set at ``dirty_cap``).
    Three phases per the kernel contract (kernels/fused_delta.py):

      pane   — re-admit ALL T rows against the A-word changed pane:
               reads cols [C,T] + pane bounds [C, 32A]x2, read-merges
               the [T, A] carry slice; 2*T*C*32A compares.
      dirty  — re-scan the D dirty rows against the FULL Q-slot window:
               reads [C,D] gathered cols + [C,Q] bounds x2, scatters
               [D, Q/32] words; 2*D*C*Q compares.
      probe  — each dirty spine row probes ONE bucket pane of width B:
               reads D keys + [D,B] bucket keys/rows, scatters D rids;
               2*D*B compares.

    ``shards`` divides the row-proportional terms (T and D are
    shard-local under the row mesh; probe sides are replicated).
    Feeds ``roofline_terms`` so BENCH_PR6.json can report whether the
    fused beat is memory- or compute-bound on the target part.
    """
    schemas = lowered.plan.catalog.schemas
    bytes_total, iops_total, per_stage = 0.0, 0.0, []
    for st in lowered.scans:
        if not st.cols or not st.covered.any():
            continue
        C, Q, A = len(st.cols), st.q_window, st.delta_words
        T = -(-schemas[st.table].capacity // shards)
        D = min(schemas[st.table].dirty_cap, T)
        b = (T * C * 4 + 2 * C * A * 32 * 4 + 2 * T * A * 4
             + D * C * 4 + 2 * C * Q * 4 + D * (Q // 32) * 8)
        i = 2.0 * T * C * A * 32 + 2.0 * D * C * Q
        per_stage.append({"stage": f"scan:{st.table}", "bytes": b,
                          "int_ops": i})
        bytes_total, iops_total = bytes_total + b, iops_total + i
    for j in lowered.joins:
        if j.kind == "gather":
            continue
        D = min(schemas[j.spine].dirty_cap,
                -(-schemas[j.spine].capacity // shards))
        B = j.bucket_cap if j.kind == "partitioned" \
            else schemas[j.pk_table].capacity
        b = D * 4 + D * B * 8 + D * 8
        i = 2.0 * D * B
        per_stage.append({"stage": f"probe:{j.spine}->{j.pk_table}",
                          "bytes": b, "int_ops": i})
        bytes_total, iops_total = bytes_total + b, iops_total + i
    terms = roofline_terms(iops_total, bytes_total, 0.0, max(shards, 1))
    return {"per_stage": per_stage, "bytes": float(bytes_total),
            "int_ops": float(iops_total),
            "arith_intensity": iops_total / max(bytes_total, 1.0),
            "dominant": terms["dominant"],
            "roofline_fraction": terms["roofline_fraction"]}


def model_flops(cfg, shape) -> float:
    """Analytic MODEL_FLOPS: 6*N*D train, 2*N*D inference (D = tokens).

    N excludes the input-embedding gather (not a matmul); the unembedding
    projection IS a matmul and stays counted (for tied embeddings the single
    table is the unembedding matmul, so nothing is subtracted).
    """
    n_active = cfg.active_param_count()
    if not cfg.tie_embeddings:
        n_active -= cfg.vocab_padded() * cfg.d_model  # gather-only table
    if shape.kind == "train":
        tokens = shape.global_batch * (
            shape.seq_len // cfg.dec_ratio if cfg.enc_dec else shape.seq_len)
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * (
            shape.seq_len // cfg.dec_ratio if cfg.enc_dec else shape.seq_len)
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch  # one token per sequence

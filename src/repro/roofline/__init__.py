from repro.roofline.analysis import (HW, parse_collectives,  # noqa: F401
                                     roofline_terms, model_flops)

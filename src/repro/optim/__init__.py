from repro.optim.adamw import (adamw_init, adamw_update,  # noqa: F401
                               opt_state_specs)
from repro.optim.schedules import cosine_schedule  # noqa: F401

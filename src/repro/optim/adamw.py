"""AdamW with f32 moments over bf16 params, plus distributed-training hooks:

* global-norm clipping,
* optional top-k / sign-based gradient compression (error feedback) for
  bandwidth-constrained inter-pod links (see runtime/ and EXPERIMENTS.md).

Pure-functional: state is a pytree shaped like the params.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    compression: str = "none"   # none | sign (1-bit w/ error feedback)


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)  # noqa: E731
    state = {"m": jax.tree.map(zeros, params),
             "v": jax.tree.map(zeros, params),
             "step": jnp.zeros((), jnp.int32)}
    return state


def opt_state_specs(param_specs):
    """Moments shard exactly like their parameters."""
    return {"m": param_specs, "v": param_specs, "step": P()}


def _global_norm(tree):
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
             for x in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def compress_grads(grads, state, cfg: AdamWConfig):
    """1-bit sign compression with error feedback (arXiv:1802.04434 style).

    Returns (decompressed grads as seen post-all-reduce, new error state).
    The *lowered* collective then moves sign bits + one scale instead of f32
    — modeled here functionally; the wire format is the runtime's concern.
    """
    if cfg.compression == "none":
        return grads, state
    err = state.get("err") or jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads)
    corrected = jax.tree.map(lambda g, e: g.astype(jnp.float32) + e,
                             grads, err)
    scale = jax.tree.map(lambda c: jnp.mean(jnp.abs(c)), corrected)
    quant = jax.tree.map(lambda c, s: jnp.sign(c) * s, corrected, scale)
    new_err = jax.tree.map(lambda c, q: c - q, corrected, quant)
    state = dict(state)
    state["err"] = new_err
    return quant, state


def adamw_update(params, grads, state, cfg: AdamWConfig,
                 lr: Optional[Any] = None):
    lr = cfg.lr if lr is None else lr
    gnorm = _global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * clip, grads)

    step = state["step"] + 1
    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    new_m = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g,
                         state["m"], grads)
    new_v = jax.tree.map(lambda v, g: cfg.b2 * v + (1 - cfg.b2) * g * g,
                         state["v"], grads)

    def upd(p, m, v):
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) \
            + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, new_m, new_v)
    new_state = dict(state)
    new_state.update(m=new_m, v=new_v, step=step)
    return new_params, new_state, gnorm

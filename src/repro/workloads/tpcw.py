"""TPC-W workload for SharedDB (paper §5): nine tables, ~15 query templates
covering the 14 web interactions, three workload mixes.

Column encoding: everything int32 — strings dictionary-encoded (dictionaries
built in sorted order so code order == lexicographic order), money in cents,
dates as integer days.  This matches the engine's columnar storage and is
standard practice for scan-oriented engines (Crescando stores fixed-size
binary rows similarly).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np

from repro.core.plan import GroupAgg, Join, Pred, QueryTemplate, compile_plan
from repro.core.storage import Catalog, TableSchema, UpdateSlots

INT_MAX = 2147483647
N_SUBJECTS = 24
N_TITLE_TOKENS = 1000
N_LNAMES = 500


# ---------------------------------------------------------------------------
# Schema (paper Fig. 6: nine base tables)
# ---------------------------------------------------------------------------


def make_catalog(scale_items: int = 10000,
                 scale_customers: int = 28800,
                 headroom: float = 0.5,
                 dense_pk_index: bool = True) -> Catalog:
    """headroom: growth slack as a fraction of the initial cardinality.
    Table CAPACITY (not live rows) bounds per-cycle work — SharedDB's
    bounded-computation guarantee is a function of these capacities.

    dense_pk_index=False drops every table's dense key->row index
    (key_space=0: unique keys over an unbounded domain), forcing shared
    joins onto the index-less access paths — ``partitioned`` for large PK
    tables, ``block`` for small ones (core/lowering.py).  This is the
    configuration the partitioned-join benchmarks and parity tests run."""
    h = headroom

    def ks(n: int) -> int:
        return n if dense_pk_index else 0

    items_cap = scale_items + 2048
    cust_cap = scale_customers + max(2048, int(scale_customers * h))
    orders0 = int(scale_customers * 0.9)
    orders_cap = orders0 + max(4096, int(orders0 * h))
    ol_cap = orders0 * 3 + max(8192, int(orders0 * 3 * h))
    return Catalog([
        TableSchema("country", ("co_id", "co_name"), 128,
                    pk="co_id", key_space=ks(128)),
        TableSchema("address", ("addr_id", "addr_co_id", "addr_street"),
                    cust_cap + 8192, pk="addr_id",
                    key_space=ks(cust_cap + 8192)),
        TableSchema("customer",
                    ("c_id", "c_uname", "c_passwd", "c_addr_id",
                     "c_discount", "c_since", "c_expiration"),
                    cust_cap, pk="c_id", key_space=ks(cust_cap)),
        TableSchema("author", ("a_id", "a_fname", "a_lname"),
                    max(scale_items // 4 + 1024, 2048), pk="a_id",
                    key_space=ks(max(scale_items // 4 + 1024, 2048))),
        TableSchema("item",
                    ("i_id", "i_a_id", "i_subject", "i_title", "i_pub_date",
                     "i_cost", "i_srp", "i_stock", "i_related1"),
                    items_cap, pk="i_id", key_space=ks(items_cap)),
        TableSchema("orders",
                    ("o_id", "o_c_id", "o_date", "o_total", "o_status"),
                    orders_cap, pk="o_id", key_space=ks(orders_cap)),
        TableSchema("order_line",
                    ("ol_o_id", "ol_i_id", "ol_qty", "ol_discount"),
                    ol_cap),
        TableSchema("cc_xacts", ("cx_o_id", "cx_type", "cx_amount"),
                    orders_cap, pk="cx_o_id", key_space=ks(orders_cap)),
        TableSchema("shopping_cart_line",
                    ("scl_id", "scl_sc_id", "scl_i_id", "scl_qty"),
                    max(8192, cust_cap), pk="scl_id",
                    key_space=ks(max(8192, cust_cap))),
    ])


def generate_data(rng: np.random.Generator, scale_items: int = 10000,
                  scale_customers: int = 28800) -> Dict:
    n_auth = scale_items // 4
    orders0 = int(scale_customers * 0.9)
    data = {}
    data["country"] = {"co_id": np.arange(92),
                       "co_name": np.arange(92)}
    data["address"] = {
        "addr_id": np.arange(scale_customers),
        "addr_co_id": rng.integers(0, 92, scale_customers),
        "addr_street": rng.integers(0, 10000, scale_customers)}
    data["customer"] = {
        "c_id": np.arange(scale_customers),
        "c_uname": np.arange(scale_customers),      # unique -> code == id
        "c_passwd": rng.integers(0, 1 << 30, scale_customers),
        "c_addr_id": np.arange(scale_customers),
        "c_discount": rng.integers(0, 51, scale_customers),
        "c_since": rng.integers(10000, 12000, scale_customers),
        "c_expiration": rng.integers(12000, 14000, scale_customers)}
    data["author"] = {
        "a_id": np.arange(n_auth),
        "a_fname": rng.integers(0, N_LNAMES, n_auth),
        "a_lname": rng.integers(0, N_LNAMES, n_auth)}
    data["item"] = {
        "i_id": np.arange(scale_items),
        "i_a_id": rng.integers(0, n_auth, scale_items),
        "i_subject": rng.integers(0, N_SUBJECTS, scale_items),
        "i_title": rng.integers(0, N_TITLE_TOKENS, scale_items),
        "i_pub_date": rng.integers(8000, 12000, scale_items),
        "i_cost": rng.integers(100, 10000, scale_items),
        "i_srp": rng.integers(100, 12000, scale_items),
        "i_stock": rng.integers(10, 30, scale_items),
        "i_related1": rng.integers(0, scale_items, scale_items)}
    o_date = np.sort(rng.integers(11000, 12000, orders0))
    data["orders"] = {
        "o_id": np.arange(orders0),
        "o_c_id": rng.integers(0, scale_customers, orders0),
        "o_date": o_date,
        "o_total": rng.integers(100, 50000, orders0),
        "o_status": rng.integers(0, 4, orders0)}
    n_ol = orders0 * 3
    data["order_line"] = {
        "ol_o_id": np.repeat(np.arange(orders0), 3),
        "ol_i_id": rng.integers(0, scale_items, n_ol),
        "ol_qty": rng.integers(1, 10, n_ol),
        "ol_discount": rng.integers(0, 30, n_ol)}
    data["cc_xacts"] = {
        "cx_o_id": np.arange(orders0),
        "cx_type": rng.integers(0, 5, orders0),
        "cx_amount": data["orders"]["o_total"]}
    n_carts = 2048
    data["shopping_cart_line"] = {
        "scl_id": np.arange(n_carts * 2),
        "scl_sc_id": np.repeat(np.arange(n_carts), 2),
        "scl_i_id": rng.integers(0, scale_items, n_carts * 2),
        "scl_qty": rng.integers(1, 5, n_carts * 2)}
    return data


# ---------------------------------------------------------------------------
# Query templates (the workload's PreparedStatements)
# ---------------------------------------------------------------------------


def make_templates(items_cap: int) -> Tuple[List[QueryTemplate],
                                            Dict[str, int]]:
    T = [
        QueryTemplate("get_customer", "customer",
                      preds=(Pred("customer", "c_uname"),), limit=1),
        QueryTemplate("get_password", "customer",
                      preds=(Pred("customer", "c_id"),), limit=1),
        QueryTemplate("get_book", "item",
                      preds=(Pred("item", "i_id"),),
                      joins=(Join("i_a_id", "author"),), limit=1),
        QueryTemplate("get_related", "item",
                      preds=(Pred("item", "i_id"),), limit=1),
        QueryTemplate("admin_item", "item",
                      preds=(Pred("item", "i_id"),), limit=1),
        QueryTemplate("search_subject", "item",
                      preds=(Pred("item", "i_subject"),),
                      sort_col="i_title", limit=50),
        QueryTemplate("search_title", "item",
                      preds=(Pred("item", "i_title"),),
                      sort_col="i_title", limit=50),
        QueryTemplate("search_author", "item",
                      preds=(Pred("author", "a_lname"),),
                      joins=(Join("i_a_id", "author"),),
                      sort_col="i_title", limit=50),
        QueryTemplate("new_products", "item",
                      preds=(Pred("item", "i_subject"),),
                      sort_col="i_pub_date", sort_desc=True, limit=50),
        QueryTemplate("best_sellers", "order_line",
                      preds=(Pred("orders", "o_id"),
                             Pred("item", "i_subject")),
                      joins=(Join("ol_o_id", "orders"),
                             Join("ol_i_id", "item")),
                      group=GroupAgg("ol_i_id", items_cap, "ol_qty",
                                     top_k=50, order_by="sum")),
        QueryTemplate("order_lines", "order_line",
                      preds=(Pred("order_line", "ol_o_id"),),
                      joins=(Join("ol_i_id", "item"),), limit=32),
        QueryTemplate("order_display", "orders",
                      preds=(Pred("orders", "o_c_id"),),
                      sort_col="o_date", sort_desc=True, limit=1),
        QueryTemplate("get_cart", "shopping_cart_line",
                      preds=(Pred("shopping_cart_line", "scl_sc_id"),),
                      joins=(Join("scl_i_id", "item"),), limit=32),
    ]
    caps = {"get_customer": 64, "get_password": 16, "get_book": 64,
            "get_related": 32, "admin_item": 8, "search_subject": 32,
            "search_title": 32, "search_author": 32, "new_products": 32,
            "best_sellers": 64, "order_display": 8, "order_lines": 8,
            "get_cart": 16}
    return T, caps


def build_tpcw_plan(scale_items: int = 10000, scale_customers: int = 28800,
                    max_results: int = 64, headroom: float = 0.5,
                    dense_pk_index: bool = True):
    catalog = make_catalog(scale_items, scale_customers, headroom,
                           dense_pk_index=dense_pk_index)
    items_cap = catalog.schemas["item"].capacity
    templates, caps = make_templates(items_cap)
    return compile_plan(catalog, templates, caps, max_results=max_results)


DEFAULT_UPDATE_SLOTS = UpdateSlots(n_insert=192, n_update=96, n_delete=96)


# ---------------------------------------------------------------------------
# Web interactions + mixes (TPC-W spec probabilities)
# ---------------------------------------------------------------------------

MIXES = {
    "browsing": {
        "home": 29.00, "new_products": 11.00, "best_sellers": 11.00,
        "product_detail": 21.00, "search_request": 12.00,
        "search_results": 11.00, "shopping_cart": 2.00,
        "customer_registration": 0.82, "buy_request": 0.75,
        "buy_confirm": 0.69, "order_inquiry": 0.30, "order_display": 0.25,
        "admin_request": 0.10, "admin_confirm": 0.09},
    "shopping": {
        "home": 16.00, "new_products": 5.00, "best_sellers": 5.00,
        "product_detail": 17.00, "search_request": 20.00,
        "search_results": 17.00, "shopping_cart": 11.60,
        "customer_registration": 3.00, "buy_request": 2.60,
        "buy_confirm": 1.20, "order_inquiry": 0.75, "order_display": 0.66,
        "admin_request": 0.10, "admin_confirm": 0.09},
    "ordering": {
        "home": 9.12, "new_products": 0.46, "best_sellers": 0.46,
        "product_detail": 12.35, "search_request": 14.53,
        "search_results": 13.08, "shopping_cart": 13.53,
        "customer_registration": 12.86, "buy_request": 12.73,
        "buy_confirm": 10.18, "order_inquiry": 0.25, "order_display": 0.22,
        "admin_request": 0.12, "admin_confirm": 0.11},
}

# web-interaction SLA timeouts (seconds) from the TPC-W spec
WI_TIMEOUT = {
    "home": 3, "new_products": 5, "best_sellers": 5, "product_detail": 3,
    "search_request": 3, "search_results": 10, "shopping_cart": 3,
    "customer_registration": 3, "buy_request": 3, "buy_confirm": 5,
    "order_inquiry": 3, "order_display": 3, "admin_request": 3,
    "admin_confirm": 5,
}


@dataclasses.dataclass
class Interaction:
    kind: str
    queries: List[Tuple[str, Dict[int, Tuple[int, int]]]]
    updates: List[Tuple[str, str, Dict]]


class WorkloadGenerator:
    """Generates web interactions -> template invocations + updates."""

    def __init__(self, rng: np.random.Generator, scale_items: int = 10000,
                 scale_customers: int = 28800):
        self.rng = rng
        self.n_items = scale_items
        self.n_cust = scale_customers
        self._next_order = int(scale_customers * 0.9)
        self._next_cart_line = 4096
        self._next_cust = scale_customers
        self._next_cart = 2048

    def _eq(self, v: int):
        return (int(v), int(v))

    def interaction(self, kind: str) -> Interaction:
        rng = self.rng
        c = int(rng.integers(0, self.n_cust))
        i = int(rng.integers(0, self.n_items))
        subj = int(rng.integers(0, N_SUBJECTS))
        q, u = [], []
        if kind == "home":
            q = [("get_customer", {0: self._eq(c)}),
                 ("get_related", {0: self._eq(i)})]
        elif kind == "new_products":
            q = [("new_products", {0: self._eq(subj)})]
        elif kind == "best_sellers":
            lo = max(0, self._next_order - 3333)
            q = [("best_sellers", {0: (lo, INT_MAX), 1: self._eq(subj)})]
        elif kind == "product_detail":
            q = [("get_book", {0: self._eq(i)})]
        elif kind == "search_request":
            q = [("get_related", {0: self._eq(i)})]
        elif kind == "search_results":
            mode = rng.integers(0, 3)
            if mode == 0:
                q = [("search_subject", {0: self._eq(subj)})]
            elif mode == 1:
                q = [("search_title",
                      {0: self._eq(int(rng.integers(0, N_TITLE_TOKENS)))})]
            else:
                q = [("search_author",
                      {0: self._eq(int(rng.integers(0, N_LNAMES)))})]
        elif kind == "shopping_cart":
            cart = int(rng.integers(0, self._next_cart))
            q = [("get_cart", {0: self._eq(cart)})]
            sid = self._next_cart_line
            self._next_cart_line += 1
            u = [("shopping_cart_line", "insert",
                  {"scl_id": sid, "scl_sc_id": cart, "scl_i_id": i,
                   "scl_qty": int(rng.integers(1, 4))})]
        elif kind == "customer_registration":
            new_c = self._next_cust
            self._next_cust += 1
            self._next_cart += 1
            q = [("get_customer", {0: self._eq(c)})]
            u = [("address", "insert",
                  {"addr_id": new_c, "addr_co_id": int(rng.integers(0, 92)),
                   "addr_street": int(rng.integers(0, 10000))}),
                 ("customer", "insert",
                  {"c_id": new_c, "c_uname": new_c,
                   "c_passwd": int(rng.integers(0, 1 << 30)),
                   "c_addr_id": new_c,
                   "c_discount": int(rng.integers(0, 51)),
                   "c_since": 12000, "c_expiration": 14000})]
        elif kind == "buy_request":
            cart = int(rng.integers(0, self._next_cart))
            q = [("get_customer", {0: self._eq(c)}),
                 ("get_cart", {0: self._eq(cart)})]
            u = [("customer", "update",
                  {"key": c, "col": "c_expiration", "val": 14600})]
        elif kind == "buy_confirm":
            o = self._next_order
            self._next_order += 1
            total = int(rng.integers(100, 50000))
            u = [("orders", "insert",
                  {"o_id": o, "o_c_id": c, "o_date": 12000,
                   "o_total": total, "o_status": 0}),
                 ("cc_xacts", "insert",
                  {"cx_o_id": o, "cx_type": int(rng.integers(0, 5)),
                   "cx_amount": total})]
            for _ in range(int(rng.integers(1, 4))):
                u.append(("order_line", "insert",
                          {"ol_o_id": o,
                           "ol_i_id": int(rng.integers(0, self.n_items)),
                           "ol_qty": int(rng.integers(1, 10)),
                           "ol_discount": int(rng.integers(0, 30))}))
            q = [("get_customer", {0: self._eq(c)})]
        elif kind == "order_inquiry":
            q = [("get_password", {0: self._eq(c)})]
        elif kind == "order_display":
            q = [("order_display", {0: self._eq(c)}),
                 ("order_lines",
                  {0: self._eq(int(rng.integers(0, self._next_order)))}),
                 ("get_customer", {0: self._eq(c)})]
        elif kind == "admin_request":
            q = [("admin_item", {0: self._eq(i)})]
        elif kind == "admin_confirm":
            q = [("admin_item", {0: self._eq(i)})]
            u = [("item", "update",
                  {"key": i, "col": "i_cost",
                   "val": int(rng.integers(100, 10000))})]
        else:
            raise ValueError(kind)
        return Interaction(kind, q, u)

    def sample_mix(self, mix: str, n: int) -> List[Interaction]:
        kinds = list(MIXES[mix])
        probs = np.array([MIXES[mix][k] for k in kinds])
        probs = probs / probs.sum()
        picks = self.rng.choice(len(kinds), size=n, p=probs)
        return [self.interaction(kinds[p]) for p in picks]

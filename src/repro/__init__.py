"""repro — SharedDB (VLDB'12) as a production-grade JAX/TPU framework.

Two pillars:
  * ``repro.core``     — the paper's batched shared-computation query engine.
  * ``repro.models``   — the assigned LM architectures served/trained under the
                         SharedDB cycle discipline (``repro.serving``).

See DESIGN.md for the full system inventory and hardware-adaptation notes.
"""

__version__ = "1.0.0"

from repro.checkpoint.checkpoint import (CheckpointManager,  # noqa: F401
                                         save_pytree, load_pytree)

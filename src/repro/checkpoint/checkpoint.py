"""Sharded, atomic checkpointing (no orbax dependency).

Layout per step:
    <dir>/step_000100.tmp/          written first
        shard_<host>.npz            this host's param/opt/data-state leaves
        manifest.json               tree structure + shapes + dtypes +
                                    sharding specs + step + integrity sums
    <dir>/step_000100/              atomic rename on completion (commit)

Fault-tolerance contract (runtime/):
  * a crash mid-write leaves only a .tmp dir -> ignored on restore;
  * restore picks the newest COMMITTED step;
  * every leaf carries a crc so silent corruption fails loudly;
  * per-host shards mean a 1000-host job writes 1000 small files, not one
    giant blob (and restores only what it owns after elastic re-sharding).
"""
from __future__ import annotations

import json
import os
import shutil
import zlib
from typing import Any, Dict, Optional

import jax
import ml_dtypes
import numpy as np

# numpy's savez cannot represent bfloat16: persist as a uint16 view and
# reconstruct from the manifest dtype on restore
_VIEW_DTYPES = {"bfloat16": (ml_dtypes.bfloat16, np.uint16)}


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_pytree(tree, directory: str, step: int, host_id: int = 0,
                extra: Optional[Dict[str, Any]] = None) -> str:
    tmp = os.path.join(directory, f"step_{step:08d}.tmp")
    final = os.path.join(directory, f"step_{step:08d}")
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(tree)
    stored = {k: (v.view(np.uint16) if str(v.dtype) in _VIEW_DTYPES else v)
              for k, v in flat.items()}
    np.savez(os.path.join(tmp, f"shard_{host_id}.npz"), **stored)
    manifest = {
        "step": step,
        "host": host_id,
        "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype),
                       "crc": zlib.crc32(np.ascontiguousarray(v).tobytes())}
                   for k, v in flat.items()},
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    os.replace(tmp, final)  # atomic commit
    return final


def load_pytree(template, directory: str, step: Optional[int] = None,
                host_id: int = 0):
    """Restore into the structure of `template` (a pytree of arrays or
    ShapeDtypeStructs).  Returns (tree, manifest)."""
    step_dir = _resolve_step(directory, step)
    with open(os.path.join(step_dir, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(step_dir, f"shard_{host_id}.npz"))
    flat_t = jax.tree_util.tree_flatten_with_path(template)
    out_leaves = []
    for path, leaf in flat_t[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = data[key]
        meta = manifest["leaves"][key]
        crc = zlib.crc32(np.ascontiguousarray(arr).tobytes())
        if crc != meta["crc"]:
            raise IOError(f"checkpoint corruption in leaf {key}")
        if meta["dtype"] in _VIEW_DTYPES:
            arr = arr.view(_VIEW_DTYPES[meta["dtype"]][0])
        out_leaves.append(arr)
    tree = jax.tree_util.tree_unflatten(flat_t[1], out_leaves)
    return tree, manifest


def _resolve_step(directory: str, step: Optional[int]) -> str:
    if step is not None:
        p = os.path.join(directory, f"step_{step:08d}")
        if not os.path.isdir(p):
            raise FileNotFoundError(p)
        return p
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(directory)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    if not steps:
        raise FileNotFoundError(f"no committed checkpoints in {directory}")
    return os.path.join(directory, f"step_{steps[-1]:08d}")


class CheckpointManager:
    """Keep-last-k manager with garbage collection of stale .tmp dirs."""

    def __init__(self, directory: str, keep: int = 3, host_id: int = 0):
        self.dir = directory
        self.keep = keep
        self.host_id = host_id
        os.makedirs(directory, exist_ok=True)
        # crash recovery: drop half-written checkpoints
        for d in os.listdir(directory):
            if d.endswith(".tmp"):
                shutil.rmtree(os.path.join(directory, d),
                              ignore_errors=True)

    def save(self, tree, step: int, extra: Optional[Dict] = None) -> str:
        path = save_pytree(tree, self.dir, step, self.host_id, extra)
        self._gc()
        return path

    def restore(self, template, step: Optional[int] = None):
        return load_pytree(template, self.dir, step, self.host_id)

    def latest_step(self) -> Optional[int]:
        steps = [int(d.split("_")[1]) for d in os.listdir(self.dir)
                 if d.startswith("step_") and not d.endswith(".tmp")]
        return max(steps) if steps else None

    def _gc(self):
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(self.dir)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

"""Sharded training-data pipeline.

Deterministic, checkpointable, host-sharded: every host generates exactly
its slice of the global batch from a (seed, step) pair, so restart-replay
and elastic re-sharding need no data movement — the stream is a pure
function of the step counter (the same discipline the SharedDB engine uses
for its cycles).

Sources: synthetic LM tokens (default; zipf-ish unigram mix so losses move)
or a memory-mapped token file.  Prefetch runs one step ahead on a
background thread.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    kind: str = "synthetic"         # synthetic | file
    path: Optional[str] = None
    # aux modality stubs
    frames_dim: int = 0             # enc-dec: frame-embedding dim
    frames_len: int = 0
    vision_tokens: int = 0
    vision_dim: int = 0


class TokenPipeline:
    def __init__(self, cfg: DataConfig, host_id: int = 0,
                 n_hosts: int = 1, prefetch: int = 2):
        assert cfg.global_batch % n_hosts == 0
        self.cfg = cfg
        self.host_id = host_id
        self.n_hosts = n_hosts
        self.local_batch = cfg.global_batch // n_hosts
        self._tokens = None
        if cfg.kind == "file" and cfg.path:
            self._tokens = np.memmap(cfg.path, dtype=np.int32, mode="r")
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self._step = 0

    # ------------------------------------------------------------- state
    def state(self) -> Dict:
        return {"step": self._step, "seed": self.cfg.seed}

    def restore(self, state: Dict) -> None:
        self._step = int(state["step"])

    # ------------------------------------------------------------- batch
    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.cfg.seed, step, self.host_id]))

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Pure function of (seed, step, host) — replayable."""
        cfg, B, S = self.cfg, self.local_batch, self.cfg.seq_len
        rng = self._rng(step)
        if self._tokens is not None:
            n = len(self._tokens) - (S + 1)
            starts = rng.integers(0, n, B)
            tok = np.stack([self._tokens[s:s + S + 1] for s in starts])
        else:
            # synthetic: mixture of zipf unigrams + local repetition so the
            # model has learnable structure
            base = rng.zipf(1.3, (B, S + 1)).astype(np.int64)
            tok = (base % (cfg.vocab - 2)) + 1
            rep = rng.random((B, S + 1)) < 0.3
            tok[:, 1:] = np.where(rep[:, 1:], tok[:, :-1], tok[:, 1:])
        batch = {"tokens": tok[:, :-1].astype(np.int32),
                 "labels": tok[:, 1:].astype(np.int32)}
        if cfg.frames_dim:
            batch["frames"] = rng.standard_normal(
                (B, cfg.frames_len, cfg.frames_dim)).astype(np.float32)
        if cfg.vision_tokens:
            batch["vision"] = rng.standard_normal(
                (B, cfg.vision_tokens, cfg.vision_dim)).astype(np.float32)
        return batch

    # ---------------------------------------------------------- iterator
    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            b = self.batch_at(step)
            self._q.put((step, b))
            step += 1

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        if self._thread is None:
            self._thread = threading.Thread(target=self._worker,
                                            daemon=True)
            self._thread.start()
        while True:
            step, b = self._q.get()
            self._step = step + 1
            yield b

    def stop(self):
        self._stop.set()

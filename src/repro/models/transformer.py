"""Unified grouped-scan language model.

Every assigned architecture compiles to a *layer program*: a repeated group
of sublayers scanned ``n_groups`` times (jax.lax.scan over stacked params,
O(1) HLO size in depth) plus optional leftover sublayers.  This uniformly
expresses:

  dense GQA           group = [attn]                          x L
  mixtral (SWA MoE)   group = [attn(window, moe)]             x L
  gemma3 (5:1)        group = [attn(w)]*5 + [attn(0)]         x 10  + 2 local
  llama-vision        group = [attn]*4 + [cross]              x 20
  recurrentgemma      group = [rec, rec, attn(w)]             x 8   + 2 rec
  mamba2              group = [ssm]                           x 48
  whisper             encoder program + decoder program (self+cross)

Each sublayer owns its pre-norm and (except bare ssm/rec) a gated MLP.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import ArchConfig
from repro.models import moe as moe_lib
from repro.models import rglru, ssm
from repro.models.common import (MeshAxes, ParamStore, apply_norm,
                                 apply_rope, block_attention,
                                 decode_attention, rope_tables)


# ---------------------------------------------------------------------------
# Layer programs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    kind: str               # attn | cross | rec | ssm
    window: int = 0         # 0 = full attention
    causal: bool = True
    moe: bool = False
    has_mlp: bool = True


@dataclasses.dataclass(frozen=True)
class Program:
    n_groups: int
    group: Tuple[LayerSpec, ...]
    leftover: Tuple[LayerSpec, ...] = ()

    @property
    def n_layers(self) -> int:
        return self.n_groups * len(self.group) + len(self.leftover)


def build_program(cfg: ArchConfig) -> Program:
    if cfg.enc_dec:
        return build_decoder_program(cfg)
    if cfg.family == "ssm":
        return Program(cfg.n_layers, (LayerSpec("ssm", has_mlp=False),))
    if cfg.rglru_pattern:
        kinds = {"rec": LayerSpec("rec", window=0),
                 "attn": LayerSpec("attn", window=cfg.window)}
        group = tuple(kinds[k] for k in cfg.rglru_pattern)
        n = cfg.n_layers // len(group)
        rest = cfg.n_layers - n * len(group)
        leftover = tuple(kinds[k] for k in cfg.rglru_pattern[:rest])
        return Program(n, group, leftover)
    if cfg.cross_every:
        per = cfg.cross_every
        group = tuple([LayerSpec("attn", moe=cfg.moe is not None)] * (per - 1)
                      + [LayerSpec("cross")])
        assert cfg.n_layers % per == 0
        return Program(cfg.n_layers // per, group)
    loc, glob = cfg.local_global
    is_moe = cfg.moe is not None
    if loc > 0 and glob > 0:
        group = tuple([LayerSpec("attn", window=cfg.window, moe=is_moe)] * loc
                      + [LayerSpec("attn", window=0, moe=is_moe)] * glob)
        per = loc + glob
        n = cfg.n_layers // per
        rest = cfg.n_layers - n * per
        leftover = tuple([LayerSpec("attn", window=cfg.window,
                                    moe=is_moe)] * rest)
        return Program(n, group, leftover)
    return Program(cfg.n_layers,
                   (LayerSpec("attn", window=cfg.window, moe=is_moe),))


def build_encoder_program(cfg: ArchConfig) -> Program:
    return Program(cfg.n_enc_layers, (LayerSpec("attn", causal=False),))


def build_decoder_program(cfg: ArchConfig) -> Program:
    # enc-dec decoder layer: self-attn sublayer (no MLP) + cross-attn + MLP
    return Program(cfg.n_layers,
                   (LayerSpec("attn", has_mlp=False), LayerSpec("cross")))


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _head_specs(cfg: ArchConfig, axes: MeshAxes):
    tp = axes.tp_size
    h_spec = axes.tp if cfg.n_heads % max(tp, 1) == 0 else None
    kv_spec = axes.tp if cfg.n_kv % max(tp, 1) == 0 else None
    return h_spec, kv_spec


def _init_norm(store: ParamStore, name: str, d: int, kind: str,
               axes: MeshAxes):
    sub = store.subtree(name)
    sub.add("scale", (d,), (None,), zeros=(kind == "rmsnorm"))
    if kind != "rmsnorm":
        sub.add("bias", (d,), (None,), zeros=True)


def _init_attn(store: ParamStore, cfg: ArchConfig, axes: MeshAxes):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h_spec, kv_spec = _head_specs(cfg, axes)
    store.add("wq", (d, cfg.n_heads, hd), (axes.fsdp, h_spec, None))
    store.add("wk", (d, cfg.n_kv, hd), (axes.fsdp, kv_spec, None))
    store.add("wv", (d, cfg.n_kv, hd), (axes.fsdp, kv_spec, None))
    store.add("wo", (cfg.n_heads, hd, d), (h_spec, None, axes.fsdp))
    if cfg.qkv_bias:
        store.add("bq", (cfg.n_heads, hd), (h_spec, None), zeros=True)
        store.add("bk", (cfg.n_kv, hd), (kv_spec, None), zeros=True)
        store.add("bv", (cfg.n_kv, hd), (kv_spec, None), zeros=True)


def _init_sublayer(store: ParamStore, spec: LayerSpec, cfg: ArchConfig,
                   axes: MeshAxes):
    _init_norm(store, "norm", cfg.d_model, cfg.norm, axes)
    if spec.kind in ("attn", "cross"):
        _init_attn(store.subtree("attn"), cfg, axes)
    elif spec.kind == "rec":
        rglru.init_rglru(store.subtree("rec"), cfg, axes)
    elif spec.kind == "ssm":
        ssm.init_ssm(store.subtree("ssm"), cfg, axes)
    if spec.has_mlp:
        _init_norm(store, "mlp_norm", cfg.d_model, cfg.norm, axes)
        mstore = store.subtree("mlp")
        if spec.moe:
            moe_lib.init_moe(mstore, cfg.d_model, cfg.moe, axes)
        elif cfg.act in ("swiglu", "gelu_glu"):
            moe_lib.init_mlp(mstore, cfg.d_model, cfg.d_ff, axes)
        else:
            moe_lib.init_mlp_nonglu(mstore, cfg.d_model, cfg.d_ff, axes)


def _init_program(store: ParamStore, prog: Program, cfg: ArchConfig,
                  axes: MeshAxes, prefix: str):
    from repro.models.common import stack_trees, stack_specs
    for idx, spec in enumerate(prog.group):
        if prog.n_groups == 0:
            break
        copies, copy_specs = [], None
        for g in range(prog.n_groups):
            sub = ParamStore(jax.random.fold_in(store._next_key(), g),
                             store.dtype)
            _init_sublayer(sub, spec, cfg, axes)
            copies.append(sub.params)
            copy_specs = sub.specs
        store.params[f"{prefix}g{idx}"] = stack_trees(copies)
        store.specs[f"{prefix}g{idx}"] = stack_specs(copy_specs)
    for idx, spec in enumerate(prog.leftover):
        sub = store.subtree(f"{prefix}x{idx}")
        _init_sublayer(sub, spec, cfg, axes)


def init_lm(key, cfg: ArchConfig, axes: MeshAxes = MeshAxes(),
            dtype=jnp.bfloat16):
    """Returns (params, pspecs) — parallel pytrees."""
    store = ParamStore(key, dtype)
    Vp = cfg.vocab_padded()
    store.add("embed", (Vp, cfg.d_model), (axes.tp, axes.fsdp), scale=0.02)
    if not cfg.tie_embeddings:
        store.add("unembed", (cfg.d_model, Vp), (axes.fsdp, axes.tp),
                  scale=0.02)
    _init_norm(store, "final_norm", cfg.d_model, cfg.norm, axes)
    prog = build_program(cfg)
    _init_program(store, prog, cfg, axes, "")
    if cfg.enc_dec:
        store.add("w_frontend", (cfg.d_model, cfg.d_model),
                  (axes.fsdp, None))
        _init_norm(store, "enc_final_norm", cfg.d_model, cfg.norm, axes)
        _init_program(store, build_encoder_program(cfg), cfg, axes, "enc_")
    if cfg.cross_every:
        store.add("w_vision_proj", (cfg.d_model, cfg.d_model),
                  (axes.fsdp, None))
    return store.params, store.specs


# ---------------------------------------------------------------------------
# Sublayer application
# ---------------------------------------------------------------------------


def _qkv(p, x, cfg, ctx=None):
    """Returns q [B,S,H,hd], k,v [B,Sk,KV,hd]."""
    src = x if ctx is None else ctx
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", src, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", src, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    return q, k, v


def _attn_full(p, x, spec: LayerSpec, cfg, axes, positions, ctx=None):
    """Train/prefill attention.  Returns (out, (k, v)) — k/v for caching."""
    q, k, v = _qkv(p, x, cfg, ctx)
    if ctx is None:  # self-attention: rope
        sin, cos = rope_tables(positions, cfg.resolved_head_dim,
                               cfg.rope_theta)
        q = apply_rope(q, sin, cos)
        k = apply_rope(k, sin, cos)
    h_spec, kv_spec = _head_specs(cfg, axes)
    out = block_attention(q, k, v, causal=spec.causal and ctx is None,
                          window=spec.window if ctx is None else 0,
                          axes=axes, head_sharded=h_spec is not None,
                          kv_sharded=kv_spec is not None)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), (k, v)


def _attn_decode(p, x, spec: LayerSpec, cfg, axes, cache, positions):
    """Single-token attention with ring-buffer cache update."""
    q, k_new, v_new = _qkv(p, x, cfg, None)
    sin, cos = rope_tables(positions[:, None], cfg.resolved_head_dim,
                           cfg.rope_theta)
    q = apply_rope(q, sin, cos)
    k_new = apply_rope(k_new, sin, cos)

    W = cache["k"].shape[1]
    slot = positions % W                                    # [B]
    bidx = jnp.arange(x.shape[0])
    k_c = cache["k"].at[bidx, slot].set(k_new[:, 0])
    v_c = cache["v"].at[bidx, slot].set(v_new[:, 0])
    pos_c = cache["pos"].at[bidx, slot].set(positions)

    seq_spec = None
    if axes.mesh is not None and x.shape[0] % axes.dp_size != 0:
        seq_spec = axes.dp[-1]  # batch unshardable -> KV seq rides data axis
    _, kv_spec = _head_specs(cfg, axes)
    if cfg.decode_cache_seq_shard == "tp" and kv_spec is None:
        seq_spec = axes.tp      # split-KV across the model axis
    out = decode_attention(q, k_c, v_c, pos_c, positions,
                           window=spec.window, axes=axes,
                           seq_axis_spec=seq_spec)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, {"k": k_c, "v": v_c, "pos": pos_c}


def _cross_decode(p, x, cfg, axes, cache):
    """Decode-time cross-attention against precomputed (k, v)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if cfg.qkv_bias:
        q = q + p["bq"]
    k_c, v_c = cache["k"], cache["v"]
    pos = jnp.zeros((x.shape[0],), jnp.int32)
    kv_pos = jnp.zeros(k_c.shape[:2], jnp.int32)  # all valid, no causality
    out = decode_attention(q, k_c, v_c, kv_pos, pos, axes=axes)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def _apply_mlp_part(p, spec: LayerSpec, x, cfg, axes):
    if not spec.has_mlp:
        return x, 0.0
    h = apply_norm(x, p["mlp_norm"], cfg.norm)
    if spec.moe:
        y, aux = moe_lib.apply_moe(p["mlp"], h, cfg.moe, cfg.act, axes,
                                   dispatch=cfg.moe_dispatch)
    elif cfg.act in ("swiglu", "gelu_glu"):
        y, aux = moe_lib.apply_mlp(p["mlp"], h, cfg.act, axes), 0.0
    else:
        y, aux = moe_lib.apply_mlp_nonglu(p["mlp"], h, cfg.act, axes), 0.0
    if cfg.sp_outputs and y.ndim == 3:
        y = axes.constrain(y, axes.dp, axes.tp, None)
    return x + y, aux


def _sublayer_train(p, spec: LayerSpec, x, cfg, axes, positions, ctx,
                    emit_cache: bool, cache_capacity: int = 0):
    """Returns (x, aux, cache_entry_or_None)."""
    h = apply_norm(x, p["norm"], cfg.norm)
    entry = None
    if spec.kind == "attn":
        y, (k, v) = _attn_full(p["attn"], h, spec, cfg, axes, positions)
        if emit_cache:
            entry = _pack_kv_cache(k, v, positions, spec, cache_capacity)
    elif spec.kind == "cross":
        y, (k, v) = _attn_full(p["attn"], h, spec, cfg, axes, positions,
                               ctx=ctx)
        if emit_cache:
            entry = {"k": k, "v": v}
    elif spec.kind == "rec":
        y, (conv, hstate) = rglru.apply_rglru(p["rec"], h, cfg, axes)
        if emit_cache:
            entry = {"conv": conv, "h": hstate}
    elif spec.kind == "ssm":
        y, (conv, st) = ssm.apply_ssm(p["ssm"], h, cfg, axes)
        if emit_cache:
            entry = {"conv": conv, "state": st}
    if cfg.sp_outputs:
        # constrain the sublayer OUTPUT to the seq-sharded layout so the
        # TP partial-sum reduction lowers as reduce-scatter (Megatron-SP)
        y = axes.constrain(y, axes.dp, axes.tp, None)
    x = x + y
    x = axes.constrain(x, axes.dp, axes.tp, None)  # sequence-sharded residual
    x, aux = _apply_mlp_part(p, spec, x, cfg, axes)
    x = axes.constrain(x, axes.dp, axes.tp, None)
    return x, aux, entry


def _pack_kv_cache(k, v, positions, spec: LayerSpec, capacity: int):
    """Arrange prefill K/V into the ring-buffer layout (slot = pos % W)."""
    B, S = k.shape[:2]
    W = min(capacity, spec.window) if spec.window else capacity
    if S >= W:
        k_keep = k[:, S - W:]
        v_keep = v[:, S - W:]
        pos_keep = jnp.broadcast_to(jnp.arange(S - W, S), (B, W))
        slots = jnp.arange(S - W, S) % W
        k_c = jnp.zeros((B, W) + k.shape[2:], k.dtype).at[:, slots].set(k_keep)
        v_c = jnp.zeros((B, W) + v.shape[2:], v.dtype).at[:, slots].set(v_keep)
        pos_c = jnp.full((B, W), -1, jnp.int32).at[:, slots].set(
            pos_keep.astype(jnp.int32))
    else:
        pad = W - S
        k_c = jnp.pad(k, ((0, 0), (0, pad)) + ((0, 0),) * (k.ndim - 2))
        v_c = jnp.pad(v, ((0, 0), (0, pad)) + ((0, 0),) * (v.ndim - 2))
        pos_c = jnp.concatenate(
            [jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S)),
             jnp.full((B, pad), -1, jnp.int32)], axis=1)
    return {"k": k_c, "v": v_c, "pos": pos_c}


def _sublayer_decode(p, spec: LayerSpec, x, cfg, axes, positions, cache):
    h = apply_norm(x, p["norm"], cfg.norm)
    if spec.kind == "attn":
        y, new_cache = _attn_decode(p["attn"], h, spec, cfg, axes, cache,
                                    positions)
    elif spec.kind == "cross":
        y = _cross_decode(p["attn"], h, cfg, axes, cache)
        new_cache = cache
    elif spec.kind == "rec":
        y, (conv, hstate) = rglru.apply_rglru(
            p["rec"], h, cfg, axes, conv_state=cache["conv"],
            h_state=cache["h"], decode=True)
        new_cache = {"conv": conv, "h": hstate}
    elif spec.kind == "ssm":
        y, (conv, st) = ssm.apply_ssm(
            p["ssm"], h, cfg, axes, conv_state=cache["conv"],
            ssd_state=cache["state"], decode=True)
        new_cache = {"conv": conv, "state": st}
    x = x + y
    x, _ = _apply_mlp_part(p, spec, x, cfg, axes)
    return x, new_cache


# ---------------------------------------------------------------------------
# Whole-model passes
# ---------------------------------------------------------------------------


def _run_program(params, prog: Program, x, cfg, axes, positions, ctx=None,
                 *, emit_cache=False, cache_capacity=0, remat=True,
                 prefix=""):
    """Scan the grouped program.  Returns (x, aux, caches dict or None)."""
    aux_total = 0.0
    caches = {} if emit_cache else None

    def group_body(carry, gparams):
        x, aux = carry
        entries = {}
        for idx, spec in enumerate(prog.group):
            x, a, entry = _sublayer_train(
                gparams[f"{prefix}g{idx}"], spec, x, cfg, axes, positions,
                ctx, emit_cache, cache_capacity)
            aux = aux + a
            if emit_cache:
                entries[f"{prefix}g{idx}"] = entry
        return (x, aux), entries

    body = jax.checkpoint(group_body) if (remat and cfg.remat == "full") \
        else group_body
    xs = {k: params[k] for k in params
          if k.startswith(f"{prefix}g") and k[len(prefix) + 1:].isdigit()}
    if xs:  # n_groups may be 0 (depth-probe configs)
        (x, aux_total), stacked_entries = jax.lax.scan(
            lambda c, gp: body(c, gp), (x, jnp.float32(0.0)), xs)
        if emit_cache:
            caches.update(stacked_entries)
    for idx, spec in enumerate(prog.leftover):
        x, a, entry = _sublayer_train(
            params[f"{prefix}x{idx}"], spec, x, cfg, axes, positions, ctx,
            emit_cache, cache_capacity)
        aux_total = aux_total + a
        if emit_cache:
            caches[f"{prefix}x{idx}"] = entry
    return x, aux_total, caches


def _embed(params, cfg, tokens, axes):
    x = params["embed"].take(tokens, axis=0)
    return axes.constrain(x, axes.dp, axes.tp, None)


def _unembed(params, cfg, x, axes):
    x = apply_norm(x, params["final_norm"], cfg.norm)
    w = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    logits = jnp.einsum("bsd,dv->bsv", x, w)
    return axes.constrain(logits, axes.dp, None, axes.tp)


def _encode(params, cfg, frames, axes):
    x = frames @ params["w_frontend"]
    pos = jnp.arange(frames.shape[1])[None]
    x, _, _ = _run_program(params, build_encoder_program(cfg), x, cfg, axes,
                           pos, prefix="enc_")
    return apply_norm(x, params["enc_final_norm"], cfg.norm)


def _get_ctx(params, cfg, batch, axes):
    if cfg.enc_dec:
        return _encode(params, cfg, batch["frames"], axes)
    if cfg.cross_every:
        return batch["vision"] @ params["w_vision_proj"]
    return None


def loss_fn(params, batch, cfg: ArchConfig, axes: MeshAxes = MeshAxes()):
    """Causal LM loss (+0.01 * MoE aux).  batch: tokens/labels [B,S] (+aux)."""
    tokens, labels = batch["tokens"], batch["labels"]
    prog = build_program(cfg)
    ctx = _get_ctx(params, cfg, batch, axes)
    x = _embed(params, cfg, tokens, axes)
    positions = jnp.arange(tokens.shape[1])[None]
    x, aux, _ = _run_program(params, prog, x, cfg, axes, positions, ctx)
    logits = _unembed(params, cfg, x, axes).astype(jnp.float32)
    Vp, V = cfg.vocab_padded(), cfg.vocab
    if Vp != V:  # mask padded vocab
        logits = logits + jnp.where(jnp.arange(Vp) < V, 0.0, -1e9)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    ce = jnp.mean(logz - gold)
    return ce + 0.01 * aux


def prefill(params, batch, cfg: ArchConfig, axes: MeshAxes = MeshAxes(),
            cache_capacity: Optional[int] = None, last_pos=None):
    """Run the prompt; returns (last-token logits [B, V], cache).

    ``last_pos`` (scalar, may be traced) selects WHICH position's logits
    to return; default is S - 1.  Fixed-shape servers right-pad short
    prompts to the compiled prefill length, and under causal attention
    the hidden state at the true last PROMPT position is identical to an
    unpadded prefill's — while position S - 1 would be a pad token's —
    so they pass the real last index here and keep one compiled shape.
    """
    tokens = batch["tokens"]
    B, S = tokens.shape
    cap = cache_capacity or S
    prog = build_program(cfg)
    ctx = _get_ctx(params, cfg, batch, axes)
    x = _embed(params, cfg, tokens, axes)
    positions = jnp.arange(S)[None]
    x, _, caches = _run_program(params, prog, x, cfg, axes, positions, ctx,
                                emit_cache=True, cache_capacity=cap,
                                remat=False)
    if last_pos is None:
        x_last = x[:, -1:]
    else:
        x_last = jax.lax.dynamic_slice_in_dim(
            x, jnp.asarray(last_pos, jnp.int32), 1, axis=1)
    logits = _unembed(params, cfg, x_last, axes)
    return logits[:, 0], caches


# ---------------------------------------------------------------------------
# Cache structure (analytic: ShapeDtypeStructs + PartitionSpecs)
# ---------------------------------------------------------------------------


def cache_struct(cfg: ArchConfig, batch: int, capacity: int,
                 axes: MeshAxes = MeshAxes(), ctx_len: int = 0,
                 dtype=jnp.bfloat16):
    """Decode-cache pytree as (ShapeDtypeStruct tree, PartitionSpec tree).

    capacity: KV slots for full-attention layers (window layers use
    min(window, capacity)).  ctx_len: encoder / vision context length for
    cross sublayers.
    """
    prog = build_program(cfg)
    hd = cfg.resolved_head_dim
    _, kv_spec = _head_specs(cfg, axes)
    batch_ok = axes.mesh is None or batch % axes.dp_size == 0
    b_spec = axes.dp if batch_ok else None
    # batch-1 long-context: shard the KV sequence dim on the data axis
    s_spec = None if batch_ok else axes.dp[-1]

    if cfg.decode_cache_seq_shard == "tp" and kv_spec is None:
        # split-KV: kv heads don't divide TP, so the cache SEQUENCE rides
        # the model axis instead (flash-decoding across devices)
        s_spec = axes.tp if axes.mesh is not None else None
        kv_spec = None

    def entry(spec: LayerSpec, stacked: int):
        lead = (stacked,) if stacked else ()
        lspec = (None,) if stacked else ()
        if spec.kind == "attn":
            W = min(spec.window, capacity) if spec.window else capacity
            return (
                {"k": jax.ShapeDtypeStruct(lead + (batch, W, cfg.n_kv, hd),
                                           dtype),
                 "v": jax.ShapeDtypeStruct(lead + (batch, W, cfg.n_kv, hd),
                                           dtype),
                 "pos": jax.ShapeDtypeStruct(lead + (batch, W), jnp.int32)},
                {"k": P(*lspec, b_spec, s_spec, kv_spec, None),
                 "v": P(*lspec, b_spec, s_spec, kv_spec, None),
                 "pos": P(*lspec, b_spec, s_spec)})
        if spec.kind == "cross":
            return (
                {"k": jax.ShapeDtypeStruct(
                    lead + (batch, ctx_len, cfg.n_kv, hd), dtype),
                 "v": jax.ShapeDtypeStruct(
                    lead + (batch, ctx_len, cfg.n_kv, hd), dtype)},
                {"k": P(*lspec, b_spec, None, kv_spec, None),
                 "v": P(*lspec, b_spec, None, kv_spec, None)})
        if spec.kind == "rec":
            dr = cfg.d_model
            return (
                {"conv": jax.ShapeDtypeStruct(
                    lead + (batch, cfg.conv_kernel - 1, dr), dtype),
                 "h": jax.ShapeDtypeStruct(lead + (batch, dr), jnp.float32)},
                {"conv": P(*lspec, b_spec, None, axes.tp),
                 "h": P(*lspec, b_spec, axes.tp)})
        if spec.kind == "ssm":
            d_in = cfg.ssm_expand * cfg.d_model
            nh = d_in // cfg.ssm_head_dim
            conv_dim = d_in + 2 * cfg.ssm_state
            return (
                {"conv": jax.ShapeDtypeStruct(
                    lead + (batch, cfg.conv_kernel - 1, conv_dim), dtype),
                 "state": jax.ShapeDtypeStruct(
                    lead + (batch, nh, cfg.ssm_head_dim, cfg.ssm_state),
                    jnp.float32)},
                {"conv": P(*lspec, b_spec, None, None),
                 "state": P(*lspec, b_spec, axes.tp, None, None)})
        raise ValueError(spec.kind)

    shapes, specs = {}, {}
    if prog.n_groups > 0:
        for idx, spec in enumerate(prog.group):
            shapes[f"g{idx}"], specs[f"g{idx}"] = entry(spec, prog.n_groups)
    for idx, spec in enumerate(prog.leftover):
        shapes[f"x{idx}"], specs[f"x{idx}"] = entry(spec, 0)
    return shapes, specs


def init_cache(cfg: ArchConfig, batch: int, capacity: int,
               axes: MeshAxes = MeshAxes(), ctx_len: int = 0,
               dtype=jnp.bfloat16):
    """Zero-initialised decode cache (pos slots = -1 = empty)."""
    shapes, _ = cache_struct(cfg, batch, capacity, axes, ctx_len, dtype)

    def mk(sd):
        if sd.dtype == jnp.int32:
            return jnp.full(sd.shape, -1, jnp.int32)
        return jnp.zeros(sd.shape, sd.dtype)

    return jax.tree.map(mk, shapes)


def decode_step(params, caches, tokens, positions, cfg: ArchConfig,
                axes: MeshAxes = MeshAxes()):
    """One token for every sequence.  tokens [B,1], positions [B]."""
    prog = build_program(cfg)
    x = _embed(params, cfg, tokens, axes)

    def group_body(x, inp):
        gparams, gcache = inp
        new_entries = {}
        for idx, spec in enumerate(prog.group):
            key = f"g{idx}"
            x, new_entries[key] = _sublayer_decode(
                gparams[key], spec, x, cfg, axes, positions, gcache[key])
        return x, new_entries

    xs_params = {k: params[k] for k in params
                 if k.startswith("g") and k[1:].isdigit()}
    xs_cache = {k: caches[k] for k in caches
                if k.startswith("g") and k[1:].isdigit()}
    if xs_params:
        x, new_caches = jax.lax.scan(group_body, x, (xs_params, xs_cache))
    else:
        new_caches = {}
    for idx, spec in enumerate(prog.leftover):
        key = f"x{idx}"
        x, new_caches[key] = _sublayer_decode(
            params[key], spec, x, cfg, axes, positions, caches[key])
    logits = _unembed(params, cfg, x, axes)
    return logits[:, 0], new_caches

"""Shared model infrastructure: sharding axes, norms, RoPE, block attention.

Everything here is pure-functional JAX.  Attention is implemented block-wise
(static python unroll over query blocks, causal/window-aware key ranges) so
that 32k prefill and 500k decode lower without materializing S^2 scores, and
so that compiled HLO FLOPs match the *useful* work (no 2x masked overcount
for causal, no S^2 for sliding-window layers).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P


# ---------------------------------------------------------------------------
# Mesh axes / sharding helpers
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MeshAxes:
    """Resolves logical sharding axes to the physical mesh.

    dp: axes carrying the batch (("data",) single-pod, ("pod","data") multi).
    fsdp: axis sharding weight rows (ZeRO-3 style gather-per-use).
    tp: tensor-parallel axis (heads / d_ff / vocab).
    mesh None => no sharding (CPU smoke tests): all helpers become no-ops.
    """

    mesh: Any = None
    dp: tuple = ("data",)
    fsdp: str = "data"
    tp: str = "model"

    @property
    def tp_size(self) -> int:
        if self.mesh is None:
            return 1
        return self.mesh.shape[self.tp]

    @property
    def dp_size(self) -> int:
        if self.mesh is None:
            return 1
        n = 1
        for a in self.dp:
            n *= self.mesh.shape[a]
        return n

    def sharding(self, *spec) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, P(*spec))

    def constrain(self, x, *spec):
        """with_sharding_constraint, or identity off-mesh."""
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(*spec)))


# ---------------------------------------------------------------------------
# Parameter bookkeeping: each creation site declares its PartitionSpec.
# ---------------------------------------------------------------------------


class ParamStore:
    """Collects (value, pspec) pairs into parallel pytrees."""

    def __init__(self, key, dtype=jnp.bfloat16):
        self._key = key
        self.dtype = dtype
        self.params: dict = {}
        self.specs: dict = {}

    def _next_key(self):
        self._key, sub = jax.random.split(self._key)
        return sub

    def add(self, name: str, shape, pspec, *, scale: float = None,
            zeros: bool = False, dtype=None):
        dtype = dtype or self.dtype
        if zeros:
            val = jnp.zeros(shape, dtype)
        else:
            if scale is None:
                scale = 1.0 / math.sqrt(shape[-2] if len(shape) >= 2
                                        else shape[-1])
            val = (jax.random.normal(self._next_key(), shape, jnp.float32)
                   * scale).astype(dtype)
        self.params[name] = val
        self.specs[name] = P(*pspec)
        return val

    def subtree(self, name: str) -> "ParamStore":
        sub = ParamStore.__new__(ParamStore)
        sub._key = self._next_key()
        sub.dtype = self.dtype
        sub.params = self.params.setdefault(name, {})
        sub.specs = self.specs.setdefault(name, {})
        return sub


def stack_trees(trees):
    """Stack a list of identical pytrees along a new leading axis."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def stack_specs(spec_tree):
    """Prepend None (replicated) to every PartitionSpec for a stacked axis."""
    return jax.tree.map(
        lambda s: P(None, *s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Norms / activations
# ---------------------------------------------------------------------------


def rmsnorm(x, scale, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layernorm(x, scale, bias, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def apply_norm(x, p, kind: str):
    if kind == "rmsnorm":
        return rmsnorm(x, p["scale"])
    return layernorm(x, p["scale"], p["bias"])


def act_fn(name: str):
    return {"gelu": jax.nn.gelu, "silu": jax.nn.silu,
            "gelu_glu": jax.nn.gelu, "swiglu": jax.nn.silu}[name]


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_tables(positions, head_dim: int, theta: float):
    """positions [*, S] -> (sin, cos) each [*, S, head_dim/2], f32."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x, sin, cos):
    """x [..., S, H, D]; sin/cos [..., S, D/2] broadcast over heads."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    sin = sin[..., None, :]  # add head axis
    cos = cos[..., None, :]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# Block attention (prefill / train): static unroll over q blocks, only the
# causally (and window-) reachable k blocks are computed.
# ---------------------------------------------------------------------------


NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _block_sizes(s_q: int, s_k: int):
    bq = min(s_q, max(512, -(-s_q // 16)))   # <=16 q blocks
    bq = min(bq, 2048)
    bq = math.gcd(s_q, bq) if s_q % bq else bq
    bk = min(s_k, 1024)                      # K side is PADDED to bk
    return bq, bk


def _expand_kv(k, n_heads: int):
    """[B, S, KV, D] -> [B, S, H, D] by repeating each group (GQA)."""
    KV = k.shape[2]
    if KV == n_heads:
        return k
    return jnp.repeat(k, n_heads // KV, axis=2)


def block_attention(q, k, v, *, causal: bool, window: int = 0,
                    q_offset=0, axes: MeshAxes = MeshAxes(),
                    head_sharded: bool = True, kv_sharded: bool = False):
    """Memory-bounded attention.

    q: [B, Sq, H, D]; k, v: [B, Sk, KV, D] (GQA: KV divides H; keys are
    broadcast to H inside so the head dim shards cleanly over TP).
    causal: apply causal mask with q position = q_offset + i.
    window: if >0, only attend to keys within `window` positions back.
    Returns [B, Sq, H, D].
    """
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    bq, bk = _block_sizes(Sq, Sk)
    scale = 1.0 / math.sqrt(D)
    tp_spec = axes.tp if head_sharded else None
    b_spec = axes.dp if (axes.mesh is not None
                         and B % axes.dp_size == 0) else None
    # Pin KV shardings explicitly: when KV heads don't divide the TP axis,
    # keep them REPLICATED pre-expand — otherwise GSPMD attempts an uneven
    # kv-head resharding ("involuntary full rematerialization") that
    # explodes compile time.  Post-expand, heads shard cleanly over TP.
    kv_tp = axes.tp if kv_sharded else None
    k = axes.constrain(k, b_spec, None, kv_tp, None)
    v = axes.constrain(v, b_spec, None, kv_tp, None)
    k = _expand_kv(k, H)
    v = _expand_kv(v, H)
    k = axes.constrain(k, b_spec, None, tp_spec, None)
    v = axes.constrain(v, b_spec, None, tp_spec, None)

    pad_k = (-Sk) % bk                       # ragged contexts (e.g. 6404
    if pad_k:                                # vision tokens): pad + mask
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))

    n_q = Sq // bq
    n_k = (Sk + pad_k) // bk
    out_blocks = []
    for i in range(n_q):
        q_blk = jax.lax.slice_in_dim(q, i * bq, (i + 1) * bq, axis=1)
        q_blk = axes.constrain(q_blk, axes.dp, None, tp_spec, None)
        # static key-block range for this q block
        if causal:
            hi = i * bq + bq  # highest key index (exclusive) of interest
            k_hi_blk = min(n_k, -(-hi // bk))
        else:
            k_hi_blk = n_k
        if causal and window > 0:
            lo = max(0, i * bq - window)
            k_lo_blk = lo // bk
        else:
            k_lo_blk = 0
        m = jnp.full((B, bq, H), NEG_INF, jnp.float32)
        l = jnp.zeros((B, bq, H), jnp.float32)
        acc = jnp.zeros((B, bq, H, D), jnp.float32)
        for j in range(k_lo_blk, k_hi_blk):
            k_blk = jax.lax.slice_in_dim(k, j * bk, (j + 1) * bk, axis=1)
            v_blk = jax.lax.slice_in_dim(v, j * bk, (j + 1) * bk, axis=1)
            s = jnp.einsum("bqhd,bkhd->bqhk", q_blk, k_blk,
                           preferred_element_type=jnp.float32) * scale
            if causal or window > 0 or (pad_k and j == n_k - 1):
                qpos = q_offset + i * bq + jnp.arange(bq)
                kpos = j * bk + jnp.arange(bk)
                ok = jnp.broadcast_to(kpos[None, :] < Sk, (bq, bk))
                if causal:
                    ok &= qpos[:, None] >= kpos[None, :]
                if window > 0:
                    ok &= qpos[:, None] - kpos[None, :] < window
                s = jnp.where(ok[None, :, None, :], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bqhk,bkhd->bqhd", p.astype(v.dtype), v_blk,
                preferred_element_type=jnp.float32)
            m = m_new
        out_blocks.append(acc / jnp.maximum(l[..., None], 1e-30))
    out = jnp.concatenate(out_blocks, axis=1).astype(q.dtype)
    return axes.constrain(out, axes.dp, None, tp_spec, None)


def decode_attention(q, k_cache, v_cache, kv_positions, pos, *,
                     window: int = 0, axes: MeshAxes = MeshAxes(),
                     seq_axis_spec=None):
    """Single-token attention against a (possibly ring-buffered) KV cache.

    q: [B, 1, H, D]; k_cache/v_cache: [B, W, KV, D];
    kv_positions: [B, W] absolute position of each slot (-1 = empty).
    pos: [B] current query position.
    """
    B, _, H, D = q.shape
    KV = k_cache.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(D)
    b_spec = axes.dp if (axes.mesh is not None
                         and B % axes.dp_size == 0) else None
    # GQA stays FOLDED at decode: q [B,1,KV,G,D] against k [B,W,KV,D]
    # so the repeated KV never materializes (a W x G-fold temp saving).
    kv_tp = axes.tp if (KV % max(axes.tp_size, 1) == 0
                        and seq_axis_spec != axes.tp) else None
    k_cache = axes.constrain(k_cache, b_spec, seq_axis_spec, kv_tp, None)
    v_cache = axes.constrain(v_cache, b_spec, seq_axis_spec, kv_tp, None)
    qf = q.reshape(B, 1, KV, G, D)
    s = jnp.einsum("bqkgd,bwkd->bqkgw", qf, k_cache,
                   preferred_element_type=jnp.float32) * scale
    ok = (kv_positions >= 0) & (kv_positions <= pos[:, None])
    if window > 0:
        ok &= (pos[:, None] - kv_positions) < window
    s = jnp.where(ok[:, None, None, None, :], s, NEG_INF)
    if seq_axis_spec is not None:
        # split-KV: scores sharded along the cache sequence; the softmax
        # normalization lowers to the cross-device combine
        s = axes.constrain(s, b_spec if seq_axis_spec == axes.tp else None,
                           None, None, None, seq_axis_spec)
    p = jax.nn.softmax(s.astype(jnp.float32), axis=-1)
    out = jnp.einsum("bqkgw,bwkd->bqkgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, D).astype(q.dtype)

"""Griffin / RecurrentGemma RG-LRU recurrent block (arXiv:2402.19427).

Train path: associative scan over the gated linear recurrence
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
with a_t = exp(-c * softplus(Lambda) * r_t),  r/i input-dependent gates.
Decode path: single-step update with O(d) state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import MeshAxes, ParamStore
from repro.models.ssm import _causal_conv

_C = 8.0


_N_BLOCKS = 16  # Griffin uses block-diagonal gate projections; 16 blocks
                # aligns the block axis with the tensor-parallel axis.


def init_rglru(store: ParamStore, cfg, axes: MeshAxes):
    d = cfg.d_model
    dr = d  # lru width = d_model in recurrentgemma-2b
    nb = _N_BLOCKS if dr % _N_BLOCKS == 0 else 1
    c = dr // nb
    store.add("w_x", (d, dr), (axes.fsdp, axes.tp))
    store.add("w_gate", (d, dr), (axes.fsdp, axes.tp))
    store.add("conv_w", (cfg.conv_kernel, dr), (None, axes.tp), scale=0.5)
    store.add("conv_b", (dr,), (axes.tp,), zeros=True)
    store.add("w_a_gate", (nb, c, c), (axes.tp, None, None), scale=0.02)
    store.add("b_a_gate", (dr,), (axes.tp,), zeros=True)
    store.add("w_i_gate", (nb, c, c), (axes.tp, None, None), scale=0.02)
    store.add("b_i_gate", (dr,), (axes.tp,), zeros=True)
    store.add("lam", (dr,), (axes.tp,), scale=1.0, dtype=jnp.float32)
    store.add("w_out", (dr, d), (axes.tp, axes.fsdp))


def _block_linear(x, w):
    """x: [B,S,dr], w: [nb,c,c] block-diagonal -> [B,S,dr]."""
    B, S, dr = x.shape
    nb, c, _ = w.shape
    xb = x.reshape(B, S, nb, c)
    return jnp.einsum("bsnc,nck->bsnk", xb, w).reshape(B, S, dr)


def _lru_scan(a, u):
    """h_t = a_t h_{t-1} + u_t via associative scan; a,u: [B,S,C] f32."""
    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br
    _, h = jax.lax.associative_scan(combine, (a, u), axis=1)
    return h


def apply_rglru(p, x, cfg, axes: MeshAxes, conv_state=None, h_state=None,
                decode: bool = False):
    """x: [B,S,D] -> ([B,S,D], (conv_state, h_state))."""
    xb = x @ p["w_x"]
    gate = jax.nn.gelu(x @ p["w_gate"])
    xb, new_conv = _causal_conv(xb, p["conv_w"], p["conv_b"], conv_state)
    xb = axes.constrain(xb, axes.dp, None, axes.tp)

    xf = xb.astype(jnp.float32)
    r = jax.nn.sigmoid(_block_linear(xf, p["w_a_gate"].astype(jnp.float32))
                       + p["b_a_gate"].astype(jnp.float32))
    i = jax.nn.sigmoid(_block_linear(xf, p["w_i_gate"].astype(jnp.float32))
                       + p["b_i_gate"].astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lam"]) * r          # [B,S,C] f32
    a = jnp.exp(log_a)
    gated_in = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * xf)

    if decode:
        h0 = jnp.zeros_like(gated_in[:, 0]) if h_state is None else h_state
        h = a[:, 0] * h0 + gated_in[:, 0]
        new_h = h
        y = h[:, None]
    else:
        if h_state is not None:
            gated_in = gated_in.at[:, 0].add(a[:, 0] * h_state)
        y = _lru_scan(a, gated_in)
        new_h = y[:, -1]

    out = (y.astype(x.dtype) * gate) @ p["w_out"]
    return out, (new_conv, new_h)

"""Model zoo: the 10 assigned architectures as pure-functional JAX modules.

Entry point: :func:`repro.models.registry.get_model`.
"""
from repro.models.registry import get_model  # noqa: F401

"""Dense gated MLP and sort-based capacity MoE.

The MoE dispatch follows the "tokens become data" discipline: token->expert
assignments are sorted by expert id and scattered into a capacity-padded
[E, C, D] buffer so the expert FFN is a single grouped matmul (static shapes,
near-zero FLOP overhead vs the one-hot einsum dispatch).  Sharding: expert
weights are FSDP x TP sharded; the buffer's capacity dim rides the data axis.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import MeshAxes, ParamStore, act_fn


# ---------------------------------------------------------------------------
# Dense gated MLP (swiglu / geglu)
# ---------------------------------------------------------------------------


def init_mlp(store: ParamStore, d_model: int, d_ff: int, axes: MeshAxes):
    store.add("w_gate", (d_model, d_ff), (axes.fsdp, axes.tp))
    store.add("w_up", (d_model, d_ff), (axes.fsdp, axes.tp))
    store.add("w_down", (d_ff, d_model), (axes.tp, axes.fsdp))


def apply_mlp(p, x, act: str, axes: MeshAxes):
    h = act_fn(act)(x @ p["w_gate"]) * (x @ p["w_up"])
    if h.ndim == 3:
        h = axes.constrain(h, axes.dp, None, axes.tp)
    else:  # flattened tokens [T, d_ff] (MoE shared-expert path)
        h = axes.constrain(h, axes.dp, axes.tp)
    return h @ p["w_down"]


def init_mlp_nonglu(store: ParamStore, d_model: int, d_ff: int,
                    axes: MeshAxes):
    store.add("w_in", (d_model, d_ff), (axes.fsdp, axes.tp))
    store.add("b_in", (d_ff,), (axes.tp,), zeros=True)
    store.add("w_out", (d_ff, d_model), (axes.tp, axes.fsdp))
    store.add("b_out", (d_model,), (None,), zeros=True)


def apply_mlp_nonglu(p, x, act: str, axes: MeshAxes):
    h = act_fn(act)(x @ p["w_in"] + p["b_in"])
    h = axes.constrain(h, axes.dp, None, axes.tp)
    return h @ p["w_out"] + p["b_out"]


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------


def init_moe(store: ParamStore, d_model: int, moe_cfg, axes: MeshAxes):
    E, ffe = moe_cfg.num_experts, moe_cfg.d_ff_expert
    store.add("router", (d_model, E), (axes.fsdp, None), scale=0.02)
    store.add("we_gate", (E, d_model, ffe), (None, axes.fsdp, axes.tp))
    store.add("we_up", (E, d_model, ffe), (None, axes.fsdp, axes.tp))
    store.add("we_down", (E, ffe, d_model), (None, axes.tp, axes.fsdp))
    if moe_cfg.num_shared:
        # shared experts act as one dense MLP of width num_shared * ffe
        sub = store.subtree("shared")
        init_mlp(sub, d_model, moe_cfg.num_shared * ffe, axes)


def moe_capacity(n_tokens: int, moe_cfg) -> int:
    c = int(n_tokens * moe_cfg.top_k / moe_cfg.num_experts
            * moe_cfg.capacity_factor)
    return max(8, -(-c // 8) * 8)


def apply_moe(p, x, moe_cfg, act: str, axes: MeshAxes,
              dispatch: str = "sort"):
    """x: [B, S, D] -> [B, S, D].

    dispatch="sort": global argsort by expert id (baseline; XLA inserts the
    gather collectives).  dispatch="onehot": GShard-style einsum dispatch
    (used for numerical cross-checks in tests).  dispatch="sharded":
    shard-local dispatch — tokens are reshaped to [dp_shards, T/dp, D] with
    the shard dim pinned to the data axis, and the sort/scatter/gather all
    happen WITHIN a shard (vmapped), so token dispatch moves zero bytes
    across devices; only the (FSDP x TP) expert weights are communicated.
    """
    if dispatch == "sharded":
        return _apply_moe_sharded(p, x, moe_cfg, act, axes)
    B, S, D = x.shape
    T = B * S
    E, k = moe_cfg.num_experts, moe_cfg.top_k
    xt = x.reshape(T, D)

    logits = (xt @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)            # [T, k]
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)

    C = moe_capacity(T, moe_cfg)

    if dispatch == "onehot":
        # reference path: positions via per-expert cumsum
        flat_e = top_e.reshape(-1)                    # [T*k]
        onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
        pos = jnp.cumsum(onehot, axis=0) * onehot - 1  # position in expert
        pos = jnp.max(pos, axis=-1)
        keep = pos < C
        dest = jnp.where(keep, flat_e * C + pos, E * C)
        buf = jnp.zeros((E * C + 1, D), x.dtype)
        tok_idx = jnp.repeat(jnp.arange(T), k)
        buf = buf.at[dest].set(xt[tok_idx])
    else:
        flat_e = top_e.reshape(-1)
        order = jnp.argsort(flat_e, stable=True)
        sorted_e = flat_e[order]
        first = jnp.searchsorted(sorted_e, sorted_e, side="left")
        pos = jnp.arange(T * k) - first               # rank within expert
        keep = pos < C
        dest = jnp.where(keep, sorted_e * C + pos, E * C)
        tok_idx = jnp.repeat(jnp.arange(T), k)[order]
        buf = jnp.zeros((E * C + 1, D), x.dtype)
        buf = buf.at[dest].set(xt[tok_idx])

    xb = buf[: E * C].reshape(E, C, D)
    xb = axes.constrain(xb, None, axes.dp[-1], None)
    h = act_fn(act)(jnp.einsum("ecd,edf->ecf", xb, p["we_gate"])) \
        * jnp.einsum("ecd,edf->ecf", xb, p["we_up"])
    h = axes.constrain(h, None, axes.dp[-1], axes.tp)
    yb = jnp.einsum("ecf,efd->ecd", h, p["we_down"])
    yb = axes.constrain(yb, None, axes.dp[-1], None)
    yb = yb.reshape(E * C, D)

    if dispatch == "onehot":
        y_flat = jnp.where(keep[:, None],
                           yb[jnp.clip(dest, 0, E * C - 1)], 0.0)
        w = top_w.reshape(-1)[:, None].astype(x.dtype)
        y = jnp.zeros((T, D), x.dtype).at[tok_idx].add(y_flat * w)
    else:
        y_flat = jnp.where(keep[:, None],
                           yb[jnp.clip(dest, 0, E * C - 1)], 0.0)
        w = top_w.reshape(-1)[order][:, None].astype(x.dtype)
        y = jnp.zeros((T, D), x.dtype).at[tok_idx].add(y_flat * w)

    if moe_cfg.num_shared:
        y = y + apply_mlp(p["shared"], xt, act, axes)

    # auxiliary load-balance loss (Switch): E * sum_e f_e * p_e
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(top_e[:, 0], E, dtype=jnp.float32), axis=0)
    aux = E * jnp.sum(me * ce)
    return y.reshape(B, S, D), aux


def _apply_moe_sharded(p, x, moe_cfg, act: str, axes: MeshAxes):
    """Shard-local capacity dispatch (beyond-paper optimization, §Perf).

    The token permutation never crosses the data axis: each of the
    `n_shards` groups dispatches its own T/n tokens into its own
    [E, C/n, D] buffer (vmapped sort-dispatch), then the grouped expert
    matmul batches over shards.  Capacity is per-shard, which slightly
    changes drop behaviour under imbalance (standard for EP systems).
    """
    B, S, D = x.shape
    T = B * S
    E, k = moe_cfg.num_experts, moe_cfg.top_k
    n_sh = axes.dp_size if axes.mesh is not None else 1
    assert T % n_sh == 0
    Tl = T // n_sh
    xs = x.reshape(n_sh, Tl, D)
    xs = axes.constrain(xs, axes.dp[-1], None, None)

    logits = jnp.einsum("ntd,de->nte", xs, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)              # [n, Tl, k]
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)
    C = moe_capacity(Tl, moe_cfg)

    def local_dispatch(xt, flat_e):
        """xt [Tl, D]; flat_e [Tl*k] -> buffer [E*C+1, D], dest, tok_idx."""
        order = jnp.argsort(flat_e, stable=True)
        sorted_e = flat_e[order]
        first = jnp.searchsorted(sorted_e, sorted_e, side="left")
        pos = jnp.arange(Tl * k) - first
        keep = pos < C
        dest = jnp.where(keep, sorted_e * C + pos, E * C)
        tok_idx = jnp.repeat(jnp.arange(Tl), k)[order]
        buf = jnp.zeros((E * C + 1, D), xt.dtype).at[dest].set(xt[tok_idx])
        return buf, dest, tok_idx, keep, order

    buf, dest, tok_idx, keep, order = jax.vmap(local_dispatch)(
        xs, top_e.reshape(n_sh, Tl * k))
    xb = buf[:, :E * C].reshape(n_sh, E, C, D)
    xb = axes.constrain(xb, axes.dp[-1], None, None, None)
    h = act_fn(act)(jnp.einsum("necd,edf->necf", xb, p["we_gate"])) \
        * jnp.einsum("necd,edf->necf", xb, p["we_up"])
    h = axes.constrain(h, axes.dp[-1], None, None, axes.tp)
    yb = jnp.einsum("necf,efd->necd", h, p["we_down"])
    yb = axes.constrain(yb, axes.dp[-1], None, None, None)
    yb = yb.reshape(n_sh, E * C, D)

    def local_combine(yb_s, dest_s, tok_idx_s, keep_s, w_s):
        y_flat = jnp.where(keep_s[:, None],
                           yb_s[jnp.clip(dest_s, 0, E * C - 1)], 0.0)
        return jnp.zeros((Tl, D), yb_s.dtype).at[tok_idx_s].add(
            y_flat * w_s[:, None])

    w_sorted = jnp.take_along_axis(
        top_w.reshape(n_sh, Tl * k), order, axis=1).astype(x.dtype)
    y = jax.vmap(local_combine)(yb, dest, tok_idx, keep, w_sorted)
    y = y.reshape(B, S, D)

    if moe_cfg.num_shared:
        y = y + apply_mlp(p["shared"], x.reshape(T, D), act,
                          axes).reshape(B, S, D)
    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.mean(jax.nn.one_hot(top_e[..., 0], E, dtype=jnp.float32),
                  axis=(0, 1))
    aux = E * jnp.sum(me * ce)
    return y, aux

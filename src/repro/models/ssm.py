"""Mamba-2 SSD (state-space duality) block.

Training path uses the chunked SSD algorithm from arXiv:2405.21060 — the
quadratic intra-chunk part is dense matmuls (MXU-friendly), the inter-chunk
part is a length-S/Q linear recurrence.  Decode is the O(1)-state recurrent
step.  A naive per-timestep recurrence lives in kernels/ref.py as the oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import MeshAxes, ParamStore


def init_ssm(store: ParamStore, cfg, axes: MeshAxes):
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    nh = d_in // cfg.ssm_head_dim
    n = cfg.ssm_state
    conv_dim = d_in + 2 * n
    store.add("w_in_zx", (d, 2 * d_in), (axes.fsdp, axes.tp))
    store.add("w_in_bc", (d, 2 * n), (axes.fsdp, None))
    store.add("w_in_dt", (d, nh), (axes.fsdp, None))
    store.add("conv_w", (cfg.conv_kernel, conv_dim), (None, None), scale=0.5)
    store.add("conv_b", (conv_dim,), (None,), zeros=True)
    store.add("A_log", (nh,), (None,), scale=0.0, dtype=jnp.float32)
    store.add("dt_bias", (nh,), (None,), zeros=True, dtype=jnp.float32)
    store.add("D", (nh,), (None,), zeros=True, dtype=jnp.float32)
    store.add("norm_scale", (d_in,), (axes.tp,), zeros=True)
    store.add("w_out", (d_in, d), (axes.tp, axes.fsdp))


def _causal_conv(u, w, b, state=None):
    """Depthwise causal conv, width K.  u: [B,S,C]; w: [K,C].

    state: [B, K-1, C] trailing context for decode; returns (y, new_state).
    """
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros(u.shape[:1] + (K - 1,) + u.shape[2:], u.dtype)
    else:
        pad = state.astype(u.dtype)
    full = jnp.concatenate([pad, u], axis=1)
    y = sum(jax.lax.slice_in_dim(full, i, i + u.shape[1], axis=1)
            * w[i].astype(u.dtype) for i in range(K))
    new_state = jax.lax.slice_in_dim(full, full.shape[1] - (K - 1),
                                     full.shape[1], axis=1)
    return y + b.astype(u.dtype), new_state


def _segsum(a):
    """a: [..., Q] -> [..., Q, Q] lower-tri pairwise sums a[j+1..i]."""
    Q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, chunk: int, init_state=None):
    """SSD scan.  x:[b,s,h,p] dt:[b,s,h] A:[h] B,C:[b,s,n].

    Returns (y [b,s,h,p], final_state [b,h,p,n]).
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    Q = min(chunk, s)
    assert s % Q == 0, f"seq {s} not divisible by chunk {Q}"
    nc = s // Q

    dA = dt * A  # [b,s,h], negative log-decay per step
    xs = (x * dt[..., None]).reshape(b, nc, Q, h, p)
    dA = dA.reshape(b, nc, Q, h)
    Bc = B.reshape(b, nc, Q, n)
    Cc = C.reshape(b, nc, Q, n)

    dA_cs = jnp.cumsum(dA, axis=2)                      # [b,nc,Q,h]

    # 1. intra-chunk (diagonal blocks): quadratic in Q, matmul-shaped
    L = jnp.exp(_segsum(jnp.moveaxis(dA, 3, 2)))        # [b,nc,h,Q,Q]
    scores = jnp.einsum("bcqn,bckn->bcqk", Cc, Bc)
    y_diag = jnp.einsum("bcqk,bchqk,bckhp->bcqhp",
                        scores, L.astype(scores.dtype), xs)

    # 2. per-chunk end states
    decay_to_end = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)  # [b,nc,Q,h]
    states = jnp.einsum("bcqn,bcqh,bcqhp->bchpn",
                        Bc, decay_to_end.astype(Bc.dtype), xs)

    # 3. inter-chunk linear recurrence over nc chunks
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])            # [b,nc,h]
    s0 = (jnp.zeros((b, h, p, n), x.dtype) if init_state is None
          else init_state.astype(x.dtype))

    def step(carry, inp):
        st, dec = inp
        new = carry * dec[..., None, None].astype(carry.dtype) + st
        return new, carry  # emit state *entering* the chunk

    final, prev_states = jax.lax.scan(
        step, s0,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)        # [b,nc,h,p,n]

    # 4. inter-chunk contribution
    y_off = jnp.einsum("bcqn,bchpn,bcqh->bcqhp",
                       Cc, prev_states, jnp.exp(dA_cs).astype(Cc.dtype))
    y = (y_diag + y_off).reshape(b, s, h, p)
    return y, final


def apply_ssm(p, x, cfg, axes: MeshAxes, conv_state=None, ssd_state=None,
              decode: bool = False):
    """Mamba-2 block.  x: [B,S,D] -> ([B,S,D], (conv_state, ssd_state))."""
    B_, S, D = x.shape
    d_in = cfg.ssm_expand * D
    nh = d_in // cfg.ssm_head_dim
    hd = cfg.ssm_head_dim
    n = cfg.ssm_state

    zx = x @ p["w_in_zx"]
    z, xin = jnp.split(zx, 2, axis=-1)
    bc = x @ p["w_in_bc"]
    dt = jax.nn.softplus((x @ p["w_in_dt"]).astype(jnp.float32)
                         + p["dt_bias"])

    u = jnp.concatenate([xin, bc], axis=-1)
    u, new_conv = _causal_conv(u, p["conv_w"], p["conv_b"], conv_state)
    u = jax.nn.silu(u)
    xin, Bmat, Cmat = jnp.split(u, [d_in, d_in + n], axis=-1)
    xin = axes.constrain(xin, axes.dp, None, axes.tp)

    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = xin.reshape(B_, S, nh, hd).astype(jnp.float32)
    Bf = Bmat.astype(jnp.float32)
    Cf = Cmat.astype(jnp.float32)

    if decode:
        # single step: state <- exp(dt*A)*state + dt*B (x) x
        st = jnp.zeros((B_, nh, hd, n), jnp.float32) if ssd_state is None \
            else ssd_state
        dA = jnp.exp(dt[:, 0] * A)                       # [B,h]
        upd = jnp.einsum("bn,bh,bhp->bhpn", Bf[:, 0], dt[:, 0], xh[:, 0])
        st = st * dA[..., None, None] + upd
        y = jnp.einsum("bn,bhpn->bhp", Cf[:, 0], st)[:, None]
        new_state = st
    else:
        # pad S to the chunk size; padded steps carry dt=0 (identity
        # transition: no decay, no input) so the final state is exact
        Q = min(cfg.ssm_chunk, max(S, 1))
        pad = (-S) % Q
        xp, Bp, Cp, dtp = xh, Bf, Cf, dt
        if pad:
            zf = lambda a: jnp.pad(  # noqa: E731
                a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2))
            xp, Bp, Cp, dtp = zf(xh), zf(Bf), zf(Cf), zf(dt)
        y, new_state = ssd_chunked(xp, dtp, A, Bp, Cp, Q, ssd_state)
        if pad:
            y = y[:, :S]
    y = y + xh * p["D"][:, None]
    y = y.reshape(B_, S, d_in).astype(x.dtype)

    # gated RMSNorm then out-projection
    g = y * jax.nn.silu(z)
    gf = g.astype(jnp.float32)
    gf = gf * jax.lax.rsqrt(jnp.mean(gf * gf, -1, keepdims=True) + 1e-6)
    g = (gf * (1.0 + p["norm_scale"].astype(jnp.float32))).astype(x.dtype)
    out = g @ p["w_out"]
    return out, (new_conv, new_state)

"""Model registry: one uniform API over every assigned architecture.

``get_model(cfg)`` returns a :class:`ModelApi` whose members are plain
functions suitable for ``jax.jit`` / AOT ``.lower().compile()``:

  train_step(params, opt_state, batch)        -> (loss, params, opt_state)
  prefill(params, batch)                      -> (last_logits, cache)
  decode_step(params, cache, tokens, pos)     -> (logits, cache)

plus the analytic machinery the dry-run needs: ``input_specs`` (weak-type
correct ShapeDtypeStructs, no allocation), ``param_specs`` / shardings, and
``cache_struct``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import ArchConfig, ShapeSpec
from repro.models import transformer
from repro.models.common import MeshAxes
from repro.optim import adamw_init, adamw_update, opt_state_specs
from repro.optim.adamw import AdamWConfig


@dataclasses.dataclass
class ModelApi:
    cfg: ArchConfig
    axes: MeshAxes
    opt_cfg: AdamWConfig

    # ---------------- parameters -------------------------------------
    def init_params(self, key):
        params, _ = transformer.init_lm(key, self.cfg, self.axes)
        return params

    def _shapes_and_specs(self):
        captured = {}

        def f(k):
            params, specs = transformer.init_lm(k, self.cfg, self.axes)
            captured.update(specs)  # specs are plain python, trace-time
            return params

        shapes = jax.eval_shape(f, jax.random.PRNGKey(0))
        return shapes, captured

    def param_specs(self):
        return self._shapes_and_specs()[1]

    def param_shapes(self):
        return self._shapes_and_specs()[0]

    def init_opt(self, params):
        return adamw_init(params)

    def opt_specs(self):
        return opt_state_specs(self.param_specs())

    # ---------------- steps ------------------------------------------
    def loss(self, params, batch):
        return transformer.loss_fn(params, batch, self.cfg, self.axes)

    def train_step(self, params, opt_state, batch):
        loss, grads = jax.value_and_grad(self.loss)(params, batch)
        params, opt_state, gnorm = adamw_update(
            params, grads, opt_state, self.opt_cfg)
        return loss, params, opt_state, gnorm

    def prefill(self, params, batch, cache_capacity: Optional[int] = None,
                last_pos=None):
        return transformer.prefill(params, batch, self.cfg, self.axes,
                                   cache_capacity, last_pos=last_pos)

    def decode_step(self, params, caches, tokens, positions):
        return transformer.decode_step(params, caches, tokens, positions,
                                       self.cfg, self.axes)

    # ---------------- analytic specs for the dry-run ------------------
    def ctx_len(self, seq_len: int) -> int:
        if self.cfg.enc_dec:
            return seq_len
        if self.cfg.cross_every:
            return self.cfg.n_vision_tokens
        return 0

    def dec_len(self, seq_len: int) -> int:
        if self.cfg.enc_dec:
            return max(self.cfg.conv_kernel,
                       seq_len // self.cfg.dec_ratio)
        return seq_len

    def input_specs(self, shape: ShapeSpec):
        """ShapeDtypeStructs for one step of the given shape (no alloc)."""
        cfg, B, S = self.cfg, shape.global_batch, shape.seq_len
        d = cfg.d_model
        tok = lambda s: jax.ShapeDtypeStruct((B, s), jnp.int32)  # noqa: E731
        if shape.kind == "train":
            Sd = self.dec_len(S)
            batch = {"tokens": tok(Sd), "labels": tok(Sd)}
            if cfg.enc_dec:
                batch["frames"] = jax.ShapeDtypeStruct((B, S, d),
                                                       jnp.bfloat16)
            if cfg.cross_every:
                batch["vision"] = jax.ShapeDtypeStruct(
                    (B, cfg.n_vision_tokens, d), jnp.bfloat16)
            return {"batch": batch}
        if shape.kind == "prefill":
            Sd = self.dec_len(S)
            batch = {"tokens": tok(Sd)}
            if cfg.enc_dec:
                batch["frames"] = jax.ShapeDtypeStruct((B, S, d),
                                                       jnp.bfloat16)
            if cfg.cross_every:
                batch["vision"] = jax.ShapeDtypeStruct(
                    (B, cfg.n_vision_tokens, d), jnp.bfloat16)
            return {"batch": batch}
        # decode: one new token against a cache of seq_len
        cap = self.dec_len(S)
        cache, _ = transformer.cache_struct(
            cfg, B, cap, self.axes, ctx_len=self.ctx_len(S))
        return {"caches": cache,
                "tokens": tok(1),
                "positions": jax.ShapeDtypeStruct((B,), jnp.int32)}

    def input_pspecs(self, shape: ShapeSpec):
        """PartitionSpecs matching input_specs."""
        cfg, B = self.cfg, shape.global_batch
        batch_ok = self.axes.mesh is None or B % self.axes.dp_size == 0
        b = self.axes.dp if batch_ok else None
        if shape.kind in ("train", "prefill"):
            batch = {"tokens": P(b, None)}
            if shape.kind == "train":
                batch["labels"] = P(b, None)
            if cfg.enc_dec:
                batch["frames"] = P(b, None, None)
            if cfg.cross_every:
                batch["vision"] = P(b, None, None)
            return {"batch": batch}
        _, cache_specs = transformer.cache_struct(
            cfg, B, self.dec_len(shape.seq_len), self.axes,
            ctx_len=self.ctx_len(shape.seq_len))
        return {"caches": cache_specs,
                "tokens": P(b, None),
                "positions": P(b)}

    def step_fn(self, shape: ShapeSpec) -> Callable:
        """The function the dry-run lowers for this shape."""
        if shape.kind == "train":
            def fn(params, opt_state, batch):
                return self.train_step(params, opt_state, batch)
            return fn
        if shape.kind == "prefill":
            def fn(params, batch):
                return self.prefill(params, batch,
                                    cache_capacity=self.dec_len(
                                        shape.seq_len))
            return fn

        def fn(params, caches, tokens, positions):
            return self.decode_step(params, caches, tokens, positions)
        return fn


def get_model(cfg: ArchConfig, axes: MeshAxes = MeshAxes(),
              opt_cfg: AdamWConfig = AdamWConfig()) -> ModelApi:
    return ModelApi(cfg=cfg, axes=axes, opt_cfg=opt_cfg)

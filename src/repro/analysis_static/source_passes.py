"""Source passes: AST-level checks on hot-path modules.

The always-on engine must survive ``python -O``: a bare ``assert`` on a
hot path is a guard that silently vanishes under optimized bytecode, so
every invariant on the beat/fold path must be a ``raise``.  This pass
parses the shipped hot-path modules and reports any ``assert`` whose
failure would change behaviour (asserts inside ``tests/`` and in
clearly-dead ``TYPE_CHECKING`` blocks are out of scope — this list is
the serving surface only).
"""
from __future__ import annotations

import ast
import os
from typing import List, Optional, Sequence

from repro.analysis_static.diagnostics import LintFinding
from repro.analysis_static import registry as R
from repro.analysis_static.registry import register_pass

#: Modules that execute on the beat / fold / load path, relative to the
#: package root (``src/repro``).
HOT_PATH_MODULES = (
    "core/plan.py",
    "core/lowering.py",
    "core/executor.py",
    "core/storage.py",
    "core/dataquery.py",
    "core/operators.py",
    "core/folding.py",
    "core/sharding.py",
    "core/backends.py",
    "kernels/fused_delta.py",
    "kernels/ops.py",
)


def package_root() -> str:
    """Directory holding the ``repro`` package sources."""
    import repro
    return os.path.dirname(os.path.abspath(repro.__file__))


class _AssertVisitor(ast.NodeVisitor):
    def __init__(self):
        self.hits: List[ast.Assert] = []

    def visit_Assert(self, node: ast.Assert):
        self.hits.append(node)
        self.generic_visit(node)


def lint_source_text(text: str, relpath: str) -> List[LintFinding]:
    """Report each bare ``assert`` statement in one module's source."""
    try:
        tree = ast.parse(text, filename=relpath)
    except SyntaxError as e:
        return [LintFinding(
            R.NO_BARE_ASSERT,
            f"could not parse: {e}", location=relpath)]
    v = _AssertVisitor()
    v.visit(tree)
    out = []
    for node in v.hits:
        frag = ast.unparse(node.test) if hasattr(ast, "unparse") else ""
        out.append(LintFinding(
            R.NO_BARE_ASSERT,
            f"bare assert on a hot path (stripped under python -O) — "
            f"raise instead: assert {frag}",
            location=f"{relpath}:{node.lineno}"))
    return out


@register_pass("no-bare-assert", "source", (R.NO_BARE_ASSERT,),
               "hot-path modules must guard with raises, not asserts")
def lint_hot_path_asserts(modules: Optional[Sequence[str]] = None
                          ) -> List[LintFinding]:
    root = package_root()
    out = []
    for rel in (modules or HOT_PATH_MODULES):
        path = os.path.join(root, rel)
        if not os.path.exists(path):
            continue
        with open(path, "r", encoding="utf-8") as f:
            out.extend(lint_source_text(f.read(), f"repro/{rel}"))
    return out

"""The planlint rule + pass registry.

Rules are declared once, here, so the CLI can print the full table, the
README rule-id table has one source of truth, and a test can assert the
mutation corpus covers every family.  Pass modules register their entry
points with ``register_pass`` at import time; ``lint.py`` drives them
by family.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Tuple

FAMILIES = ("ir", "fold", "jaxpr", "kernel", "source")


@dataclasses.dataclass(frozen=True)
class Rule:
    id: str
    family: str
    summary: str


RULES: Dict[str, Rule] = {}


def _rule(id: str, family: str, summary: str) -> str:
    if family not in FAMILIES:
        raise ValueError(f"unknown family {family!r}")
    RULES[id] = Rule(id, family, summary)
    return id


# ---- IR rules (always-on: engine construction + every fold commit) ----
IR_SLOT_OVERLAP = _rule(
    "ir-slot-overlap", "ir",
    "template admission slot ranges must be pairwise disjoint")
IR_SLOT_COVERAGE = _rule(
    "ir-slot-coverage", "ir",
    "slot ranges must have positive caps and fit inside qcap "
    "(a multiple of 32)")
IR_WORD_WINDOW = _rule(
    "ir-word-window", "ir",
    "per-stage word windows, subscriber masks and predicate scatter "
    "plans must stay in bounds")
IR_PARTITION_GEOMETRY = _rule(
    "ir-partition-geometry", "ir",
    "partition-bucket geometry must cover the table capacity and the "
    "construction-time measured key skew (bucket_cap >= max_dup)")

# ---- fold rules (begin_fold / extend_plan admission) ------------------
FOLD_DUPLICATE_TEMPLATE = _rule(
    "fold-duplicate-template", "fold",
    "a fold may not register a template name already in the plan")
FOLD_DUPLICATE_IN_BATCH = _rule(
    "fold-duplicate-in-batch", "fold",
    "template names within one fold batch must be distinct")
FOLD_ZERO_CAP = _rule(
    "fold-zero-cap", "fold",
    "every folded template needs a positive slot capacity")
FOLD_ALIEN_TABLE = _rule(
    "fold-alien-table", "fold",
    "folds admit new query shapes, not new tables: every referenced "
    "table must already be in the catalog")
FOLD_UNKNOWN_COLUMN = _rule(
    "fold-unknown-column", "fold",
    "folded template predicates must bind existing columns")
FOLD_PLAN_PREFIX = _rule(
    "fold-plan-prefix", "fold",
    "the extended plan must keep every existing slot range and node "
    "position (plan-level prefix stability)")
FOLD_PREFIX_STABILITY = _rule(
    "fold-prefix-stability", "fold",
    "the extended LOWERED plan must be a prefix-stable extension "
    "(windows widen high-side only, stage order and join access paths "
    "fixed) or carries cannot migrate")
FOLD_IN_FLIGHT = _rule(
    "fold-in-flight", "fold",
    "only one fold may be in flight per engine")
FOLD_MIRROR_SET = _rule(
    "fold-mirror-set", "fold",
    "a fold under a mesh must not change the mirrored table set")

# ---- jaxpr rules ------------------------------------------------------
JAXPR_DELTA_COLLECTIVE = _rule(
    "jaxpr-delta-collective", "jaxpr",
    "delta beats must contain ZERO collective primitives at every "
    "shard count (shard-local by construction)")
JAXPR_RESEED_COLLECTIVE = _rule(
    "jaxpr-reseed-collective", "jaxpr",
    "the full/reseed beat's only collective is one all_gather per "
    "mirrored predicated scan stage, over that stage's per-shard rows")
JAXPR_DELTA_WIDTH = _rule(
    "jaxpr-delta-width", "jaxpr",
    "no full-window compare/probe may be reachable on the delta path "
    "(steady state pays pane width, never window width)")
JAXPR_DONATED_ALIAS = _rule(
    "jaxpr-donated-alias", "jaxpr",
    "buffers reachable through non-donated aliases (rid carry, staged "
    "queries/updates) must not be donated — use-after-donate")

# ---- kernel rules (fused mega-kernel static schedule) -----------------
KERNEL_SCHEDULE_COVERAGE = _rule(
    "kernel-schedule-coverage", "kernel",
    "every pane tile / dirty slot / probe slot is owned by exactly one "
    "schedule row")
KERNEL_GATHER_BOUNDS = _rule(
    "kernel-gather-bounds", "kernel",
    "scalar-prefetch gather indices stay inside their padded extent")
KERNEL_GRID_LENGTH = _rule(
    "kernel-grid-length", "kernel",
    "the pallas grid length equals the schedule length")
KERNEL_GARBAGE_PARK = _rule(
    "kernel-garbage-park", "kernel",
    "non-owning programs park on the garbage tile; every real output "
    "block has exactly one writer")

# ---- source rules -----------------------------------------------------
NO_BARE_ASSERT = _rule(
    "no-bare-assert", "source",
    "hot-path modules guard with raises, never bare assert "
    "(stripped under python -O)")


@dataclasses.dataclass(frozen=True)
class LintPass:
    name: str
    family: str
    rules: Tuple[str, ...]
    fn: Callable
    summary: str


PASSES: Dict[str, LintPass] = {}


def register_pass(name: str, family: str, rules: Tuple[str, ...],
                  summary: str):
    """Decorator: register a pass entry point under the registry."""
    def deco(fn):
        for r in rules:
            if r not in RULES:
                raise ValueError(f"pass {name!r} names unknown rule {r!r}")
        PASSES[name] = LintPass(name, family, tuple(rules), fn, summary)
        return fn
    return deco


def all_rules() -> List[Rule]:
    return [RULES[k] for k in sorted(RULES)]

"""Kernel passes: static validation of the fused mega-kernel's
scalar-prefetched schedule (``kernels/fused_delta.py``).

The fused delta beat's correctness rests on a STATIC contract between
the work descriptor ``sdesc int32[N, 4] = (kind, owner, idx, gather)``
and the BlockSpec index maps: every pane tile / dirty slot / probe slot
is owned by exactly one schedule row, every gather index stays inside
its padded extent, the grid length equals the schedule length, and
every non-owning program's write window parks on the garbage tile so
each real output block has exactly one writer.  These passes re-derive
and verify that contract from the same builders the kernel ships
(``build_schedule`` / ``build_sdesc`` / ``make_out_specs``), evaluating
the REAL index maps against a concrete descriptor — a mutated schedule
(an off-by-one tile, a truncated grid, an out-of-range gather) is
caught before the first beat instead of silently double-writing a
block on device.
"""
from __future__ import annotations

from collections import Counter
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.analysis_static.diagnostics import LintFinding
from repro.analysis_static import registry as R
from repro.analysis_static.registry import register_pass


def geometry_from_lowered(lowered, update_slots=None
                          ) -> Tuple[list, list]:
    """The fused grid geometry a delta beat over ``lowered`` would
    launch with (unsharded row extents): one ``ScanGeom`` per
    predicated scan stage, one ``JoinGeom`` per carried join (block
    joins arrive as single-bucket pseudo-partitions over the full PK
    pane)."""
    from repro.kernels.fused_delta import PANE_TILE, JoinGeom, ScanGeom
    cat = lowered.plan.catalog
    sgeom, jgeom = [], []
    for st in lowered.scans:
        if not st.cols:
            continue
        T = cat.schemas[st.table].capacity
        Rt = min(PANE_TILE, T)
        sgeom.append(ScanGeom(
            C=len(st.cols), Q=st.q_window, A=st.delta_words,
            R=Rt, nt=-(-T // Rt), D=cat.schemas[st.table].dirty_cap))
    for j in lowered.joins:
        if j.kind == "gather":
            continue
        if j.kind == "partitioned":
            B, P = j.bucket_cap, j.n_partitions
        else:
            B, P = cat.schemas[j.pk_table].capacity, 1
        jgeom.append(JoinGeom(B=B, D=cat.schemas[j.spine].dirty_cap,
                              P=P))
    return sgeom, jgeom


def synthesize_sdesc(sgeom, jgeom, schedule=None) -> np.ndarray:
    """A concrete descriptor for static validation: the real
    ``build_sdesc`` over worst-case in-range gathers (dirty rows at the
    far end of each padded extent, probes at the last bucket)."""
    from repro.kernels.fused_delta import build_schedule, build_sdesc
    if schedule is None:
        schedule = build_schedule(sgeom, jgeom)
    scan_rows = [np.full((g.D,), g.nt * g.R - 1, np.int32)
                 for g in sgeom]
    buckets = [np.full((g.D,), g.P - 1, np.int32) for g in jgeom]
    return np.asarray(build_sdesc(schedule, sgeom, jgeom, scan_rows,
                                  buckets))


@register_pass("fused-schedule", "kernel",
               (R.KERNEL_SCHEDULE_COVERAGE, R.KERNEL_GRID_LENGTH),
               "schedule covers every extent exactly once; grid length")
def lint_fused_schedule(sgeom, jgeom, schedule,
                        grid_len: Optional[int] = None,
                        location: str = "fused") -> List[LintFinding]:
    """Every pane tile, dirty slot and probe slot of every owner is
    covered by EXACTLY one schedule row, and the grid is exactly as
    long as the schedule."""
    out = []
    schedule = np.asarray(schedule)
    want_n = (sum(g.nt + g.D for g in sgeom)
              + sum(g.D for g in jgeom))
    if schedule.ndim != 2 or schedule.shape[1] < 3:
        return [LintFinding(
            R.KERNEL_GRID_LENGTH,
            f"schedule shape {schedule.shape} is not [N, >=3]",
            location=location)]
    if schedule.shape[0] != want_n:
        out.append(LintFinding(
            R.KERNEL_GRID_LENGTH,
            f"schedule has {schedule.shape[0]} rows but the geometry "
            f"demands {want_n} grid programs", location=location))
    if grid_len is not None and grid_len != schedule.shape[0]:
        out.append(LintFinding(
            R.KERNEL_GRID_LENGTH,
            f"grid length {grid_len} != schedule length "
            f"{schedule.shape[0]}", location=location))
    extents = {}                 # (kind, owner) -> extent
    from repro.kernels.fused_delta import _DIRTY, _PANE, _PROBE
    for s, g in enumerate(sgeom):
        extents[(_PANE, s)] = g.nt
        extents[(_DIRTY, s)] = g.D
    for j, g in enumerate(jgeom):
        extents[(_PROBE, j)] = g.D
    seen = Counter()
    for kind, owner, idx in schedule[:, :3]:
        key = (int(kind), int(owner))
        if key not in extents:
            out.append(LintFinding(
                R.KERNEL_SCHEDULE_COVERAGE,
                f"schedule row targets unknown (kind, owner) {key}",
                location=location))
            continue
        if not 0 <= int(idx) < extents[key]:
            out.append(LintFinding(
                R.KERNEL_SCHEDULE_COVERAGE,
                f"schedule row (kind {int(kind)}, owner {int(owner)}) "
                f"indexes {int(idx)} outside [0, {extents[key]})",
                location=location))
            continue
        seen[(key, int(idx))] += 1
    for key, extent in extents.items():
        for idx in range(extent):
            n = seen.get((key, idx), 0)
            if n != 1:
                out.append(LintFinding(
                    R.KERNEL_SCHEDULE_COVERAGE,
                    f"(kind {key[0]}, owner {key[1]}) unit {idx} is "
                    f"covered by {n} schedule rows (want exactly 1)",
                    location=location))
    return out


@register_pass("gather-bounds", "kernel", (R.KERNEL_GATHER_BOUNDS,),
               "scalar-prefetch gather indices in bounds")
def lint_gather_bounds(sgeom, jgeom, sdesc,
                       location: str = "fused") -> List[LintFinding]:
    """DIRTY gathers stay inside the padded pane extent (nt * R) and
    PROBE gathers inside the bucket count — the BlockSpec index maps
    DMA exactly these blocks, and an out-of-range index reads (or
    clamps onto) someone else's rows."""
    from repro.kernels.fused_delta import _DIRTY, _PROBE
    out = []
    sdesc = np.asarray(sdesc)
    if sdesc.ndim != 2 or sdesc.shape[1] != 4:
        return [LintFinding(
            R.KERNEL_GATHER_BOUNDS,
            f"descriptor shape {sdesc.shape} is not [N, 4]",
            location=location)]
    for kind, owner, idx, gather in sdesc:
        kind, owner, gather = int(kind), int(owner), int(gather)
        if kind == _DIRTY and 0 <= owner < len(sgeom):
            hi = sgeom[owner].nt * sgeom[owner].R
            if not 0 <= gather < hi:
                out.append(LintFinding(
                    R.KERNEL_GATHER_BOUNDS,
                    f"dirty gather {gather} of scan {owner} escapes "
                    f"[0, {hi})", location=location))
        elif kind == _PROBE and 0 <= owner < len(jgeom):
            if not 0 <= gather < jgeom[owner].P:
                out.append(LintFinding(
                    R.KERNEL_GATHER_BOUNDS,
                    f"probe bucket {gather} of join {owner} escapes "
                    f"[0, {jgeom[owner].P})", location=location))
    return out


def _eval_index_map(spec, i: np.ndarray, sdesc: np.ndarray
                    ) -> Tuple[np.ndarray, ...]:
    """Evaluate a BlockSpec's index map for every grid step at once
    (the maps are elementwise in ``i``)."""
    got = spec.index_map(i, sdesc)
    return tuple(np.asarray(g) for g in got)


@register_pass("garbage-park", "kernel", (R.KERNEL_GARBAGE_PARK,),
               "non-owners park on the garbage tile; one writer/block")
def lint_garbage_park(sgeom, jgeom, sdesc,
                      location: str = "fused") -> List[LintFinding]:
    """Evaluate the SHIPPED output index maps against a concrete
    descriptor: every non-owning grid step must land on the garbage
    block (index ``nt`` for panes, ``D`` for dirty/probe slots), and
    every real block must have exactly one writer."""
    from repro.kernels.fused_delta import (_DIRTY, _PANE, _PROBE,
                                           make_out_specs)
    out = []
    sdesc = np.asarray(sdesc)
    N = sdesc.shape[0]
    i = np.arange(N)
    specs, _shapes = make_out_specs(sgeom, jgeom)
    owners, parks, extents, labels = [], [], [], []
    for s, g in enumerate(sgeom):
        owners.append((_PANE, s))
        parks.append(g.nt)
        extents.append(g.nt)
        labels.append(f"pane[{s}]")
        owners.append((_DIRTY, s))
        parks.append(g.D)
        extents.append(g.D)
        labels.append(f"dirty[{s}]")
    for j, g in enumerate(jgeom):
        owners.append((_PROBE, j))
        parks.append(g.D)
        extents.append(g.D)
        labels.append(f"probe[{j}]")
    for spec, (kind, owner), park, extent, label in zip(
            specs, owners, parks, extents, labels):
        blocks = _eval_index_map(spec, i, sdesc)[0]
        is_owner = (sdesc[:, 0] == kind) & (sdesc[:, 1] == owner)
        stray = np.flatnonzero(~is_owner & (blocks != park))
        if stray.size:
            out.append(LintFinding(
                R.KERNEL_GARBAGE_PARK,
                f"{stray.size} non-owning program(s) of {label} write "
                f"real blocks (e.g. step {int(stray[0])} -> block "
                f"{int(blocks[stray[0]])}, park is {park})",
                location=location))
        writes = Counter(int(b) for b in blocks[is_owner])
        multi = {b: n for b, n in writes.items() if n > 1 and b != park}
        if multi:
            out.append(LintFinding(
                R.KERNEL_GARBAGE_PARK,
                f"real output blocks of {label} with multiple writers: "
                f"{dict(sorted(multi.items()))}", location=location))
        escaped = [b for b in writes if not 0 <= b <= extent]
        if escaped:
            out.append(LintFinding(
                R.KERNEL_GARBAGE_PARK,
                f"owner writes of {label} escape [0, {extent}]: "
                f"{sorted(escaped)}", location=location))
    return out


def run_kernel_passes(lowered, update_slots=None,
                      location: str = "fused") -> List[LintFinding]:
    """The full kernel bundle for a plan's fused delta geometry."""
    from repro.kernels.fused_delta import build_schedule
    sgeom, jgeom = geometry_from_lowered(lowered, update_slots)
    if not sgeom and not jgeom:
        return []
    schedule = build_schedule(sgeom, jgeom)
    sdesc = synthesize_sdesc(sgeom, jgeom, schedule)
    return (lint_fused_schedule(sgeom, jgeom, schedule,
                                grid_len=schedule.shape[0],
                                location=location)
            + lint_gather_bounds(sgeom, jgeom, sdesc, location=location)
            + lint_garbage_park(sgeom, jgeom, sdesc, location=location))

"""Lint diagnostics: findings, severities, and the raise convention.

Every planlint rule reports through a ``LintFinding`` carrying a rule
id, a severity, and a plan location, and every exception a pass raises
embeds ``[planlint:<rule-id>]`` in its message — so runtime rejections
(``FoldError``, the construction-time guards) and CLI output name the
SAME rule, and a test can pin an error to its rule id by substring.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, List, Type

SEVERITIES = ("error", "warning", "info")


class PlanLintError(ValueError):
    """A lint pass found an error-severity violation.

    A ``ValueError`` so existing callers of the guards planlint replaced
    (``lowering.check_extension_prefix``, fold validation) keep
    catching it without change.
    """


@dataclasses.dataclass(frozen=True)
class LintFinding:
    """One diagnostic: ``[planlint:<rule>] <location>: <message>``."""
    rule: str
    message: str
    severity: str = "error"
    location: str = ""            # plan location, e.g. "scan[item]"

    def __post_init__(self):
        if self.severity not in SEVERITIES:
            raise ValueError(f"unknown severity {self.severity!r}")

    def format(self) -> str:
        where = f" {self.location}:" if self.location else ""
        return f"[planlint:{self.rule}]{where} {self.message}"


def errors_in(findings: Iterable[LintFinding]) -> List[LintFinding]:
    return [f for f in findings if f.severity == "error"]


def format_findings(findings: Iterable[LintFinding]) -> str:
    return "\n".join(f.format() for f in findings)


def raise_on_error(findings: Iterable[LintFinding],
                   exc: Type[Exception] = PlanLintError
                   ) -> List[LintFinding]:
    """Raise ``exc`` if any finding is error-severity; else pass the
    findings through (so always-on call sites stay one-liners)."""
    findings = list(findings)
    errs = errors_in(findings)
    if errs:
        raise exc(format_findings(errs))
    return findings

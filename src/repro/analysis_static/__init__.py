"""planlint: static plan / jaxpr / kernel verification for the shared
heartbeat.

SharedDB's value proposition is *predictability*: one always-on plan
whose per-beat cost is bounded by construction.  The invariants that
boundedness rests on — disjoint admission slot ranges, in-window scatter
plans, partition geometry wide enough for the measured key skew,
prefix-stable folds, shard-local delta beats, no full-width compare on
the steady-state path, carries donated exactly once — used to be
enforced piecemeal (runtime guards here, a hand-built jaxpr test
there).  This package turns each of them into a named lint rule that a
single analyzer proves for ANY lowered plan:

  * ``ir_passes``     — structural checks over ``CompiledPlan`` + the
                        staged lowering IR (``LoweredPlan``), including
                        the fold-admission and prefix-stability rules
                        that ``folding.extend_plan`` and
                        ``SharedDBEngine.begin_fold`` route through.
                        Cheap: run always-on at engine construction and
                        at every fold commit.
  * ``jaxpr_passes``  — walk the closed jaxprs of the full/delta/fused
                        beats: collective detector, width classifier,
                        donation/alias checker.
  * ``kernel_passes`` — static validation of the fused mega-kernel's
                        scalar-prefetched schedule (coverage, gather
                        bounds, grid length, garbage-tile parking).
  * ``source_passes`` — ``no-bare-assert``: hot-path modules must guard
                        with real raises, never ``assert`` (stripped
                        under ``python -O``).

``python -m repro.analysis_static.lint`` sweeps workloads x backends x
shard counts and exits non-zero on any error-severity finding; the
seeded mutation corpus under ``tests/lint_corpus/`` proves each rule
actually fires.
"""
from repro.analysis_static.diagnostics import (LintFinding, PlanLintError,
                                               errors_in, format_findings,
                                               raise_on_error)
from repro.analysis_static.registry import RULES, Rule, all_rules

__all__ = [
    "LintFinding", "PlanLintError", "errors_in", "format_findings",
    "raise_on_error", "RULES", "Rule", "all_rules",
]

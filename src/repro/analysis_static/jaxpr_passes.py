"""Jaxpr passes: walk the closed jaxprs of the full/delta/fused beats.

Three analyzers, generalizing the hand-built proofs of
``tests/test_sharding_locality.py`` from one picked configuration to
ANY (plan, backend, shard count):

  * collective detector — a delta beat is shard-local by construction:
    its jaxpr (recursively, through shard_map / cond / pallas_call
    bodies) contains ZERO collective primitives; the full/reseed beat
    contains exactly one ``all_gather`` per mirrored predicated scan
    stage, over that stage's per-shard row slice.
  * width classifier — steady state never pays window width: no
    ``ge``/``le`` range-compare (scan) or full-spine ``eq`` probe
    (join) of a forbidden (rows, q_window) shape is reachable on the
    delta path.  Shapes that a LEGITIMATE kernel also produces (pane
    compares, dirty-row re-evals, key-locate scans) are subtracted
    first; a forbidden shape that collides with a legitimate one is
    reported as an info-severity ambiguity instead of a false error.
  * donation/alias checker — parses the lowered StableHLO's
    ``tf.aliasing_output`` markers to recover which top-level arguments
    actually donate, and flags donation of any argument reachable
    through a non-donated alias (the rid carry doubles as the previous
    beat's in-flight ``results["_join_rids"]`` — the PR-4 bug class).
"""
from __future__ import annotations

import re
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

import jax
import jax.core as jcore

from repro.analysis_static.diagnostics import LintFinding
from repro.analysis_static import registry as R
from repro.analysis_static.registry import register_pass

COLLECTIVES = {"all_gather", "psum", "ppermute", "all_to_all", "pgather",
               "reduce_scatter", "pmax", "pmin", "pargmax", "pargmin",
               "pbroadcast"}
HLO_COLLECTIVES = ("all-reduce", "all-gather", "collective-permute",
                   "all-to-all", "reduce-scatter", "collective-broadcast")


def walk_eqns(closed):
    """Yield every eqn in a closed jaxpr, recursing into sub-jaxprs
    (shard_map / scan / cond / pallas_call bodies)."""
    def walk(jx):
        for e in jx.eqns:
            yield e
            for v in e.params.values():
                vs = v if isinstance(v, (list, tuple)) else (v,)
                for w in vs:
                    if isinstance(w, jcore.ClosedJaxpr):
                        yield from walk(w.jaxpr)
                    elif isinstance(w, jcore.Jaxpr):
                        yield from walk(w)
    yield from walk(closed.jaxpr)


def collective_names(closed) -> Set[str]:
    return {e.primitive.name for e in walk_eqns(closed)} & COLLECTIVES


# ---------------------------------------------------------------------------
# Collective detector
# ---------------------------------------------------------------------------


@register_pass("delta-collectives", "jaxpr", (R.JAXPR_DELTA_COLLECTIVE,),
               "delta beats contain zero collective primitives")
def lint_delta_collectives(closed, location: str = "delta"
                           ) -> List[LintFinding]:
    hits = collective_names(closed)
    if hits:
        return [LintFinding(
            R.JAXPR_DELTA_COLLECTIVE,
            f"collective primitives on the delta path: {sorted(hits)} "
            "— delta beats must be shard-local", location=location)]
    return []


def lint_delta_hlo(hlo_text: str, location: str = "delta"
                   ) -> List[LintFinding]:
    """Same proof on the OPTIMIZED compiled HLO (GSPMD must not have
    added a collective behind the jaxpr's back)."""
    hits = [t for t in HLO_COLLECTIVES if t in hlo_text]
    if hits:
        return [LintFinding(
            R.JAXPR_DELTA_COLLECTIVE,
            f"collective instructions in the compiled delta HLO: {hits}",
            location=location)]
    return []


@register_pass("reseed-collectives", "jaxpr", (R.JAXPR_RESEED_COLLECTIVE,),
               "reseed = one all_gather per mirrored predicated stage")
def lint_reseed_collectives(closed, lowered, spec,
                            location: str = "full") -> List[LintFinding]:
    """The full/reseed beat's only collective is one ``all_gather`` per
    mirrored predicated scan stage, each gathering that stage's
    per-shard row slice — the rescan touched every shard exactly once
    before re-assembly."""
    out = []
    names = collective_names(closed)
    mi_pred = [st for st in lowered.scans
               if spec.is_mirrored(st.table) and st.cols]
    if names - {"all_gather"}:
        out.append(LintFinding(
            R.JAXPR_RESEED_COLLECTIVE,
            f"unexpected collectives on the reseed path: "
            f"{sorted(names - {'all_gather'})}", location=location))
    gathers = [e for e in walk_eqns(closed)
               if e.primitive.name == "all_gather"]
    if len(gathers) != len(mi_pred):
        out.append(LintFinding(
            R.JAXPR_RESEED_COLLECTIVE,
            f"{len(gathers)} all_gathers != {len(mi_pred)} mirrored "
            "predicated scan stages", location=location))
        return out
    got = sorted(tuple(e.invars[0].aval.shape) for e in gathers)
    want = sorted((spec.shard_rows[st.table], st.whi - st.wlo)
                  for st in mi_pred)
    if got != want:
        out.append(LintFinding(
            R.JAXPR_RESEED_COLLECTIVE,
            f"all_gather operand shapes {got} != per-shard stage "
            f"slices {want}", location=location))
    return out


# ---------------------------------------------------------------------------
# Width classifier
# ---------------------------------------------------------------------------


def _row_candidates(lowered, table: str, spec=None) -> Set[int]:
    """Row extents a compare over ``table`` could legitimately carry:
    the schema capacity, and under a mesh the padded / per-shard
    extents."""
    cap = lowered.plan.catalog.schemas[table].capacity
    cands = {cap}
    if spec is not None:
        cands.add(spec.padded.get(table, cap))
        cands.add(spec.shard_rows.get(table, cap))
    return cands


def _width_shape_sets(lowered, spec=None
                      ) -> Tuple[Dict[Tuple[int, int], str],
                                 Set[Tuple[int, int]]]:
    """(forbidden shapes -> stage location, legitimate shapes).

    Forbidden: a range compare at (table rows, FULL stage q_window) for
    any stage whose pane is narrower than its window — the full-rescan
    work shape, unreachable from a delta beat.  Legitimate: admission
    pane compares (rows, 32*delta_words), single-row / dirty-set
    re-evals, and the storage update path's key-locate scans.  A
    forbidden shape also in the legitimate set cannot be classified
    statically and is skipped (reported as info by the caller).
    """
    cat = lowered.plan.catalog
    legit: Set[Tuple[int, int]] = set()
    for st in lowered.scans:
        if not st.cols:
            continue
        pane = 32 * st.delta_words
        for rows in _row_candidates(lowered, st.table, spec):
            legit.add((rows, pane))
        dirty = cat.schemas[st.table].dirty_cap
        legit.add((dirty, st.q_window))      # chained dirty re-eval
        legit.add((1, st.q_window))          # fused DIRTY program row
    forbidden: Dict[Tuple[int, int], str] = {}
    for st in lowered.scans:
        if not st.cols or 32 * st.delta_words >= st.q_window:
            continue                          # pane IS the window: exempt
        for rows in _row_candidates(lowered, st.table, spec):
            forbidden[(rows, st.q_window)] = f"scan[{st.table}]"
    return forbidden, legit


def _probe_shape_sets(lowered, spec=None, update_slots=None
                      ) -> Tuple[Dict[Tuple[int, int], str],
                                 Set[Tuple[int, int]]]:
    """Same split for join probes on the delta-join path: a full-probe
    ``eq`` pane is (spine rows, bucket width); the delta path probes
    only (dirty rows, one bucket).  The storage update path's
    key-locate scans on index-less PK tables ((update slots, table
    rows) ``eq``s) run on EVERY beat and are legitimate."""
    cat = lowered.plan.catalog
    legit: Set[Tuple[int, int]] = set()
    forbidden: Dict[Tuple[int, int], str] = {}
    if update_slots is not None:
        for t, schema in cat.schemas.items():
            if schema.pk and not schema.indexed:
                for rows in _row_candidates(lowered, t, spec):
                    legit.add((update_slots.n_update, rows))
                    legit.add((update_slots.n_delete, rows))
    for j in lowered.joins:
        if j.kind == "gather":
            continue
        spine_rows = _row_candidates(lowered, j.spine, spec)
        dirty = cat.schemas[j.spine].dirty_cap
        if j.kind == "partitioned":
            widths = {j.bucket_cap}
        else:                                 # block: full PK pane
            widths = _row_candidates(lowered, j.pk_table, spec)
        for w in widths:
            legit.add((dirty, w))            # chained delta probe
            legit.add((1, w))                # fused PROBE program row
            for rows in spine_rows:
                forbidden[(rows, w)] = f"join[{j.spine}->{j.pk_table}]"
    return forbidden, legit


@register_pass("delta-width", "jaxpr", (R.JAXPR_DELTA_WIDTH,),
               "no full-window compare/probe on the delta path")
def lint_delta_width(closed, lowered, spec=None, *,
                     delta_joins: bool = False, update_slots=None,
                     location: str = "delta") -> List[LintFinding]:
    """No full-window range compare (and, on the delta-join flavour, no
    full-spine probe) is reachable on the delta path."""
    out = []
    forbidden, legit = _width_shape_sets(lowered, spec)
    prims = {"ge", "le"}
    if delta_joins:
        pf, pl_ = _probe_shape_sets(lowered, spec, update_slots)
        for shape, loc in pf.items():
            forbidden.setdefault(shape, loc)
        legit |= pl_
        prims.add("eq")
    ambiguous = set(forbidden) & legit
    for shape in sorted(ambiguous):
        out.append(LintFinding(
            R.JAXPR_DELTA_WIDTH,
            f"shape {shape} is both a full-window and a legitimate "
            "delta compare at this scale — not statically classifiable",
            severity="info", location=forbidden[shape]))
    check = {s: loc for s, loc in forbidden.items()
             if s not in ambiguous}
    hits: Dict[Tuple[int, int], int] = {}
    for e in walk_eqns(closed):
        if e.primitive.name not in prims:
            continue
        shape = tuple(e.outvars[0].aval.shape)
        if len(shape) == 2 and shape in check:
            hits[shape] = hits.get(shape, 0) + 1
    for shape, n in sorted(hits.items()):
        out.append(LintFinding(
            R.JAXPR_DELTA_WIDTH,
            f"{n} full-window compare(s) of shape {shape} reachable "
            "on the delta path", location=f"{location} {check[shape]}"))
    return out


# ---------------------------------------------------------------------------
# Donation / alias checker
# ---------------------------------------------------------------------------

_ALIAS_RE = re.compile(r"%arg(\d+):[^%]*?tf\.aliasing_output")


def donated_leaf_args(fn, args: Sequence, donate_argnums: Iterable[int]
                      ) -> Set[int]:
    """Flat (leaf) argument indices the lowered StableHLO actually
    marks as donated (``tf.aliasing_output``)."""
    import warnings
    j = jax.jit(fn, donate_argnums=tuple(donate_argnums))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        txt = j.lower(*args).as_text()
    return {int(m.group(1)) for m in _ALIAS_RE.finditer(txt)}


def _arg_of_leaf(args: Sequence, leaf_idx: int) -> int:
    """Top-level positional argument owning flat leaf ``leaf_idx``."""
    bound = 0
    for i, a in enumerate(args):
        bound += len(jax.tree_util.tree_leaves(a))
        if leaf_idx < bound:
            return i
    return len(args) - 1


@register_pass("donation-alias", "jaxpr", (R.JAXPR_DONATED_ALIAS,),
               "donated buffers unreachable through non-donated aliases")
def lint_donation(fn, args: Sequence, donate_argnums: Sequence[int],
                  aliased_args: Dict[int, str],
                  location: str = "") -> List[LintFinding]:
    """Use-after-donate detector.

    ``aliased_args`` names the top-level arguments whose buffers are
    reachable through OTHER live references — the rid carry (aliases
    the previous beat's in-flight ``results["_join_rids"]``) and the
    staged query/update buffers (reused across pipeline slots).
    Donating any of their leaves frees a buffer something else still
    reads — the DECLARATION is the hazard (whether a given lowering
    materializes the alias is backend luck), so aliased donations are
    flagged from ``donate_argnums`` itself.  Also flags declared
    donations the lowering dropped entirely (warning: the in-place
    carry roll-forward silently degraded to a copy)."""
    out = []
    declared = set(donate_argnums)
    donated = donated_leaf_args(fn, args, donate_argnums)
    donated_top = {_arg_of_leaf(args, leaf) for leaf in donated}
    for argnum in sorted(declared & set(aliased_args)):
        out.append(LintFinding(
            R.JAXPR_DONATED_ALIAS,
            f"argument {argnum} ({aliased_args[argnum]}) is donated "
            "but reachable through a non-donated alias — "
            "use-after-donate", location=location))
    for argnum in sorted(declared - donated_top - set(aliased_args)):
        if len(jax.tree_util.tree_leaves(args[argnum])) == 0:
            continue
        out.append(LintFinding(
            R.JAXPR_DONATED_ALIAS,
            f"declared donation of argument {argnum} was dropped by "
            "the lowering (carry roll-forward degraded to a copy)",
            severity="warning", location=location))
    return out

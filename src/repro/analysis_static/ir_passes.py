"""IR passes: structural lint over ``CompiledPlan`` + the staged
lowering IR (``LoweredPlan``).

These are the cheap, always-on passes: ``SharedDBEngine._build_compiled``
runs ``run_construction_passes`` on every generation it lowers (cold
start AND every background fold build), and ``folding.extend_plan`` /
``begin_fold`` route fold admission through the ``lint_fold_*`` passes
— the single source of truth the old private ad-hoc checks collapsed
into.
"""
from __future__ import annotations

from typing import Dict, List, Optional

from repro.analysis_static.diagnostics import LintFinding
from repro.analysis_static import registry as R
from repro.analysis_static.registry import register_pass


# ---------------------------------------------------------------------------
# Plan-level: admission slot layout
# ---------------------------------------------------------------------------


@register_pass("slot-layout", "ir",
               (R.IR_SLOT_OVERLAP, R.IR_SLOT_COVERAGE),
               "slot-range disjointness and qcap coverage")
def lint_slot_layout(plan) -> List[LintFinding]:
    """Template slot ranges: positive caps, inside qcap, disjoint."""
    out = []
    if plan.qcap <= 0 or plan.qcap % 32:
        out.append(LintFinding(
            R.IR_SLOT_COVERAGE,
            f"qcap {plan.qcap} is not a positive multiple of 32"))
    missing = set(plan.templates) ^ set(plan.offsets)
    missing |= set(plan.templates) ^ set(plan.caps)
    if missing:
        out.append(LintFinding(
            R.IR_SLOT_COVERAGE,
            f"templates without slot ranges (or vice versa): "
            f"{sorted(missing)}"))
        return out
    ranges = sorted((plan.offsets[n], plan.caps[n], n)
                    for n in plan.templates)
    prev_end, prev_name = 0, None
    for off, cap, name in ranges:
        loc = f"template[{name}]"
        if cap < 1:
            out.append(LintFinding(
                R.IR_SLOT_COVERAGE, f"slot capacity {cap} < 1",
                location=loc))
        if off < 0 or off + cap > plan.qcap:
            out.append(LintFinding(
                R.IR_SLOT_COVERAGE,
                f"slot range [{off}, {off + cap}) escapes qcap "
                f"{plan.qcap}", location=loc))
        if off < prev_end:
            out.append(LintFinding(
                R.IR_SLOT_OVERLAP,
                f"slot range [{off}, {off + cap}) overlaps "
                f"{prev_name!r} (ends at {prev_end})", location=loc))
        if off + cap > prev_end:
            prev_end, prev_name = off + cap, name
    return out


# ---------------------------------------------------------------------------
# IR-level: per-stage windows, masks, scatter plans
# ---------------------------------------------------------------------------


def _lint_slots_in_window(slots, q_window: int, loc: str
                          ) -> List[LintFinding]:
    out = []
    for name, off, cap in slots:
        if off < 0 or off + cap > q_window:
            out.append(LintFinding(
                R.IR_WORD_WINDOW,
                f"slot range of {name!r} ([{off}, {off + cap})) escapes "
                f"the stage window ({q_window} slots)", location=loc))
    return out


@register_pass("word-windows", "ir", (R.IR_WORD_WINDOW,),
               "per-stage word-window / mask / scatter-plan bounds")
def lint_word_windows(lowered) -> List[LintFinding]:
    """Every stage's word window, subscriber mask and predicate scatter
    plan stays inside the global [0, W) mask and its own window."""
    out = []
    W = lowered.W
    for st in lowered.scans:
        loc = f"scan[{st.table}]"
        if not (0 <= st.wlo <= st.whi <= W):
            out.append(LintFinding(
                R.IR_WORD_WINDOW,
                f"word window [{st.wlo}, {st.whi}) escapes [0, {W})",
                location=loc))
            continue
        qw = st.q_window
        if st.covered.shape != (qw,):
            out.append(LintFinding(
                R.IR_WORD_WINDOW,
                f"covered mask shape {st.covered.shape} != ({qw},)",
                location=loc))
        want = (max(len(st.cols), 1), qw)
        if st.param_idx.shape != want:
            out.append(LintFinding(
                R.IR_WORD_WINDOW,
                f"param_idx shape {st.param_idx.shape} != {want}",
                location=loc))
        elif st.param_idx.size and (
                st.param_idx.min() < -1
                or st.param_idx.max() >= lowered.n_params_max):
            out.append(LintFinding(
                R.IR_WORD_WINDOW,
                f"param_idx values escape [-1, {lowered.n_params_max})",
                location=loc))
        if st.cols and not (1 <= st.delta_words <= st.whi - st.wlo):
            out.append(LintFinding(
                R.IR_WORD_WINDOW,
                f"delta pane ({st.delta_words} words) escapes the "
                f"window ({st.whi - st.wlo} words)", location=loc))
        out += _lint_slots_in_window(st.slots, qw, loc)
        if st.covered.shape == (qw,):
            for name, off, cap in st.slots:
                if 0 <= off and off + cap <= qw \
                        and not st.covered[off:off + cap].all():
                    out.append(LintFinding(
                        R.IR_WORD_WINDOW,
                        f"slots of {name!r} not marked covered",
                        location=loc))
    for j in lowered.joins:
        loc = f"join[{j.spine}->{j.pk_table}]"
        if j.sub_mask.shape != (W,):
            out.append(LintFinding(
                R.IR_WORD_WINDOW,
                f"subscriber mask shape {j.sub_mask.shape} != ({W},)",
                location=loc))
    for kind, st in list(lowered.stages())[len(lowered.scans)
                                           + len(lowered.joins):]:
        loc = f"{kind}[{st.spine}]"
        if not (0 <= st.wlo <= st.whi <= W):
            out.append(LintFinding(
                R.IR_WORD_WINDOW,
                f"word window [{st.wlo}, {st.whi}) escapes [0, {W})",
                location=loc))
            continue
        if hasattr(st, "sub_mask") and \
                st.sub_mask.shape != (st.whi - st.wlo,):
            out.append(LintFinding(
                R.IR_WORD_WINDOW,
                f"window-local mask shape {st.sub_mask.shape} != "
                f"({st.whi - st.wlo},)", location=loc))
        if st.union_cap < 1:
            out.append(LintFinding(
                R.IR_WORD_WINDOW, f"union cap {st.union_cap} < 1",
                location=loc))
        out += _lint_slots_in_window(st.slots, (st.whi - st.wlo) * 32,
                                     loc)
    if lowered.limits.shape != (lowered.qcap,):
        out.append(LintFinding(
            R.IR_WORD_WINDOW,
            f"limits shape {lowered.limits.shape} != ({lowered.qcap},)"))
    elif lowered.limits.size and (
            lowered.limits.min() < 1
            or lowered.limits.max() > lowered.plan.max_results):
        out.append(LintFinding(
            R.IR_WORD_WINDOW,
            f"per-slot limits escape [1, {lowered.plan.max_results}]"))
    return out


@register_pass("partition-geometry", "ir", (R.IR_PARTITION_GEOMETRY,),
               "bucket geometry vs capacity and measured key skew")
def lint_partition_geometry(lowered,
                            key_stats: Optional[Dict] = None
                            ) -> List[LintFinding]:
    """Partitioned joins: buckets must cover the PK capacity, and under
    measured ``key_stats`` the bucket width must hold the widest
    duplicate run AND reproduce ``partition_layout`` exactly (the
    carried partitions remap across folds only if geometry is a pure
    function of (capacity, stats))."""
    from repro.core.lowering import partition_layout
    out = []
    cat = lowered.plan.catalog
    for j in lowered.joins:
        loc = f"join[{j.spine}->{j.pk_table}]"
        cap = cat.schemas[j.pk_table].capacity
        if j.kind != "partitioned":
            if (j.n_partitions, j.bucket_cap) != (0, 0):
                out.append(LintFinding(
                    R.IR_PARTITION_GEOMETRY,
                    f"{j.kind} join carries partition geometry "
                    f"({j.n_partitions}x{j.bucket_cap})", location=loc))
            continue
        if j.n_partitions < 1 or j.bucket_cap < 1:
            out.append(LintFinding(
                R.IR_PARTITION_GEOMETRY,
                f"degenerate geometry {j.n_partitions}x{j.bucket_cap}",
                location=loc))
            continue
        if j.n_partitions * j.bucket_cap < cap:
            out.append(LintFinding(
                R.IR_PARTITION_GEOMETRY,
                f"partition capacity {j.n_partitions}x{j.bucket_cap} "
                f"= {j.n_partitions * j.bucket_cap} < table capacity "
                f"{cap} (build_key_partitions would overflow)",
                location=loc))
        if key_stats is not None:
            stats = key_stats.get(j.pk_table)
            if stats and j.bucket_cap < int(stats.get("max_dup", 1)):
                out.append(LintFinding(
                    R.IR_PARTITION_GEOMETRY,
                    f"bucket capacity {j.bucket_cap} < measured widest "
                    f"duplicate run {stats['max_dup']}", location=loc))
            want = partition_layout(cap, stats)
            if (j.n_partitions, j.bucket_cap) != want:
                out.append(LintFinding(
                    R.IR_PARTITION_GEOMETRY,
                    f"geometry {j.n_partitions}x{j.bucket_cap} != "
                    f"partition_layout{want} for the measured stats "
                    "(folds could not remap carried partitions)",
                    location=loc))
    return out


def run_construction_passes(lowered, key_stats: Optional[Dict] = None
                            ) -> List[LintFinding]:
    """The always-on bundle: raise ``PlanLintError`` on any error."""
    from repro.analysis_static.diagnostics import raise_on_error
    findings = (lint_slot_layout(lowered.plan)
                + lint_word_windows(lowered)
                + lint_partition_geometry(lowered, key_stats))
    return raise_on_error(findings)


# ---------------------------------------------------------------------------
# Fold admission passes (folding.extend_plan / begin_fold route here)
# ---------------------------------------------------------------------------


@register_pass("fold-batch", "fold",
               (R.FOLD_DUPLICATE_TEMPLATE, R.FOLD_DUPLICATE_IN_BATCH,
                R.FOLD_ZERO_CAP, R.FOLD_ALIEN_TABLE,
                R.FOLD_UNKNOWN_COLUMN),
               "fold-batch admission: names, caps, referenced schema")
def lint_fold_batch(plan, new_templates, new_caps) -> List[LintFinding]:
    out = []
    for t in new_templates:
        loc = f"template[{t.name}]"
        if t.name in plan.templates:
            out.append(LintFinding(
                R.FOLD_DUPLICATE_TEMPLATE,
                f"template {t.name!r} already in the plan",
                location=loc))
        if t.name not in new_caps or new_caps[t.name] < 1:
            out.append(LintFinding(
                R.FOLD_ZERO_CAP,
                f"template {t.name!r} needs a positive cap "
                f"(got {new_caps.get(t.name)!r})", location=loc))
        for table in t.tables():
            if table not in plan.catalog.schemas:
                out.append(LintFinding(
                    R.FOLD_ALIEN_TABLE,
                    f"template {t.name!r} references unknown table "
                    f"{table!r} — folding admits new query shapes, not "
                    "new tables", location=loc))
        for p in t.preds:
            if p.table not in plan.catalog.schemas or \
                    p.col not in plan.catalog.schemas[p.table].columns:
                out.append(LintFinding(
                    R.FOLD_UNKNOWN_COLUMN,
                    f"template {t.name!r} predicate on unknown column "
                    f"{p.table}.{p.col}", location=loc))
    names = [t.name for t in new_templates]
    if len(set(names)) != len(names):
        dupes = sorted({n for n in names if names.count(n) > 1})
        out.append(LintFinding(
            R.FOLD_DUPLICATE_IN_BATCH,
            f"duplicate template names in the fold batch: {dupes}"))
    return out


@register_pass("plan-prefix", "fold", (R.FOLD_PLAN_PREFIX,),
               "plan-level prefix stability of an extension")
def lint_plan_prefix(old, new) -> List[LintFinding]:
    """Prefix stability at the PLAN level (the IR level is re-proved by
    ``lint_extension_prefix`` after the extended plan lowers)."""
    out = []

    def bad(msg):
        out.append(LintFinding(R.FOLD_PLAN_PREFIX, msg))

    for name in old.templates:
        if new.offsets.get(name) != old.offsets[name] or \
                new.caps.get(name) != old.caps[name]:
            bad(f"slot range of existing template {name!r} moved "
                f"({old.offsets[name]}+{old.caps[name]} -> "
                f"{new.offsets.get(name)}+{new.caps.get(name)})")
    if new.qcap < old.qcap:
        bad(f"qcap shrank ({old.qcap} -> {new.qcap})")
    old_scan_keys = list(old.scans)
    if list(new.scans)[:len(old_scan_keys)] != old_scan_keys:
        bad("scan node order changed")
    else:
        for table in old_scan_keys:
            oc, nc = old.scans[table].cols, new.scans[table].cols
            if tuple(nc[:len(oc)]) != tuple(oc):
                bad(f"scan {table!r} columns reordered")
    ok = [(j.spine, j.fk_col, j.pk_table) for j in old.joins]
    if [(j.spine, j.fk_col, j.pk_table)
            for j in new.joins[:len(ok)]] != ok:
        bad("join node order changed")
    osk = [(s.spine, s.col, s.desc) for s in old.sorts]
    if [(s.spine, s.col, s.desc) for s in new.sorts[:len(osk)]] != osk:
        bad("sort node order changed")
    ogk = [(g.spine, g.agg.group_col, g.agg.agg_col) for g in old.groups]
    if [(g.spine, g.agg.group_col, g.agg.agg_col)
            for g in new.groups[:len(ogk)]] != ogk:
        bad("group node order changed")
    return out


@register_pass("extension-prefix", "fold", (R.FOLD_PREFIX_STABILITY,),
               "IR-level prefix stability of an extension")
def lint_extension_prefix(old, new) -> List[LintFinding]:
    """Prefix stability re-proved on the LOWERED IR — the contract
    carry migration (``folding.migrate_carry``) rests on.  Every
    derivation ``lower_plan`` makes for an appended-template extension
    (stage positions fixed, windows widen high-side only, predicate
    columns append, join access paths frozen) becomes a hard finding."""
    out = []

    def bad(what):
        out.append(LintFinding(
            R.FOLD_PREFIX_STABILITY,
            f"plan extension is not prefix-stable: {what} — the fold "
            "cannot migrate carries into this layout"))

    if new.qcap < old.qcap or new.n_params_max < old.n_params_max:
        bad(f"global capacity shrank (qcap {old.qcap}->{new.qcap}, "
            f"P_max {old.n_params_max}->{new.n_params_max})")
    if len(new.scans) < len(old.scans):
        bad("scan stage list shrank")
    for os_, ns in zip(old.scans, new.scans):
        if ns.table != os_.table:
            bad(f"scan stage order changed ({os_.table} -> {ns.table})")
        if ns.wlo != os_.wlo or ns.whi < os_.whi:
            bad(f"scan window of {os_.table} moved "
                f"([{os_.wlo},{os_.whi}) -> [{ns.wlo},{ns.whi}))")
        if tuple(ns.cols[:len(os_.cols)]) != tuple(os_.cols):
            bad(f"predicated columns of {os_.table} reordered "
                f"({os_.cols} -> {ns.cols})")
    if [j.key for j in new.joins[:len(old.joins)]] != \
            [j.key for j in old.joins]:
        bad("join stage order changed")
    for oj, nj in zip(old.joins, new.joins):
        if (nj.kind, nj.n_partitions, nj.bucket_cap) != \
                (oj.kind, oj.n_partitions, oj.bucket_cap):
            bad(f"join {oj.key} access path changed "
                f"({oj.kind} -> {nj.kind})")
    old_sorts = [(s.spine, s.col, s.desc) for s in old.sorts]
    if [(s.spine, s.col, s.desc) for s in new.sorts[:len(old_sorts)]] \
            != old_sorts:
        bad("sort stage order changed")
    old_groups = [(g.spine, g.agg.group_col, g.agg.agg_col)
                  for g in old.groups]
    if [(g.spine, g.agg.group_col, g.agg.agg_col)
            for g in new.groups[:len(old_groups)]] != old_groups:
        bad("group stage order changed")
    if [r.spine for r in new.routes[:len(old.routes)]] != \
            [r.spine for r in old.routes]:
        bad("route stage order changed")
    return out


@register_pass("fold-mirrors", "fold", (R.FOLD_MIRROR_SET,),
               "mesh folds keep the mirrored table set fixed")
def lint_fold_mirrors(old_plan, new_plan) -> List[LintFinding]:
    """A fold under a mesh must keep the sharded STATE layout fixed:
    the mirrored (replicated probe side) table set is decided by join
    membership, and flipping a table would demand a cross-shard state
    migration mid-serve."""
    old_m = {j.pk_table for j in old_plan.joins}
    new_m = {j.pk_table for j in new_plan.joins}
    if old_m != new_m:
        return [LintFinding(
            R.FOLD_MIRROR_SET,
            "fold under a mesh would change the mirrored table set "
            f"({sorted(old_m ^ new_m)}) — the sharded state layout is "
            "fixed at startup; register templates whose joins target "
            "already-mirrored PK tables, or restart to re-shard")]
    return []

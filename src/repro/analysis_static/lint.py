"""planlint CLI: prove heartbeat invariants before the first beat.

    python -m repro.analysis_static.lint                       # full sweep
    python -m repro.analysis_static.lint --rules               # rule table
    python -m repro.analysis_static.lint --workloads tpcw \\
        --backends jnp,pallas --shards 1,2,4                   # CI leg

Sweeps workload plans x operator backends x shard counts and runs every
pass family against the REAL lowered plan and the REAL traced cycle
flavours — nothing executes on device (full beats are shape-evaluated,
delta beats are traced to jaxprs), so the whole sweep is tracing cost
only.  Exit status 1 iff any error-severity finding survives.
"""
from __future__ import annotations

import os


def _force_cpu_mesh() -> None:
    """Give the sweep 8 host devices BEFORE jax initializes (same trick
    as tests/conftest.py), so the sharded legs have a mesh to lint."""
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + " --xla_force_host_platform_device_count=8").strip()


_force_cpu_mesh()

import argparse  # noqa: E402
import sys  # noqa: E402
from typing import List  # noqa: E402

import numpy as np  # noqa: E402

import jax  # noqa: E402

from repro.analysis_static.diagnostics import (LintFinding, errors_in,  # noqa: E402
                                               format_findings)
from repro.analysis_static import ir_passes, jaxpr_passes  # noqa: E402
from repro.analysis_static import kernel_passes, source_passes  # noqa: E402
from repro.analysis_static.registry import PASSES, all_rules  # noqa: E402

WORKLOADS = ("tpcw", "tpcw-nopk")


def _struct(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(np.shape(x), np.asarray(x).dtype)
        if not isinstance(x, jax.ShapeDtypeStruct) else x, tree)


def _build_plan(workload: str, scale_i: int, scale_c: int):
    from repro.workloads import tpcw
    plan = tpcw.build_tpcw_plan(
        scale_i, scale_c, dense_pk_index=(workload == "tpcw"))
    data = tpcw.generate_data(np.random.default_rng(0), scale_i, scale_c)
    return plan, data


def lint_config(workload: str, backend_name: str, n_shards: int,
                scale_i: int, scale_c: int) -> List[LintFinding]:
    """All pass families against one (workload, backend, shards) cell."""
    import repro.kernels  # noqa: F401  (registers the pallas backend)
    from repro.core import backends
    from repro.core.executor import DONATION_SPEC, _measure_key_stats
    from repro.core.lowering import (build_cycle, build_delta_cycle,
                                     lower_plan)
    from repro.core.storage import empty_update_batch
    from repro.workloads import tpcw

    cfg = f"{workload}/{backend_name}/shards={n_shards or 'off'}"
    plan, data = _build_plan(workload, scale_i, scale_c)
    key_stats = _measure_key_stats(plan, data)
    lowered = lower_plan(plan, key_stats=key_stats)

    # ---- IR family (the always-on bundle, here surfaced as findings)
    findings = (ir_passes.lint_slot_layout(plan)
                + ir_passes.lint_word_windows(lowered)
                + ir_passes.lint_partition_geometry(lowered, key_stats))

    # ---- kernel family (fused-delta grid geometry; backend-independent)
    findings += kernel_passes.run_kernel_passes(lowered, location=cfg)

    # ---- build the three cycle flavours exactly as the executor does
    be = backends.get_backend(backend_name)
    spec = None
    if n_shards:
        from repro.core.sharding import (build_shard_spec,
                                         build_sharded_cycle,
                                         build_sharded_delta_cycle,
                                         init_sharded_state,
                                         make_row_mesh)
        if jax.device_count() < n_shards:
            findings.append(LintFinding(
                jaxpr_passes.R.JAXPR_DELTA_COLLECTIVE,
                f"skipped: {n_shards} shards > {jax.device_count()} "
                "devices", severity="warning", location=cfg))
            return findings
        mesh = make_row_mesh(n_shards)
        spec = build_shard_spec(plan, mesh)
        full = build_sharded_cycle(lowered, be, spec)
        delta = build_sharded_delta_cycle(lowered, be, spec)
        delta_j = build_sharded_delta_cycle(lowered, be, spec,
                                            delta_joins=True)
        state = init_sharded_state(spec, data)
    else:
        full = build_cycle(lowered, be)
        delta = build_delta_cycle(lowered, be)
        delta_j = build_delta_cycle(lowered, be, delta_joins=True)
        state = plan.catalog.init_state(data)

    slots = tpcw.DEFAULT_UPDATE_SLOTS
    queries = {
        "params": np.zeros((plan.qcap, plan.n_params_max, 2), np.int32),
        "active": np.zeros((plan.qcap,), bool)}
    updates = {t: empty_update_batch(s, slots, xp=np)
               for t, s in plan.catalog.schemas.items()}
    state_s, queries_s, updates_s = map(_struct,
                                        (state, queries, updates))

    # shape-evaluate the full beat (no execution) to recover the carry
    # and results layouts the delta flavours consume
    state2_s, carry_s, results_s = jax.eval_shape(full, state_s,
                                                  queries_s, updates_s)
    queries_d = dict(queries_s,
                     changed=jax.ShapeDtypeStruct((plan.qcap,), bool))
    args_full = (state_s, queries_s, updates_s)
    args_delta = (state2_s, carry_s, queries_d, updates_s)
    args_dj = (state2_s, carry_s, results_s["_join_rids"], queries_d,
               updates_s)

    # ---- jaxpr family: collectives + width, per delta flavour
    jd = jax.make_jaxpr(delta)(*args_delta)
    jdj = jax.make_jaxpr(delta_j)(*args_dj)
    findings += jaxpr_passes.lint_delta_collectives(
        jd, location=f"{cfg} delta")
    findings += jaxpr_passes.lint_delta_collectives(
        jdj, location=f"{cfg} delta_join")
    findings += jaxpr_passes.lint_delta_width(
        jd, lowered, spec, location=f"{cfg} delta")
    findings += jaxpr_passes.lint_delta_width(
        jdj, lowered, spec, delta_joins=True, update_slots=slots,
        location=f"{cfg} delta_join")
    if spec is not None:
        jf = jax.make_jaxpr(full)(*args_full)
        findings += jaxpr_passes.lint_reseed_collectives(
            jf, lowered, spec, location=f"{cfg} full")

    # ---- donation contract: the executor's shipped spec against the
    # aliasing the lowering actually emits
    aliased = {
        "full": {1: "staged queries", 2: "staged updates"},
        "delta": {2: "staged queries", 3: "staged updates"},
        "delta_join": {2: "rid carry (aliases the previous beat's "
                          "in-flight results)",
                       3: "staged queries", 4: "staged updates"}}
    for flavour, fn, args in (("full", full, args_full),
                              ("delta", delta, args_delta),
                              ("delta_join", delta_j, args_dj)):
        findings += jaxpr_passes.lint_donation(
            fn, args, DONATION_SPEC[flavour], aliased[flavour],
            location=f"{cfg} {flavour}")
    return findings


def _print_rules() -> None:
    print(f"{'rule id':<26} {'family':<7} summary")
    for r in all_rules():
        print(f"{r.id:<26} {r.family:<7} {r.summary}")
    print(f"\n{len(all_rules())} rules across "
          f"{len(PASSES)} registered passes")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis_static.lint",
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--workloads", default=",".join(WORKLOADS),
                    help="comma list from: " + ", ".join(WORKLOADS))
    ap.add_argument("--backends", default="jnp,pallas")
    ap.add_argument("--shards", default="0,1,2,4",
                    help="comma list of shard counts (0 = unsharded)")
    ap.add_argument("--scale-items", type=int, default=64)
    ap.add_argument("--scale-customers", type=int, default=128)
    ap.add_argument("--rules", action="store_true",
                    help="print the rule table and exit")
    ap.add_argument("-v", "--verbose", action="store_true",
                    help="also print warning/info findings")
    args = ap.parse_args(argv)
    if args.rules:
        _print_rules()
        return 0

    all_findings: List[LintFinding] = source_passes.lint_hot_path_asserts()
    configs = [(w, b, int(s))
               for w in args.workloads.split(",")
               for b in args.backends.split(",")
               for s in args.shards.split(",")]
    for w, b, s in configs:
        findings = lint_config(w, b, s, args.scale_items,
                               args.scale_customers)
        errs = errors_in(findings)
        rest = [f for f in findings if f.severity != "error"]
        tag = "FAIL" if errs else "ok"
        print(f"[{tag:>4}] {w}/{b}/shards={s or 'off'} — "
              f"{len(errs)} error(s), {len(rest)} note(s)")
        all_findings += findings

    errs = errors_in(all_findings)
    shown = all_findings if args.verbose else errs
    if shown:
        print()
        print(format_findings(shown))
    print(f"\nplanlint: {len(configs)} configs, "
          f"{len(errs)} error finding(s)")
    return 1 if errs else 0


if __name__ == "__main__":
    sys.exit(main())

"""Jit'd dispatch wrappers: jnp reference path on CPU, Pallas on TPU.

``REPRO_KERNELS=pallas`` forces the Pallas path (interpret=True off-TPU),
which is how the kernel test-suite validates every kernel against ref.py.
"""
from __future__ import annotations

import functools
import os

import jax

from repro.kernels import ref as _ref


def _backend() -> str:
    forced = os.environ.get("REPRO_KERNELS")
    if forced:
        return forced
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=())
def _clockscan_ref(cols, lo, hi, valid):
    return _ref.clockscan_ref(cols, lo, hi, valid)


def clockscan(cols, lo, hi, valid):
    if _backend() == "pallas":
        from repro.kernels.clockscan import clockscan_pallas
        return clockscan_pallas(cols, lo, hi, valid,
                                interpret=_interpret())
    return _ref.clockscan_ref(cols, lo, hi, valid)


def bitmask_join(keys_l, mask_l, keys_r, mask_r, valid_r):
    if _backend() == "pallas":
        from repro.kernels.bitmask_join import bitmask_join_pallas
        return bitmask_join_pallas(keys_l, mask_l, keys_r, mask_r, valid_r,
                                   interpret=_interpret())
    return _ref.bitmask_join_ref(keys_l, mask_l, keys_r, mask_r, valid_r)


def shared_groupby(group_code, values, mask, n_groups: int):
    if _backend() == "pallas":
        from repro.kernels.shared_groupby import shared_groupby_pallas
        return shared_groupby_pallas(group_code, values, mask, n_groups,
                                     interpret=_interpret())
    return _ref.shared_groupby_ref(group_code, values, mask, n_groups)


def fused_delta(scan_in, join_in):
    if _backend() == "pallas":
        from repro.kernels.fused_delta import fused_delta_pallas
        return fused_delta_pallas(scan_in, join_in, interpret=_interpret())
    return _ref.fused_delta_ref(scan_in, join_in)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0):
    if _backend() == "pallas":
        from repro.kernels.flash_attention import flash_attention_pallas
        return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                      interpret=_interpret())
    return _ref.flash_attention_ref(q, k, v, causal=causal, window=window)

"""Delta-join kernel: re-probe ONLY the dirty spine rows of a
partitioned shared join.

A carried join rid array (core/lowering.py ``build_delta_cycle`` with
delta joins) stays exact for every spine row whose fk key did not change
while the PK side's partitions were not rebuilt — so a steady-state
heartbeat only needs fresh rids for the update batch's dirty spine rows.
This kernel is the partitioned probe of kernels/partitioned_join.py
restricted to that fixed-capacity dirty set:

  grid              = (D,)          one program per dirty-row slot
  bidx (prefetch)   = int32[D]      the dirty row's bucket index — the
                                    ``searchsorted`` routing over the P
                                    bucket bounds runs in XLA outside
                                    (it needs the KEY VALUE, which no
                                    BlockSpec index_map can see); the
                                    kernel uses it to pick which bucket
                                    pane to DMA
  kd block          = [1]           the dirty row's fk key (gathered in
                                    XLA alongside the routing)
  bkeys/brows block = [1, B]        THE routed bucket's keys / row ids
  out block         = [1]           matched PK row id (-1 = none),
                                    scattered back into the carried rid
                                    array by the caller

One row per program keeps the scalar-prefetch gather exact for any dirty
pattern; D is the fixed (small) dirty capacity, so total work is
O(D * B) — independent of the spine size, which is the whole point
(the full probe is O(Tl * B)).  Empty slots (storage pads the dirty set
with the capacity sentinel) clamp to a real row, evaluate it, and are
dropped by the caller's bounds-checked scatter, mirroring delta_scan.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(bidx_ref, kd_ref, bkeys_ref, brows_ref, rid_ref):
    hit = (bkeys_ref[...] == kd_ref[0]) & (brows_ref[...] >= 0)  # [1, B]
    rid_ref[0] = jnp.max(jnp.where(hit, brows_ref[...], -1))


def delta_join_pallas(keys_l, rows, bucket_keys, bucket_rows, bounds, *,
                      interpret: bool = True):
    """Same contract as kernels/ref.delta_join_ref."""
    P, B = bucket_keys.shape
    T = keys_l.shape[0]
    D = rows.shape[0]
    # XLA prologue, shared with the reference probe: gather the dirty
    # rows' keys (pad slots clamp in range) and route each to its ONE
    # candidate bucket — the last whose bound <= key
    safe = jnp.clip(rows, 0, T - 1)
    kd = keys_l[safe]
    b = jnp.searchsorted(bounds, kd, side="right").astype(jnp.int32) - 1
    b = jnp.clip(b, 0, P - 1)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(D,),
        in_specs=[
            pl.BlockSpec((1,), lambda i, bidx_ref: (i,)),
            # the scalar-prefetch gather: bidx[i] picks the bucket pane
            pl.BlockSpec((1, B), lambda i, bidx_ref: (bidx_ref[i], 0)),
            pl.BlockSpec((1, B), lambda i, bidx_ref: (bidx_ref[i], 0)),
        ],
        out_specs=pl.BlockSpec((1,), lambda i, bidx_ref: (i,)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((D,), jnp.int32),
        interpret=interpret,
    )(b, kd, bucket_keys, bucket_rows)

"""Shared group-by kernel: aggregation as an MXU contraction.

Phase 1 of the paper's shared group-by (§3.4) — grouping the union of all
queries' tuples — becomes, per (group-tile, row-tile):

  count[G_t, Q] += onehot(group)^T @ unpack(mask)
  sum  [G_t, Q] += onehot(group)^T @ (unpack(mask) * value)

i.e. "all groups x all queries" aggregation is two dense f32 matmuls per
tile — exactly what the MXU is built for.  Row tiles are the inner
(sequential) grid dim so accumulation stays in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_T = 512
TILE_G = 256


def _unpack_bits(mask, qcap):
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (mask[..., None] >> shifts) & jnp.uint32(1)
    return bits.reshape(mask.shape[0], qcap)


def _kernel(group_ref, value_ref, mask_ref, count_ref, sum_ref, *,
            qcap: int, tile_g: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        count_ref[...] = jnp.zeros_like(count_ref)
        sum_ref[...] = jnp.zeros_like(sum_ref)

    g0 = pl.program_id(0) * tile_g
    bits = _unpack_bits(mask_ref[...], qcap).astype(jnp.float32)
    local = group_ref[...] - g0                      # [Tt]
    onehot = (local[:, None] ==
              jnp.arange(tile_g, dtype=jnp.int32)[None, :])
    onehot = onehot.astype(jnp.float32)              # [Tt, Gt]
    count_ref[...] += jnp.einsum("tg,tq->gq", onehot, bits)
    vals = value_ref[...].astype(jnp.float32)[:, None] * bits
    sum_ref[...] += jnp.einsum("tg,tq->gq", onehot, vals)


def shared_groupby_pallas(group_code, values, mask, n_groups: int, *,
                          interpret: bool = True):
    T, W = mask.shape
    Q = W * 32
    tt = min(TILE_T, T)
    pad = (-T) % tt
    if pad:  # arbitrary row counts: padded rows carry empty masks
        group_code = jnp.pad(group_code, (0, pad))
        values = jnp.pad(values, (0, pad))
        mask = jnp.pad(mask, ((0, pad), (0, 0)))
        T += pad
    tg = min(TILE_G, n_groups)
    assert T % tt == 0
    Gp = -(-n_groups // tg) * tg                     # pad group space
    kernel = functools.partial(_kernel, qcap=Q, tile_g=tg)
    count, ssum = pl.pallas_call(
        kernel,
        grid=(Gp // tg, T // tt),
        in_specs=[
            pl.BlockSpec((tt,), lambda i, j: (j,)),
            pl.BlockSpec((tt,), lambda i, j: (j,)),
            pl.BlockSpec((tt, W), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tg, Q), lambda i, j: (i, 0)),
            pl.BlockSpec((tg, Q), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Gp, Q), jnp.float32),
            jax.ShapeDtypeStruct((Gp, Q), jnp.float32),
        ],
        interpret=interpret,
    )(group_code, values, mask)
    return count[:n_groups], ssum[:n_groups]

"""Pallas TPU kernels for SharedDB's compute hot-spots + the LM serving path.

Layout per kernel: <name>.py holds the pl.pallas_call + BlockSpec tiling;
ref.py holds pure-jnp oracles; ops.py holds the jit'd dispatch wrappers
(ref path on CPU, Pallas on TPU, interpret=True for CPU validation).
"""

"""Pallas TPU kernels for SharedDB's compute hot-spots + the LM serving path.

Layout per kernel: <name>.py holds the pl.pallas_call + BlockSpec tiling;
ref.py holds pure-jnp oracles; ops.py holds the jit'd dispatch wrappers
(ref path on CPU, Pallas on TPU, interpret=True for CPU validation).

Importing this package registers the ``pallas`` operator backend with
repro.core.backends, which is how the lowered global plan selects the
kernels (``SharedDBEngine(..., kernels="pallas")`` or ``"auto"`` on TPU).
The kernel modules themselves are imported lazily, at first call.
"""
from __future__ import annotations

import jax

from repro.core import backends as _backends


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pallas_scan(cols, lo, hi, valid):
    from repro.kernels.clockscan import clockscan_pallas
    return clockscan_pallas(cols, lo, hi, valid, interpret=_interpret())


def _pallas_join_block(keys_l, mask_l, keys_r, mask_r, valid_r):
    from repro.kernels.bitmask_join import bitmask_join_pallas
    return bitmask_join_pallas(keys_l, mask_l, keys_r, mask_r, valid_r,
                               interpret=_interpret())


def _pallas_join_partitioned(keys_l, mask_l, bucket_keys, bucket_rows,
                             bounds, mask_r):
    from repro.kernels.partitioned_join import partitioned_join_pallas
    return partitioned_join_pallas(keys_l, mask_l, bucket_keys, bucket_rows,
                                   bounds, mask_r, interpret=_interpret())


def _pallas_groupby(group_code, values, mask, n_groups: int):
    from repro.kernels.shared_groupby import shared_groupby_pallas
    return shared_groupby_pallas(group_code, values, mask, n_groups,
                                 interpret=_interpret())


def _pallas_scan_delta(cols, lo, hi, valid, rows):
    from repro.kernels.fused_delta import delta_scan_pallas
    return delta_scan_pallas(cols, lo, hi, valid, rows,
                             interpret=_interpret())


def _pallas_join_delta(keys_l, rows, bucket_keys, bucket_rows, bounds):
    from repro.kernels.fused_delta import delta_join_pallas
    return delta_join_pallas(keys_l, rows, bucket_keys, bucket_rows,
                             bounds, interpret=_interpret())


def _pallas_fused_delta(scan_in, join_in):
    from repro.kernels.fused_delta import fused_delta_pallas
    return fused_delta_pallas(scan_in, join_in, interpret=_interpret())


_backends.register_backend(_backends.OperatorBackend(
    name="pallas", scan=_pallas_scan, join_block=_pallas_join_block,
    join_partitioned=_pallas_join_partitioned, groupby=_pallas_groupby,
    scan_delta=_pallas_scan_delta, join_delta=_pallas_join_delta,
    fused_delta=_pallas_fused_delta))

"""Pure-jnp oracles for every kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import dataquery as dq


def clockscan_ref(cols, lo, hi, valid):
    """cols int32[C,T]; lo/hi int32[C,Q]; valid bool[T] -> uint32[T,Q/32]."""
    C, T = cols.shape
    ok = jnp.ones((T, lo.shape[1]), bool)
    for c in range(C):
        x = cols[c][:, None]
        ok &= (x >= lo[c][None, :]) & (x <= hi[c][None, :])
    ok &= valid[:, None]
    return dq.pack(ok)


def delta_scan_ref(cols, lo, hi, valid, rows):
    """Dirty-row delta scan oracle.

    cols int32[C,T]; lo/hi int32[C,Q]; valid bool[T]; rows int32[D]
    (out-of-range values — storage pads with the capacity sentinel — are
    empty slots) -> uint32[D, Q/32]: the freshly evaluated bitmask words
    for exactly the gathered rows (empty slots clamp to a real row and
    are dropped by the caller's bounds-checked scatter).  Same predicate
    semantics as ``clockscan_ref`` restricted to ``rows``.
    """
    C, T = cols.shape
    safe = jnp.clip(rows, 0, T - 1)
    ok = jnp.ones((rows.shape[0], lo.shape[1]), bool)
    for c in range(C):
        x = cols[c][safe][:, None]
        ok &= (x >= lo[c][None, :]) & (x <= hi[c][None, :])
    ok &= valid[safe][:, None]
    return dq.pack(ok)


def delta_join_ref(keys_l, rows, bucket_keys, bucket_rows, bounds):
    """Dirty-row partitioned-join probe oracle.

    keys_l int32[Tl] (the spine's full fk column); rows int32[D] dirty
    spine row ids (out-of-range values — storage pads with the capacity
    sentinel — are empty slots); bucket_keys/bucket_rows int32[P, B],
    bounds int32[P] per ``storage.build_key_partitions``.  Returns
    rid int32[D]: the matched PK row (-1 = no match) for exactly the
    gathered rows — empty slots clamp to a real row, evaluate it, and
    are dropped by the caller's bounds-checked scatter.  Same probe
    contract as ``partitioned_join_ref`` restricted to ``rows`` (a key k
    lives in the LAST bucket whose bound <= k; duplicates resolve to the
    max row id).
    """
    P, B = bucket_keys.shape
    safe = jnp.clip(rows, 0, keys_l.shape[0] - 1)
    kd = keys_l[safe]
    b = jnp.searchsorted(bounds, kd, side="right").astype(jnp.int32) - 1
    b = jnp.clip(b, 0, P - 1)
    hit = (bucket_keys[b] == kd[:, None]) & (bucket_rows[b] >= 0)
    return jnp.max(jnp.where(hit, bucket_rows[b], -1), axis=1)


def fused_delta_ref(scan_in, join_in):
    """Whole-delta-beat oracle (backends.OperatorBackend.fused_delta).

    ``scan_in``/``join_in`` are tuples of backends.FusedScanIn /
    FusedJoinIn.  Per scan stage: merge the admission pane (an in-place
    dynamic_update_slice of a pane-width ``clockscan_ref``) and the
    dirty rows (``delta_scan_ref`` + sorted-unique scatter) into the
    carried words; per carried join: merge the dirty spine rows'
    one-bucket probe (``delta_join_ref``) into the carried rids.

    Unlike the chained ops, each phase runs under a ``lax.cond`` on its
    host-free emptiness scalar (``span``/``dn``): a steady-state trickle
    beat typically changes ONE stage's admission and dirties ONE table,
    so every other stage's pane recompute and dirty rescan — exact
    identities on the carry — are skipped outright instead of recomputed
    and rewritten.  The conds branch on replicated/shard-local scalars,
    never introducing a collective (the sharded delta beat's locality
    contract, tests/test_sharding_locality.py).
    """
    from repro.core.storage import scatter_dirty_rows

    words = []
    for e in scan_in:
        T = e.cols.shape[1]
        m = jax.lax.cond(
            e.span > 0,
            lambda c, e=e: jax.lax.dynamic_update_slice(
                c, clockscan_ref(e.cols, e.lo_p, e.hi_p, e.valid),
                (0, e.w0)),
            lambda c: c, e.carry)
        m = jax.lax.cond(
            e.dn > 0,
            lambda mm, e=e: scatter_dirty_rows(
                mm, e.rows,
                delta_scan_ref(e.cols, e.lo, e.hi, e.valid, e.rows), T),
            lambda mm: mm, m)
        words.append(m)
    rids = []
    for e in join_in:
        rids.append(jax.lax.cond(
            e.dn > 0,
            lambda r, e=e: scatter_dirty_rows(
                r, e.rows,
                delta_join_ref(e.keys, e.rows, e.bkeys, e.brows,
                               e.bounds), e.keys.shape[0]),
            lambda r: r, e.rid_carry))
    return tuple(words), tuple(rids)


def bitmask_join_ref(keys_l, mask_l, keys_r, mask_r, valid_r):
    """Block shared join oracle; right keys UNIQUE among valid rows.

    Returns (rid int32[Tl] (-1 = no match), combined uint32[Tl, W]).
    """
    eq = (keys_l[:, None] == keys_r[None, :]) & valid_r[None, :]
    eqi = eq.astype(jnp.uint32)
    combined = mask_l & (eqi @ mask_r)
    rid = jnp.max(jnp.where(eq, jnp.arange(keys_r.shape[0],
                                           dtype=jnp.int32)[None, :] + 1, 0),
                  axis=1) - 1
    return rid, jnp.where((rid >= 0)[:, None], combined, jnp.uint32(0))


def partitioned_join_ref(keys_l, mask_l, bucket_keys, bucket_rows, bounds,
                         mask_r):
    """Partitioned shared join probe, pure jnp (oracle + CPU path).

    The right side arrives pre-partitioned (storage.build_key_partitions):
    bucket_keys/bucket_rows int32[P, B] hold the valid right rows sorted
    by key and split into P fixed-capacity range buckets; bounds int32[P]
    is each bucket's smallest key.  Each left key probes exactly ONE
    bucket — the last whose bound <= key — so the probe is O(Tl * B) =
    O(Tl * Tr / P) instead of the dense block join's O(Tl * Tr).

    Returns (rid int32[Tl] (-1 = no match; duplicates resolve to the max
    row id, matching bitmask_join_ref), combined uint32[Tl, W] =
    mask_l & mask_r[rid]).
    """
    P, B = bucket_keys.shape
    b = jnp.searchsorted(bounds, keys_l, side="right").astype(jnp.int32) - 1
    b = jnp.clip(b, 0, P - 1)
    cand_keys = bucket_keys[b]                       # [Tl, B]
    cand_rows = bucket_rows[b]
    hit = (cand_keys == keys_l[:, None]) & (cand_rows >= 0)
    rid = jnp.max(jnp.where(hit, cand_rows, -1), axis=1)
    safe = jnp.clip(rid, 0, mask_r.shape[0] - 1)
    combined = jnp.where((rid >= 0)[:, None], mask_l & mask_r[safe],
                         jnp.uint32(0))
    return rid, combined


def shared_groupby_ref(group_code, values, mask, n_groups: int):
    """-> (count f32[G, Q], sum f32[G, Q]).

    segment_sum formulation — O(T*Q): the semantic oracle and the CPU
    execution path.  The Pallas kernel computes the same contraction as
    one-hot matmuls on the MXU (see shared_groupby.py).
    """
    bits = dq.unpack(mask).astype(jnp.float32)
    count = jax.ops.segment_sum(bits, group_code, num_segments=n_groups)
    ssum = jax.ops.segment_sum(
        bits * values[:, None].astype(jnp.float32), group_code,
        num_segments=n_groups)
    return count, ssum


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """Naive softmax attention oracle.

    q: [B, Sq, H, D]; k, v: [B, Sk, KV, D] (GQA); returns [B, Sq, H, D].
    Decode: pass Sq=1 with causal offset = Sk - 1 implied (q at last pos).
    """
    B, Sq, H, D = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    if KV != H:
        k = jnp.repeat(k, H // KV, axis=2)
        v = jnp.repeat(v, H // KV, axis=2)
    s = jnp.einsum("bqhd,bkhd->bqhk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / jnp.sqrt(float(D))
    qpos = jnp.arange(Sq) + (Sk - Sq)
    kpos = jnp.arange(Sk)
    ok = jnp.ones((Sq, Sk), bool)
    if causal:
        ok &= qpos[:, None] >= kpos[None, :]
    if window > 0:
        ok &= qpos[:, None] - kpos[None, :] < window
    s = jnp.where(ok[None, :, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqhk,bkhd->bqhd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def ssd_scan_ref(x, dt, A, B, C):
    """Naive per-timestep Mamba-2 recurrence oracle.

    x:[b,s,h,p] dt:[b,s,h] A:[h] B,C:[b,s,n] -> (y, final_state[b,h,p,n]).
    """
    b, s, h, p = x.shape
    n = B.shape[-1]

    def step(state, inp):
        xt, dtt, Bt, Ct = inp
        dA = jnp.exp(dtt * A)                         # [b,h]
        upd = jnp.einsum("bn,bh,bhp->bhpn", Bt, dtt, xt)
        state = state * dA[..., None, None] + upd
        y = jnp.einsum("bn,bhpn->bhp", Ct, state)
        return state, y

    init = jnp.zeros((b, h, p, n), jnp.float32)
    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(B, 1, 0), jnp.moveaxis(C, 1, 0))
    final, ys = jax.lax.scan(step, init, xs)
    return jnp.moveaxis(ys, 0, 1), final

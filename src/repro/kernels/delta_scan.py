"""Delta-scan kernel: re-evaluate ONLY the dirty rows of a ClockScan.

Steady-state heartbeats touch a handful of rows (one update batch) while
the full shared scan re-compares every tuple against every query slot.
The incremental scan path (core/lowering.py ``build_delta_cycle``) keeps
the previous heartbeat's bitmask words and asks this kernel for fresh
words for exactly the rows the update batch dirtied:

  grid             = (D,)            one program per dirty-row slot
  rows (prefetch)  = int32[D]        dirty row ids; out-of-range values
                                     (storage pads with the table
                                     capacity sentinel) are empty slots
  cols block       = [C, 1]          THE dirty row's column values —
                                     gathered via scalar prefetch: the
                                     BlockSpec index_map reads rows[i] to
                                     pick which column of cols to DMA
  lo/hi blocks     = [C, Q]          whole predicate matrix resident
  valid block      = [1]             the dirty row's validity
  out block        = [1, W]          packed words, scattered back into
                                     the carried mask by the caller

One row per program keeps the scalar-prefetch gather exact for any dirty
pattern; D is the fixed (small) dirty capacity, so total work is
O(D * C * Q) — independent of the table size, which is the whole point.
Empty slots clamp to a real row, evaluate it, and are dropped by the
caller's bounds-checked scatter, mirroring partitioned_join's padding.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(rows_ref, cols_ref, lo_ref, hi_ref, valid_ref, out_ref, *,
            n_cols: int, qcap: int):
    ok = jnp.ones((1, qcap), jnp.bool_)
    for c in range(n_cols):
        x = cols_ref[c, 0]
        ok &= (x >= lo_ref[c, :][None, :]) & (x <= hi_ref[c, :][None, :])
    ok &= valid_ref[0]
    w = qcap // 32
    bits = ok.reshape(1, w, 32).astype(jnp.uint32)
    weights = jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32)
    out_ref[...] = jnp.sum(bits * weights[None, None, :], axis=-1,
                           dtype=jnp.uint32)


def delta_scan_pallas(cols, lo, hi, valid, rows, *, interpret: bool = True):
    """Same contract as kernels/ref.delta_scan_ref."""
    C, T = cols.shape
    Q = lo.shape[1]
    D = rows.shape[0]
    assert Q % 32 == 0
    W = Q // 32
    kernel = functools.partial(_kernel, n_cols=C, qcap=Q)

    def row(i, rows_ref):                    # pad slots clamp in range
        return jnp.clip(rows_ref[i], 0, T - 1)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(D,),
        in_specs=[
            # the scalar-prefetch gather: rows[i] picks the cols column
            pl.BlockSpec((C, 1), lambda i, rows_ref: (0, row(i, rows_ref))),
            pl.BlockSpec((C, Q), lambda i, rows_ref: (0, 0)),
            pl.BlockSpec((C, Q), lambda i, rows_ref: (0, 0)),
            pl.BlockSpec((1,), lambda i, rows_ref: (row(i, rows_ref),)),
        ],
        out_specs=pl.BlockSpec((1, W), lambda i, rows_ref: (i, 0)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((D, W), jnp.uint32),
        interpret=interpret,
    )(rows.astype(jnp.int32), cols, lo, hi, valid)

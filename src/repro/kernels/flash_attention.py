"""Flash attention (causal / sliding-window, GQA) — the LM serving hot-spot.

  grid = (B * H, Sq // BLOCK_Q, Sk // BLOCK_K)   (k blocks innermost)
  q block  [BLOCK_Q, D] VMEM; k/v blocks [BLOCK_K, D] VMEM
  online-softmax running (m, l, acc) kept in VMEM scratch across k blocks;
  finalized on the last k block.

GQA is handled by mapping head h to kv head h // (H // KV) in the k/v
index_map, so the repeated KV never materializes in HBM.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK_Q = 128
BLOCK_K = 128
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, window: int, block_q: int,
            block_k: int, n_k: int, sq: int, sk: int):
    iq = pl.program_id(1)
    jk = pl.program_id(2)

    @pl.when(jk == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                 # [bq, D]
    k = k_ref[0].astype(jnp.float32)                 # [bk, D]
    s = jnp.einsum("qd,kd->qk", q, k) * scale

    qpos = iq * block_q + jnp.arange(block_q) + (sk - sq)
    kpos = jk * block_k + jnp.arange(block_k)
    ok = jnp.ones((block_q, block_k), bool)
    if causal:
        ok &= qpos[:, None] >= kpos[None, :]
    if window > 0:
        ok &= qpos[:, None] - kpos[None, :] < window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1)
    acc_scr[...] = acc_scr[...] * corr[:, None] + jnp.einsum(
        "qk,kd->qd", p, v_ref[0].astype(jnp.float32))
    m_scr[...] = m_new

    @pl.when(jk == n_k - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True, window: int = 0,
                           interpret: bool = True,
                           block_q: int = BLOCK_Q, block_k: int = BLOCK_K):
    """q: [B, Sq, H, D]; k, v: [B, Sk, KV, D] -> [B, Sq, H, D]."""
    B, Sq, H, D = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    g = H // KV
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    assert Sq % bq == 0 and Sk % bk == 0
    scale = 1.0 / math.sqrt(D)

    # flatten (B, H) into the leading grid dim
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * KV, Sk, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * KV, Sk, D)

    def kv_map(bh, i, j):
        # bh = b * H + h  ->  b * KV + h // g
        return (bh // H) * KV + (bh % H) // g, j, 0

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window, block_q=bq,
        block_k=bk, n_k=Sk // bk, sq=Sq, sk=Sk)
    of = pl.pallas_call(
        kernel,
        grid=(B * H, Sq // bq, Sk // bk),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda bh, i, j: (bh, i, 0)),
            pl.BlockSpec((1, bk, D), kv_map),
            pl.BlockSpec((1, bk, D), kv_map),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda bh, i, j: (bh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return of.reshape(B, H, Sq, D).transpose(0, 2, 1, 3)

"""Fused delta-heartbeat mega-kernel: the WHOLE incremental beat in one
``pallas_call``.

The chained delta path (PR 3/4) launches one kernel per phase per stage
— an admission-pane compare, a dirty-row rescan and a dirty-spine-row
bucket probe for every predicated scan / carried join — and threads
materialized intermediates (pane words, dirty words, dirty rids) between
them through XLA.  At trickle rates the beat's wall time is dominated by
that dispatch chain, not by compute.  This kernel collapses the chain:

  grid = (N,)   N = Σ_stages (pane tiles + dirty slots) + Σ_joins slots

one flat grid whose every program is ONE unit of delta work, routed by a
scalar-prefetched work descriptor ``sdesc int32[N, 4]``:

  sdesc[i] = (kind, owner, idx, gather)

  kind 0 (PANE)  — one ``PANE_TILE``-row tile of stage ``owner``'s
                   admission-pane compare: the pane-width predicate
                   slices (lo_p/hi_p, pre-sliced at w0 by the caller)
                   against the tile's column values, bit-packed to
                   ``A`` words per row.  ``idx`` picks the tile.
  kind 1 (DIRTY) — one dirty row of stage ``owner``, re-evaluated
                   against the FULL window: ``gather`` holds the row id
                   (pad slots clamp in range) and the BlockSpec
                   index_map reads it to DMA exactly that column of
                   cols — the scalar-prefetch gather.
  kind 2 (PROBE) — one dirty spine row of carried join ``owner``:
                   ``gather`` holds the row's bucket index (the
                   ``searchsorted`` routing runs in the XLA prologue —
                   it needs the key VALUE, which no index_map can see)
                   and the kernel probes that ONE bucket pane.
                   Block-kind joins arrive as single-bucket
                   pseudo-partitions, so every carried join probes
                   through this same path.

Non-owning programs park on per-output GARBAGE blocks (one spare tile /
slot appended past the real extent), so each real output block has
exactly one writer and no cross-program masking is needed.  A thin XLA
epilogue inside the op — still one kernel launch on the hot path —
merges the pane into the carried words (in-place dynamic_update_slice,
skipped when ``span == 0``), scatters the dirty words/rids back on the
sorted-unique fast path (pad sentinels drop), and returns the merged
carries directly: the ``[Tl, B]`` candidate panes and full-window
compare matrices of the chained path are never materialized.

The standalone ``delta_scan_pallas`` / ``delta_join_pallas`` kernels
(formerly kernels/delta_scan.py / delta_join.py) are absorbed below:
they are the DIRTY / PROBE program bodies as free-standing calls, kept
as the chained fallback surface (``OperatorBackend.scan_delta`` /
``join_delta``) for backends or beats the fused path does not cover.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.storage import scatter_dirty_rows

PANE_TILE = 256

_PANE, _DIRTY, _PROBE = 0, 1, 2


class ScanGeom(NamedTuple):
    """Static geometry of one predicated scan stage in the fused grid."""
    C: int        # predicated columns
    Q: int        # full window width (slots)
    A: int        # admission-pane words
    R: int        # pane tile rows (min(PANE_TILE, T))
    nt: int       # pane tiles (ceil(T / R)); tile nt is the garbage tile
    D: int        # dirty-row slots; slot D is the garbage slot


class JoinGeom(NamedTuple):
    """Static geometry of one carried join in the fused grid."""
    B: int        # bucket pane width
    D: int        # dirty spine-row slots; slot D is the garbage slot
    P: int        # bucket count (1 for block pseudo-partitions)


def scan_geometry(e) -> ScanGeom:
    """Geometry from a ``FusedScanIn``'s static shapes."""
    C, T = e.cols.shape
    R = min(PANE_TILE, T)
    return ScanGeom(C=C, Q=e.lo.shape[1], A=e.lo_p.shape[1] // 32,
                    R=R, nt=-(-T // R), D=e.rows.shape[0])


def join_geometry(e) -> JoinGeom:
    """Geometry from a ``FusedJoinIn``'s static shapes."""
    P, B = e.bkeys.shape
    return JoinGeom(B=B, D=e.rows.shape[0], P=P)


def build_schedule(sgeom, jgeom) -> np.ndarray:
    """The STATIC third of the work descriptor: int32[N, 3] rows of
    (kind, owner, idx) — one pane tile / dirty slot / probe slot per
    grid program, in stage order.  Pure geometry, no runtime data: this
    is the schedule ``analysis_static.kernel_passes`` validates (every
    extent covered exactly once, grid length == schedule length)."""
    rows = []
    for s, g in enumerate(sgeom):
        rows += [(_PANE, s, t) for t in range(g.nt)]
        rows += [(_DIRTY, s, d) for d in range(g.D)]
    for j, g in enumerate(jgeom):
        rows += [(_PROBE, j, d) for d in range(g.D)]
    return np.asarray(rows, np.int32).reshape(len(rows), 3)


def build_sdesc(schedule, sgeom, jgeom, scan_rows, probe_buckets):
    """Assemble the full scalar-prefetch descriptor int32[N, 4] =
    (kind, owner, idx, gather) by appending the runtime gather column:
    clamped dirty-row ids for DIRTY rows (the BlockSpec index_map DMAs
    exactly that column), routed bucket indices for PROBE rows, zeros
    for PANE rows (unused)."""
    gathers = []
    for g, rows in zip(sgeom, scan_rows):
        gathers.append(jnp.zeros((g.nt,), jnp.int32))
        gathers.append(jnp.clip(rows, 0, g.nt * g.R - 1)
                       .astype(jnp.int32))
    gathers += [b.astype(jnp.int32) for b in probe_buckets]
    gather = jnp.concatenate(gathers) if gathers else \
        jnp.zeros((0,), jnp.int32)
    return jnp.concatenate([jnp.asarray(schedule), gather[:, None]],
                           axis=1)


def _own(d, i, k, o):
    """Does grid step ``i``'s descriptor row target (kind k, owner o)?"""
    return (d[i, 0] == k) & (d[i, 1] == o)


def make_in_specs(sgeom, jgeom):
    """Input BlockSpecs, in the kernel's ref order: 8 per scan stage
    (cols x2, valid x2, lo/hi, lo_p/hi_p), 3 per join (kd, bkeys,
    brows).  Owners address their real block; non-owners re-read block
    0 (harmless — inputs have no write hazard)."""
    specs = []
    for s, g in enumerate(sgeom):
        C, Q, A, R = g.C, g.Q, g.A, g.R
        specs += [
            pl.BlockSpec((C, R), lambda i, d, s=s: (
                0, jnp.where(_own(d, i, _PANE, s), d[i, 2], 0))),
            pl.BlockSpec((C, 1), lambda i, d, s=s: (
                0, jnp.where(_own(d, i, _DIRTY, s), d[i, 3], 0))),
            pl.BlockSpec((R,), lambda i, d, s=s: (
                jnp.where(_own(d, i, _PANE, s), d[i, 2], 0),)),
            pl.BlockSpec((1,), lambda i, d, s=s: (
                jnp.where(_own(d, i, _DIRTY, s), d[i, 3], 0),)),
            pl.BlockSpec((C, Q), lambda i, d: (0, 0)),
            pl.BlockSpec((C, Q), lambda i, d: (0, 0)),
            pl.BlockSpec((C, 32 * A), lambda i, d: (0, 0)),
            pl.BlockSpec((C, 32 * A), lambda i, d: (0, 0)),
        ]
    for j, g in enumerate(jgeom):
        B = g.B
        specs += [
            pl.BlockSpec((1,), lambda i, d, j=j: (
                jnp.where(_own(d, i, _PROBE, j), d[i, 2], 0),)),
            pl.BlockSpec((1, B), lambda i, d, j=j: (
                jnp.where(_own(d, i, _PROBE, j), d[i, 3], 0), 0)),
            pl.BlockSpec((1, B), lambda i, d, j=j: (
                jnp.where(_own(d, i, _PROBE, j), d[i, 3], 0), 0)),
        ]
    return specs


def make_out_specs(sgeom, jgeom):
    """Output BlockSpecs + shapes: one spare (garbage) tile / slot past
    the real extent parks every non-owning program's write window, so
    each real output block has exactly one writer and no cross-program
    masking is needed.  ``kernel_passes.lint_garbage_park`` re-evaluates
    these maps against a concrete descriptor to prove it."""
    specs, shapes = [], []
    for s, g in enumerate(sgeom):
        specs.append(pl.BlockSpec((g.R, g.A), lambda i, d, s=s,
                                  nt=g.nt: (
            jnp.where(_own(d, i, _PANE, s), d[i, 2], nt), 0)))
        shapes.append(
            jax.ShapeDtypeStruct(((g.nt + 1) * g.R, g.A), jnp.uint32))
        specs.append(pl.BlockSpec((1, g.Q // 32), lambda i, d, s=s,
                                  D=g.D: (
            jnp.where(_own(d, i, _DIRTY, s), d[i, 2], D), 0)))
        shapes.append(
            jax.ShapeDtypeStruct((g.D + 1, g.Q // 32), jnp.uint32))
    for j, g in enumerate(jgeom):
        specs.append(pl.BlockSpec((1,), lambda i, d, j=j, D=g.D: (
            jnp.where(_own(d, i, _PROBE, j), d[i, 2], D),)))
        shapes.append(jax.ShapeDtypeStruct((g.D + 1,), jnp.int32))
    return specs, shapes


def _pack_bits(ok):
    """bool[R, 32*w] -> uint32[R, w] (32 query lanes per word)."""
    R = ok.shape[0]
    w = ok.shape[1] // 32
    bits = ok.reshape(R, w, 32).astype(jnp.uint32)
    weights = jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32)
    return jnp.sum(bits * weights[None, None, :], axis=-1,
                   dtype=jnp.uint32)


# ---------------------------------------------------------------------------
# The mega-kernel
# ---------------------------------------------------------------------------


def _mega_kernel(sdesc_ref, *refs, sgeom, jgeom):
    i = pl.program_id(0)
    kind = sdesc_ref[i, 0]
    owner = sdesc_ref[i, 1]
    n_in = 8 * len(sgeom) + 3 * len(jgeom)
    for s, (C, Q, A, R, _nt, _D) in enumerate(sgeom):
        (cols_t, cols_r, valid_t, valid_r, lo, hi, lo_p,
         hi_p) = refs[8 * s:8 * s + 8]
        pane_out = refs[n_in + 2 * s]
        dwords_out = refs[n_in + 2 * s + 1]

        @pl.when((kind == _PANE) & (owner == s))
        def _():
            ok = jnp.ones((R, 32 * A), jnp.bool_)
            for c in range(C):
                x = cols_t[c, :][:, None]                   # [R, 1]
                ok &= (x >= lo_p[c, :][None, :]) \
                    & (x <= hi_p[c, :][None, :])
            ok &= valid_t[...][:, None]
            pane_out[...] = _pack_bits(ok)

        @pl.when((kind == _DIRTY) & (owner == s))
        def _():
            ok = jnp.ones((1, Q), jnp.bool_)
            for c in range(C):
                x = cols_r[c, 0]
                ok &= (x >= lo[c, :][None, :]) \
                    & (x <= hi[c, :][None, :])
            ok &= valid_r[0]
            dwords_out[...] = _pack_bits(ok)

    for j, (_B, _Dj, _P) in enumerate(jgeom):
        kd, bkeys, brows = refs[8 * len(sgeom) + 3 * j:
                                8 * len(sgeom) + 3 * j + 3]
        rid_out = refs[n_in + 2 * len(sgeom) + j]

        @pl.when((kind == _PROBE) & (owner == j))
        def _():
            hit = (bkeys[...] == kd[0]) & (brows[...] >= 0)  # [1, B]
            rid_out[0] = jnp.max(jnp.where(hit, brows[...], -1))


def fused_delta_pallas(scan_in, join_in, *, interpret: bool = True):
    """Same contract as kernels/ref.fused_delta_ref: tuples of
    backends.FusedScanIn / FusedJoinIn -> (merged words, merged rids)."""
    scan_in, join_in = tuple(scan_in), tuple(join_in)
    if not scan_in and not join_in:
        return (), ()

    # ---- static geometry + padded inputs -------------------------------
    sgeom = [scan_geometry(e) for e in scan_in]
    padded = []
    for g, e in zip(sgeom, scan_in):
        pad = g.nt * g.R - e.cols.shape[1]
        cols_p = jnp.pad(e.cols, ((0, 0), (0, pad))) if pad else e.cols
        valid_p = jnp.pad(e.valid, (0, pad)) if pad else e.valid
        padded.append((cols_p, valid_p))
    jgeom = [join_geometry(e) for e in join_in]
    probes = []
    for g, e in zip(jgeom, join_in):
        # XLA prologue (shared with the reference probe): gather the
        # dirty rows' keys and route each to its ONE candidate bucket
        safe = jnp.clip(e.rows, 0, e.keys.shape[0] - 1)
        kd = e.keys[safe]
        b = jnp.searchsorted(e.bounds, kd,
                             side="right").astype(jnp.int32) - 1
        probes.append((kd, jnp.clip(b, 0, g.P - 1)))

    # ---- the flat work descriptor (kind, owner, idx, gather) ----------
    schedule = build_schedule(sgeom, jgeom)
    sdesc = build_sdesc(schedule, sgeom, jgeom,
                        [e.rows for e in scan_in],
                        [b for _, b in probes])
    N = int(schedule.shape[0])

    # ---- block specs: owners address real blocks, others park ---------
    inputs = []
    for (cols_p, valid_p), e in zip(padded, scan_in):
        inputs += [cols_p, cols_p, valid_p, valid_p, e.lo, e.hi, e.lo_p,
                   e.hi_p]
    for (kd, b), e in zip(probes, join_in):
        inputs += [kd, e.bkeys, e.brows]
    in_specs = make_in_specs(sgeom, jgeom)
    out_specs, out_shapes = make_out_specs(sgeom, jgeom)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1, grid=(N,), in_specs=in_specs,
        out_specs=out_specs)
    outs = pl.pallas_call(
        functools.partial(_mega_kernel, sgeom=tuple(sgeom),
                          jgeom=tuple(jgeom)),
        grid_spec=grid_spec, out_shape=out_shapes,
        interpret=interpret)(sdesc, *inputs)

    # ---- XLA epilogue: merge into the carries (no intermediates leave
    # the op; sentinel rows drop in the bounds-checked scatter) ---------
    words = []
    for s, ((C, Q, A, R, nt, D), e) in enumerate(zip(sgeom, scan_in)):
        T = e.cols.shape[1]
        pane = outs[2 * s][:T]                            # [T, A]
        m = jnp.where(e.span > 0,
                      jax.lax.dynamic_update_slice(e.carry, pane,
                                                   (0, e.w0)),
                      e.carry)
        words.append(scatter_dirty_rows(m, e.rows, outs[2 * s + 1][:D],
                                        T))
    rids = []
    for j, ((B, D, _P), e) in enumerate(zip(jgeom, join_in)):
        rid_d = outs[2 * len(sgeom) + j][:D]
        rids.append(scatter_dirty_rows(e.rid_carry, e.rows, rid_d,
                                       e.keys.shape[0]))
    return tuple(words), tuple(rids)


# ---------------------------------------------------------------------------
# Absorbed standalone kernels (the chained-fallback surface)
# ---------------------------------------------------------------------------


def _delta_scan_kernel(rows_ref, cols_ref, lo_ref, hi_ref, valid_ref,
                       out_ref, *, n_cols: int, qcap: int):
    ok = jnp.ones((1, qcap), jnp.bool_)
    for c in range(n_cols):
        x = cols_ref[c, 0]
        ok &= (x >= lo_ref[c, :][None, :]) & (x <= hi_ref[c, :][None, :])
    ok &= valid_ref[0]
    out_ref[...] = _pack_bits(ok)


def delta_scan_pallas(cols, lo, hi, valid, rows, *, interpret: bool = True):
    """Dirty-row delta scan (contract: kernels/ref.delta_scan_ref).

    grid = (D,), one program per dirty-row slot; the BlockSpec index_map
    reads the scalar-prefetched row id to DMA exactly that column of
    cols.  Work is O(D * C * Q) — independent of the table size.  This
    is the fused kernel's DIRTY program as a standalone call (the
    chained ``OperatorBackend.scan_delta`` fallback).
    """
    C, T = cols.shape
    Q = lo.shape[1]
    D = rows.shape[0]
    if Q % 32:
        raise ValueError(
            f"delta scan window width {Q} is not a multiple of 32")
    W = Q // 32
    kernel = functools.partial(_delta_scan_kernel, n_cols=C, qcap=Q)

    def row(i, rows_ref):                    # pad slots clamp in range
        return jnp.clip(rows_ref[i], 0, T - 1)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(D,),
        in_specs=[
            # the scalar-prefetch gather: rows[i] picks the cols column
            pl.BlockSpec((C, 1), lambda i, rows_ref: (0, row(i, rows_ref))),
            pl.BlockSpec((C, Q), lambda i, rows_ref: (0, 0)),
            pl.BlockSpec((C, Q), lambda i, rows_ref: (0, 0)),
            pl.BlockSpec((1,), lambda i, rows_ref: (row(i, rows_ref),)),
        ],
        out_specs=pl.BlockSpec((1, W), lambda i, rows_ref: (i, 0)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((D, W), jnp.uint32),
        interpret=interpret,
    )(rows.astype(jnp.int32), cols, lo, hi, valid)


def _delta_join_kernel(bidx_ref, kd_ref, bkeys_ref, brows_ref, rid_ref):
    hit = (bkeys_ref[...] == kd_ref[0]) & (brows_ref[...] >= 0)  # [1, B]
    rid_ref[0] = jnp.max(jnp.where(hit, brows_ref[...], -1))


def delta_join_pallas(keys_l, rows, bucket_keys, bucket_rows, bounds, *,
                      interpret: bool = True):
    """Dirty-spine-row partitioned probe (contract:
    kernels/ref.delta_join_ref).

    grid = (D,), one program per dirty-row slot; the ``searchsorted``
    bucket routing runs in XLA outside (it needs the key VALUE, which no
    BlockSpec index_map can see) and the kernel probes the ONE routed
    bucket pane.  Work is O(D * B) — independent of the spine size.
    This is the fused kernel's PROBE program as a standalone call (the
    chained ``OperatorBackend.join_delta`` fallback).
    """
    P, B = bucket_keys.shape
    T = keys_l.shape[0]
    D = rows.shape[0]
    safe = jnp.clip(rows, 0, T - 1)
    kd = keys_l[safe]
    b = jnp.searchsorted(bounds, kd, side="right").astype(jnp.int32) - 1
    b = jnp.clip(b, 0, P - 1)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(D,),
        in_specs=[
            pl.BlockSpec((1,), lambda i, bidx_ref: (i,)),
            # the scalar-prefetch gather: bidx[i] picks the bucket pane
            pl.BlockSpec((1, B), lambda i, bidx_ref: (bidx_ref[i], 0)),
            pl.BlockSpec((1, B), lambda i, bidx_ref: (bidx_ref[i], 0)),
        ],
        out_specs=pl.BlockSpec((1,), lambda i, bidx_ref: (i,)),
    )
    return pl.pallas_call(
        _delta_join_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((D,), jnp.int32),
        interpret=interpret,
    )(b, kd, bucket_keys, bucket_rows)

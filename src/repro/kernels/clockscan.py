"""ClockScan shared-scan kernel: evaluate ALL queries against a tuple tile.

The paper's storage layer (Crescando [28]) "indexes the queries, not the
data" and joins query predicates against tuples in one clock pass.  On TPU
this becomes a query-data outer comparison per tuple tile:

  grid            = (T // TILE_T,)
  cols block      = [C, TILE_T]   (VMEM; C = predicated columns, small)
  lo/hi blocks    = [C, Q]        (whole predicate matrix resident in VMEM —
                                   queries ARE the indexed side)
  out block       = [TILE_T, W]   packed uint32 bitmask words

Per tile: broadcast compare (VPU), AND-reduce over columns, then shift-OR
bit-pack 32 query lanes per word.  Work per tile is O(C * TILE_T * Q)
independent of selectivity or query count <= Q — bounded computation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_T = 256


def _kernel(cols_ref, lo_ref, hi_ref, valid_ref, out_ref, *, n_cols: int,
            qcap: int):
    tile = out_ref.shape[0]
    ok = jnp.ones((tile, qcap), jnp.bool_)
    for c in range(n_cols):
        x = cols_ref[c, :][:, None]                      # [Tt, 1]
        ok &= (x >= lo_ref[c, :][None, :]) & (x <= hi_ref[c, :][None, :])
    ok &= valid_ref[...][:, None]
    w = qcap // 32
    bits = ok.reshape(tile, w, 32).astype(jnp.uint32)
    weights = jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32)
    out_ref[...] = jnp.sum(bits * weights[None, None, :], axis=-1,
                           dtype=jnp.uint32)


def clockscan_pallas(cols, lo, hi, valid, *, interpret: bool = True):
    """cols int32[C,T]; lo/hi int32[C,Q]; valid bool[T] -> uint32[T,Q/32]."""
    C, T_orig = cols.shape
    Q = lo.shape[1]
    assert Q % 32 == 0
    tile = min(TILE_T, T_orig)
    pad = (-T_orig) % tile
    if pad:  # arbitrary table capacities: pad rows (invalid -> all-zero)
        cols = jnp.pad(cols, ((0, 0), (0, pad)))
        valid = jnp.pad(valid, (0, pad))
    T = T_orig + pad
    W = Q // 32
    kernel = functools.partial(_kernel, n_cols=C, qcap=Q)
    out = _call(kernel, cols, lo, hi, valid, C, T, Q, W, tile, interpret)
    return out[:T_orig]


def _call(kernel, cols, lo, hi, valid, C, T, Q, W, tile, interpret):
    return pl.pallas_call(
        kernel,
        grid=(T // tile,),
        in_specs=[
            pl.BlockSpec((C, tile), lambda i: (0, i)),
            pl.BlockSpec((C, Q), lambda i: (0, 0)),
            pl.BlockSpec((C, Q), lambda i: (0, 0)),
            pl.BlockSpec((tile,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((tile, W), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((T, W), jnp.uint32),
        interpret=interpret,
    )(cols, lo, hi, valid)

"""Partitioned shared join kernel: probe fixed-capacity range buckets
instead of the whole right side (the O(Tl*Tr) -> O(Tl*Tr/P) upgrade of
kernels/bitmask_join.py for index-less PK tables).

The right side is pre-partitioned once per heartbeat at update-apply time
(storage.build_key_partitions): valid rows sorted by key, split into P
contiguous buckets of exactly B = bucket_cap entries — a range radix on
the sorted key order, so no bucket can overflow and the join stays exact
for any key distribution.  The probe has two parts:

  1. bucket routing + gather (XLA): each left key finds its ONE candidate
     bucket via searchsorted over the P bucket bounds, and that bucket's
     keys/rows are gathered to [Tl, B] candidate panes — TPU-native
     dynamic slicing, shared verbatim with the jnp reference path.
  2. the match reduction (THIS kernel): grid over (left-tile, bucket
     chunk); each program compares a left tile against one chunk of its
     rows' candidate panes and accumulates the matched right row id by
     max — identical accumulation to bitmask_join's right-tile loop, but
     over B candidates per row instead of Tr.

The bitmask intersection (mask_l & mask_r[rid] — the paper's amended
``R.query_id = S.query_id`` join predicate) is a single O(Tl) gather once
rid is known, shared by both backends.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_L = 256
TILE_B = 256


def _kernel(keys_l_ref, cand_keys_ref, cand_rows_ref, rid_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        rid_ref[...] = jnp.full_like(rid_ref, -1)

    keys_l = keys_l_ref[...]                          # [Tl]
    hit = (cand_keys_ref[...] == keys_l[:, None]) \
        & (cand_rows_ref[...] >= 0)
    cand = jnp.max(jnp.where(hit, cand_rows_ref[...], -1), axis=1)
    rid_ref[...] = jnp.maximum(rid_ref[...], cand)


def partitioned_join_pallas(keys_l, mask_l, bucket_keys, bucket_rows,
                            bounds, mask_r, *, interpret: bool = True):
    """Same contract as kernels/ref.partitioned_join_ref."""
    P, B = bucket_keys.shape
    Tl_orig = keys_l.shape[0]
    b = jnp.searchsorted(bounds, keys_l, side="right").astype(jnp.int32) - 1
    b = jnp.clip(b, 0, P - 1)
    cand_keys = bucket_keys[b]                        # [Tl, B]
    cand_rows = bucket_rows[b]
    # pad to tile multiples: padded candidates carry row -1 (never a hit),
    # padded left rows are sliced off — mirrors bitmask_join's padding
    tl = min(TILE_L, max(Tl_orig, 1))
    tb = min(TILE_B, max(B, 1))
    pad_l = (-Tl_orig) % tl
    pad_b = (-B) % tb
    if pad_l:
        keys_l = jnp.pad(keys_l, (0, pad_l))
        cand_keys = jnp.pad(cand_keys, ((0, pad_l), (0, 0)))
        cand_rows = jnp.pad(cand_rows, ((0, pad_l), (0, 0)),
                            constant_values=-1)
    if pad_b:
        cand_keys = jnp.pad(cand_keys, ((0, 0), (0, pad_b)))
        cand_rows = jnp.pad(cand_rows, ((0, 0), (0, pad_b)),
                            constant_values=-1)
    Tl, Bp = Tl_orig + pad_l, B + pad_b
    rid = pl.pallas_call(
        _kernel,
        grid=(Tl // tl, Bp // tb),
        in_specs=[
            pl.BlockSpec((tl,), lambda i, j: (i,)),
            pl.BlockSpec((tl, tb), lambda i, j: (i, j)),
            pl.BlockSpec((tl, tb), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((tl,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((Tl,), jnp.int32),
        interpret=interpret,
    )(keys_l, cand_keys, cand_rows)
    rid = rid[:Tl_orig]
    safe = jnp.clip(rid, 0, mask_r.shape[0] - 1)
    combined = jnp.where((rid >= 0)[:, None], mask_l & mask_r[safe],
                         jnp.uint32(0))
    return rid, combined

"""Shared block join kernel: key-equality outer compare fused with
query-set intersection (the paper's shared join, §3.3).

  grid = (T_left // TILE_L, T_right // TILE_R)   (right tiles innermost —
                                                  sequential reduction)
  blocks: keys_l [TILE_L], mask_l [TILE_L, W],
          keys_r [TILE_R], mask_r [TILE_R, W], valid_r [TILE_R]
  outs:   rid    [TILE_L]        matched right row (-1 = none)
          out    [TILE_L, W]     mask_l & mask_r[match]

Inner tile computes eq = keys_l x keys_r outer equality, then accumulates
  mask  += eq @ mask_r      (unique right keys => sum == the single match;
                             an integer contraction — MXU-adjacent)
  rid   = max(rid, eq * (row+1))
The final right tile ANDs in mask_l and converts rid to -1-based.  The
query-set intersection here IS the amended join predicate
``R.query_id = S.query_id`` of the paper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_L = 256
TILE_R = 256


def _kernel(keys_l_ref, mask_l_ref, keys_r_ref, mask_r_ref, valid_r_ref,
            rid_ref, out_ref, *, n_right_tiles: int, tile_r: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        rid_ref[...] = jnp.zeros_like(rid_ref)
        out_ref[...] = jnp.zeros_like(out_ref)

    keys_l = keys_l_ref[...]                         # [Tl]
    keys_r = keys_r_ref[...]                         # [Tr]
    eq = (keys_l[:, None] == keys_r[None, :]) & valid_r_ref[...][None, :]
    eq_u = eq.astype(jnp.uint32)
    # sum over the (unique-key) match: [Tl, Tr] x [Tr, W] contraction
    acc = jnp.einsum("lr,rw->lw", eq_u, mask_r_ref[...])
    out_ref[...] = out_ref[...] | acc.astype(jnp.uint32)
    base = j * tile_r
    rows = base + jnp.arange(keys_r.shape[0], dtype=jnp.int32) + 1
    cand = jnp.max(jnp.where(eq, rows[None, :], 0), axis=1)
    rid_ref[...] = jnp.maximum(rid_ref[...], cand)

    @pl.when(j == n_right_tiles - 1)
    def _finalize():
        matched = rid_ref[...] > 0
        out_ref[...] = jnp.where(matched[:, None],
                                 out_ref[...] & mask_l_ref[...],
                                 jnp.uint32(0))
        rid_ref[...] = rid_ref[...] - 1


def bitmask_join_pallas(keys_l, mask_l, keys_r, mask_r, valid_r, *,
                        interpret: bool = True):
    Tl_orig, W = mask_l.shape
    Tr_orig = keys_r.shape[0]
    # arbitrary table capacities: pad to tile multiples (padded right rows
    # are invalid so they can never match; padded left rows are sliced
    # off), matching clockscan/shared_groupby's internal padding
    pad_l = (-Tl_orig) % min(TILE_L, max(Tl_orig, 1))
    pad_r = (-Tr_orig) % min(TILE_R, max(Tr_orig, 1))
    if pad_l:
        keys_l = jnp.pad(keys_l, (0, pad_l))
        mask_l = jnp.pad(mask_l, ((0, pad_l), (0, 0)))
    if pad_r:
        keys_r = jnp.pad(keys_r, (0, pad_r))
        mask_r = jnp.pad(mask_r, ((0, pad_r), (0, 0)))
        valid_r = jnp.pad(valid_r, (0, pad_r))
    Tl, Tr = Tl_orig + pad_l, Tr_orig + pad_r
    tl, tr = min(TILE_L, Tl), min(TILE_R, Tr)
    kernel = functools.partial(_kernel, n_right_tiles=Tr // tr, tile_r=tr)
    rid, mask = pl.pallas_call(
        kernel,
        grid=(Tl // tl, Tr // tr),
        in_specs=[
            pl.BlockSpec((tl,), lambda i, j: (i,)),
            pl.BlockSpec((tl, W), lambda i, j: (i, 0)),
            pl.BlockSpec((tr,), lambda i, j: (j,)),
            pl.BlockSpec((tr, W), lambda i, j: (j, 0)),
            pl.BlockSpec((tr,), lambda i, j: (j,)),
        ],
        out_specs=[
            pl.BlockSpec((tl,), lambda i, j: (i,)),
            pl.BlockSpec((tl, W), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Tl,), jnp.int32),
            jax.ShapeDtypeStruct((Tl, W), jnp.uint32),
        ],
        interpret=interpret,
    )(keys_l, mask_l, keys_r, mask_r, valid_r)
    return rid[:Tl_orig], mask[:Tl_orig]

"""Shared relational operators over the data-query model (paper §3.3-3.4).

Every operator processes the UNION of tuples needed by all concurrent
queries exactly once, carrying the packed query bitmask.  Worst-case work is
a function of table capacity only — never of the number of queries — which
is the bounded-computation property behind the paper's SLA guarantees.

The hot loops (shared scan, shared join, shared group-by) have Pallas TPU
kernels in repro.kernels; these jnp implementations are both the CPU
execution path and the kernels' oracles.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import dataquery as dq

INT_MIN = -2147483647
INT_MAX = 2147483647


# ---------------------------------------------------------------------------
# Shared scan — ClockScan (query-data join): index the queries, not the data
# ---------------------------------------------------------------------------


def shared_scan(cols, lo, hi, valid):
    """Evaluate ALL queries' conjunctive range predicates in one pass.

    cols:  int32[C, T]  predicated column values
    lo,hi: int32[C, Q]  per-query inclusive bounds (full range = no pred;
                        queries not scanning this table use [1, 0] = fail)
    valid: bool[T]      live rows
    Returns packed bitmask uint32[T, Q/32].
    """
    from repro.kernels import ops as kops
    return kops.clockscan(cols, lo, hi, valid)


def shared_scan_ref(cols, lo, hi, valid):
    C, T = cols.shape
    ok = jnp.ones((T, lo.shape[1]), bool)
    for c in range(C):
        x = cols[c][:, None]
        ok &= (x >= lo[c][None, :]) & (x <= hi[c][None, :])
    ok &= valid[:, None]
    return dq.pack(ok)


# ---------------------------------------------------------------------------
# Shared join — one big join; query-set intersection == query_id predicate
# ---------------------------------------------------------------------------


def shared_join_fk(fk, left_mask, pk_index, right_mask):
    """PK-FK shared join (the paper's >< with query_id in the predicate).

    fk:         int32[T_l] foreign key of the left (spine) relation
    left_mask:  uint32[T_l, W]
    pk_index:   int32[K]  dense key -> right row (-1 absent)
    right_mask: uint32[T_r, W]
    Returns (right_row int32[T_l]  (-1 = no match),
             combined mask uint32[T_l, W] = left & right[match]).
    """
    K = pk_index.shape[0]
    safe_fk = jnp.clip(fk, 0, K - 1)
    r = jnp.where((fk >= 0) & (fk < K), pk_index[safe_fk], -1)
    gathered = right_mask[jnp.clip(r, 0, right_mask.shape[0] - 1)]
    combined = jnp.where((r >= 0)[:, None], left_mask & gathered,
                         jnp.uint32(0))
    return r, combined


def shared_join_block_ref(keys_l, mask_l, keys_r, mask_r, valid_r):
    """Block nested-loop shared join oracle (general equality keys with
    UNIQUE right keys).  Mirrors kernels/bitmask_join.py.

    Returns (matched right row per left row (-1 none), combined mask).
    """
    eq = (keys_l[:, None] == keys_r[None, :]) & valid_r[None, :]
    eqi = eq.astype(jnp.uint32)
    # unique right keys: sum over matches == the single match
    combined = mask_l & (eqi @ mask_r)
    rid = (eq.astype(jnp.int32)
           @ (jnp.arange(keys_r.shape[0], dtype=jnp.int32) + 1)) - 1
    return rid, jnp.where((rid >= 0)[:, None], combined, jnp.uint32(0))


# ---------------------------------------------------------------------------
# Union compression: extract the tuples at least one query wants.
#
# The paper's shared operators process "the union of all R and S tuples that
# the queries are interested in" (Fig. 3/4) — NOT the whole table.  The
# union is extracted with a BOUNDED capacity (bounded computation, §3.5):
# per-cycle work stays a static function of the cap; overflow beyond the
# cap is reported, never silently mis-answered (rows past the cap are
# dropped deterministically from the tail).
# ---------------------------------------------------------------------------


def compress_union(mask, cap: int):
    """Returns (row_idx int32[cap] (-1 pad), cmask uint32[cap, W],
    n_wanted int32 — observability: n_wanted > cap means overflow)."""
    T = mask.shape[0]
    wanted = dq.any_query(mask)
    n_wanted = jnp.sum(wanted.astype(jnp.int32))
    idx = jnp.nonzero(wanted, size=cap, fill_value=T)[0]
    safe = jnp.minimum(idx, T - 1).astype(jnp.int32)
    live = idx < T
    cmask = jnp.where(live[:, None], mask[safe], jnp.uint32(0))
    rows = jnp.where(live, safe, -1).astype(jnp.int32)
    return rows, cmask, n_wanted


# ---------------------------------------------------------------------------
# Shared sort + per-query Top-N (paper Fig. 4)
# ---------------------------------------------------------------------------


def shared_sort(sort_key, mask, descending: bool = False):
    """ONE sort over the union of interested tuples; bitmask rides along.

    Rows wanted by nobody sort to the end.  Returns (perm, sorted_mask).
    """
    wanted = dq.any_query(mask)
    key = jnp.where(wanted, sort_key, INT_MAX)
    if descending:
        key = jnp.where(wanted, -sort_key, INT_MAX)
    perm = jnp.argsort(key, stable=True)
    return perm, mask[perm]


def shared_topn(sorted_mask, n_per_query):
    """Phase 2 of shared Top-N: per-query rank filter (cheap, per query).

    sorted_mask: uint32[T, W] in sort order; n_per_query: int32[Q].
    Returns filtered mask keeping each query's first n bits.
    """
    bits = dq.unpack(sorted_mask)                    # [T, Q]
    rank = jnp.cumsum(bits.astype(jnp.int32), axis=0)
    keep = bits & (rank <= n_per_query[None, :])
    return dq.pack(keep)


# ---------------------------------------------------------------------------
# Shared group-by — aggregation as MXU matmul
# ---------------------------------------------------------------------------


def shared_groupby(group_code, values, mask, n_groups: int):
    """Phase-1 grouping + per-query aggregates for ALL queries at once.

    group_code: int32[T] in [0, n_groups)  (e.g. dict-encoded column)
    values:     int32[T] aggregation operand
    mask:       uint32[T, W]
    Returns (count f32[G, Q], sum f32[G, Q]).

    TPU mapping: one-hot(group)^T @ unpacked-mask is a dense contraction —
    the MXU computes "all groups x all queries" in a single pass.  See
    kernels/shared_groupby.py for the tiled Pallas version.
    """
    from repro.kernels import ops as kops
    return kops.shared_groupby(group_code, values, mask, n_groups)


def shared_groupby_ref(group_code, values, mask, n_groups: int):
    bits = dq.unpack(mask).astype(jnp.float32)       # [T, Q]
    onehot = jax.nn.one_hot(group_code, n_groups, dtype=jnp.float32)
    count = onehot.T @ bits
    ssum = onehot.T @ (bits * values[:, None].astype(jnp.float32))
    return count, ssum


# ---------------------------------------------------------------------------
# Result routing (the paper's Gamma operator): top-R row ids per query
# ---------------------------------------------------------------------------


def route_topn(mask_in_order, n_per_query, max_results: int, rows=None):
    """Fused shared Top-N + result routing: ONE unpack + cumsum pass.

    mask_in_order: uint32[K, W] in output order (typically the compressed
    union, post-sort); rows: int32[K] storage row ids (-1 invalid; default
    the positional index); n_per_query: int32[W*32].
    Returns int32[Q, max_results] row ids (-1 padded).
    """
    K, W = mask_in_order.shape
    Q = W * dq.WORD
    bits = dq.unpack(mask_in_order)                  # [K, Q]
    if rows is None:
        rows = jnp.arange(K, dtype=jnp.int32)
    bits &= (rows >= 0)[:, None]
    rank = jnp.cumsum(bits.astype(jnp.int32), axis=0) - 1
    keep = bits & (rank < jnp.minimum(n_per_query, max_results)[None, :])
    # at most Q*max_results entries survive: compress before scattering
    # (scatters are serial-ish on CPU; keep them tiny)
    flat = jnp.nonzero(keep.reshape(-1), size=Q * max_results,
                       fill_value=K * Q)[0]
    safe = jnp.minimum(flat, K * Q - 1)
    live = flat < K * Q
    k_idx = safe // Q
    q_idx = jnp.where(live, safe % Q, Q)
    slot = jnp.where(live, rank.reshape(-1)[safe], max_results)
    out = jnp.full((Q, max_results), -1, jnp.int32)
    out = out.at[q_idx, slot].set(rows[k_idx], mode="drop")
    return out


def route_results(mask_in_order, max_results: int, perm=None):
    """Per query: first `max_results` row ids whose bit is set, in order.

    mask_in_order: uint32[T, W] (already in output order, e.g. post-sort).
    perm: optional int32[T] mapping positions back to storage row ids.
    Returns int32[Q, max_results] row ids (-1 padded).
    """
    T, W = mask_in_order.shape
    Q = W * dq.WORD
    bits = dq.unpack(mask_in_order)                  # [T, Q]
    rank = jnp.cumsum(bits.astype(jnp.int32), axis=0) - 1
    rows = jnp.arange(T, dtype=jnp.int32)
    if perm is not None:
        rows = perm.astype(jnp.int32)
    out = jnp.full((Q, max_results), -1, jnp.int32)
    q_idx = jnp.broadcast_to(jnp.arange(Q)[None, :], (T, Q))
    slot = jnp.where(bits & (rank < max_results), rank, max_results)
    out = out.at[q_idx.reshape(-1),
                 slot.reshape(-1)].set(
        jnp.broadcast_to(rows[:, None], (T, Q)).reshape(-1), mode="drop")
    return out

"""SharedDB core: batched shared-computation query engine (the paper).

Layers:
  dataquery   — NF2 data-query model as packed query bitmasks (TPU: VPU ops)
  storage     — columnar tables, functional MVCC snapshots, key indexes
  operators   — shared scan / join / sort / top-n / group-by
  plan        — global query plan (DAG), template merging (Fig. 3)
  lowering    — plan -> staged operator graph IR (windows, masks, caps)
  backends    — operator backend registry: jnp reference vs Pallas kernels
  executor    — pipelined dispatch/collect heartbeats over the jitted plan
  sharding    — mesh-aware heartbeats: row-sharded spines/carries,
                replicated probe sides, shard-local delta beats
  baseline    — query-at-a-time executor ("SystemX" stand-in)
  sla         — bounded-computation / response-time provisioning (§3.5)
"""

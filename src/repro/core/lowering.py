"""Lowering: CompiledPlan -> explicit staged operator graph (the IR).

``compile_plan`` (plan.py) performs the paper's *logical* optimization:
predicate pushdown and operator merging across templates (Fig. 2/3).
This module performs the *physical* lowering: it turns the merged plan
into an explicit pipeline of stages

    update-apply -> shared scans -> shared joins
                 -> shared sorts / group-bys -> result routing

with every piece of static metadata — per-node word windows, subscriber
bitmasks, slot layouts, bounded union caps, per-query limit vectors —
computed HERE, at lowering time, instead of inside the traced closure.
The lowered graph is inspectable (``LoweredPlan.stages()``), and executing
it is a mechanical walk that delegates each hot loop to an operator
backend (backends.py): the jnp reference ops or the Pallas TPU kernels.

Join access paths are chosen at lowering time, per node:

  * ``gather`` — the PK table maintains a dense key->row index
    (storage.py), so the shared PK-FK join is an O(1) gather per spine
    row.  This is the TPU-native replacement for the paper's hash join
    and needs no kernel; both backends share it.
  * ``block``  — no dense index (schema.key_space == 0): the shared join
    runs as a blocked key-equality kernel fused with query-set
    intersection (kernels/bitmask_join.py on the Pallas backend).

Per-cycle work remains a static function of table/slot capacities — the
bounded-computation property (§3.5) — because every shape below is fixed
at lowering time.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import operators as ops
from repro.core.backends import OperatorBackend
from repro.core.plan import CompiledPlan, GroupAgg

INT_MIN = ops.INT_MIN
INT_MAX = ops.INT_MAX

# (template, q_offset_in_window, slot_capacity)
SlotRange = Tuple[str, int, int]


# ---------------------------------------------------------------------------
# Stage IR
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ScanStage:
    """One ClockScan pass over a base table for ALL referencing queries."""
    table: str
    cols: Tuple[str, ...]
    wlo: int                                  # word window [wlo, whi)
    whi: int
    slots: Tuple[SlotRange, ...]              # referencing templates
    # (template, col_idx, param_idx, q_offset_in_window, cap)
    bindings: Tuple[Tuple[str, int, int, int, int], ...]

    @property
    def q_window(self) -> int:
        return (self.whi - self.wlo) * 32


@dataclasses.dataclass(frozen=True)
class JoinStage:
    """One shared PK-FK join per (spine, fk, pk) signature."""
    spine: str
    fk_col: str
    pk_table: str
    kind: str                                 # "gather" | "block"
    pk_col: str                               # key column on the PK side
    sub_mask: np.ndarray                      # uint32[W] subscriber words


@dataclasses.dataclass(frozen=True)
class SortStage:
    """Shared sort over the bounded union + fused per-query top-n."""
    spine: str
    col: str
    desc: bool
    wlo: int
    whi: int
    sub_mask: np.ndarray                      # uint32[whi-wlo], window-local
    union_cap: int
    slots: Tuple[SlotRange, ...]


@dataclasses.dataclass(frozen=True)
class GroupStage:
    """Shared group-by: phase 1 over the union, phase 2 per query."""
    spine: str
    agg: GroupAgg
    wlo: int
    whi: int
    union_cap: int
    slots: Tuple[SlotRange, ...]


@dataclasses.dataclass(frozen=True)
class RouteStage:
    """Natural-order routing for unsorted templates, one pass per spine."""
    spine: str
    wlo: int
    whi: int
    sub_mask: np.ndarray                      # uint32[whi-wlo], window-local
    union_cap: int
    slots: Tuple[SlotRange, ...]


@dataclasses.dataclass(frozen=True)
class LoweredPlan:
    plan: CompiledPlan
    qcap: int
    W: int
    scans: Tuple[ScanStage, ...]
    joins: Tuple[JoinStage, ...]
    sorts: Tuple[SortStage, ...]
    groups: Tuple[GroupStage, ...]
    routes: Tuple[RouteStage, ...]
    limits: np.ndarray                        # int32[qcap] per-slot top-n

    def stages(self) -> Iterator[Tuple[str, object]]:
        """The staged execution order (the IR, for inspection/debug)."""
        for s in self.scans:
            yield "scan", s
        for j in self.joins:
            yield "join", j
        for s in self.sorts:
            yield "sort", s
        for g in self.groups:
            yield "group", g
        for r in self.routes:
            yield "route", r


# ---------------------------------------------------------------------------
# Lowering
# ---------------------------------------------------------------------------


def _slot_ranges(plan: CompiledPlan, names: List[str],
                 base: int) -> Tuple[SlotRange, ...]:
    return tuple((n, plan.offsets[n] - base, plan.caps[n]) for n in names)


def lower_plan(plan: CompiledPlan) -> LoweredPlan:
    cat = plan.catalog
    W = plan.qcap // 32

    scans = []
    for table, node in plan.scans.items():
        wlo, whi = plan.word_range(node.referencing)
        base = wlo * 32
        bindings = tuple(
            (name, col_idx, param_idx, plan.offsets[name] - base,
             plan.caps[name])
            for name, col_idx, param_idx in node.bindings)
        scans.append(ScanStage(
            table=table, cols=tuple(node.cols), wlo=wlo, whi=whi,
            slots=_slot_ranges(plan, node.referencing, base),
            bindings=bindings))

    joins = []
    for j in plan.joins:
        schema = cat.schemas[j.pk_table]
        if schema.pk is None:
            raise ValueError(
                f"join {j.spine}->{j.pk_table}: PK table has no key column")
        kind = "gather" if schema.key_space > 0 else "block"
        joins.append(JoinStage(
            spine=j.spine, fk_col=j.fk_col, pk_table=j.pk_table,
            kind=kind, pk_col=schema.pk,
            sub_mask=plan.sub_mask(j.subscribers)))

    sorts = []
    for s in plan.sorts:
        wlo, whi = plan.word_range(s.subscribers)
        T = cat.schemas[s.spine].capacity
        sorts.append(SortStage(
            spine=s.spine, col=s.col, desc=s.desc, wlo=wlo, whi=whi,
            sub_mask=plan.sub_mask(s.subscribers)[wlo:whi],
            union_cap=min(T, plan.union_cap),
            slots=_slot_ranges(plan, s.subscribers, wlo * 32)))

    groups = []
    for g in plan.groups:
        wlo, whi = plan.word_range(g.subscribers)
        T = cat.schemas[g.spine].capacity
        groups.append(GroupStage(
            spine=g.spine, agg=g.agg, wlo=wlo, whi=whi,
            union_cap=min(T, plan.group_union_cap),
            slots=_slot_ranges(plan, g.subscribers, wlo * 32)))

    routed = {name for st in sorts + groups for name, _, _ in st.slots}
    by_spine: Dict[str, List[str]] = {}
    for name, t in plan.templates.items():
        if name not in routed:
            by_spine.setdefault(t.spine, []).append(name)
    routes = []
    for spine, names in by_spine.items():
        wlo, whi = plan.word_range(names)
        T = cat.schemas[spine].capacity
        routes.append(RouteStage(
            spine=spine, wlo=wlo, whi=whi,
            sub_mask=plan.sub_mask(names)[wlo:whi],
            union_cap=min(T, plan.union_cap),
            slots=_slot_ranges(plan, names, wlo * 32)))

    limits = np.ones(plan.qcap, np.int32)
    for name, t in plan.templates.items():
        o, c = plan.offsets[name], plan.caps[name]
        limits[o:o + c] = min(t.limit, plan.max_results)

    return LoweredPlan(
        plan=plan, qcap=plan.qcap, W=W,
        scans=tuple(scans), joins=tuple(joins), sorts=tuple(sorts),
        groups=tuple(groups), routes=tuple(routes), limits=limits)


# ---------------------------------------------------------------------------
# Executing the lowered graph: one heartbeat of the always-on plan
# ---------------------------------------------------------------------------


def build_cycle(lowered: LoweredPlan, backend: OperatorBackend):
    """Returns cycle(storage, queries, updates) -> (storage', results).

    queries: {template: {"params": int32[cap, n_preds, 2],
                          "active": bool[cap]}}
    updates: {table: update batch dict (see storage.empty_update_batch)}
    results: per template row-id matrices / group top-k; all fixed shapes,
    plus "_overflow" (union-cap overflow count) and "_join_rids".
    """
    from repro.core.storage import apply_updates

    plan = lowered.plan
    cat = plan.catalog
    W = lowered.W
    limits = jnp.asarray(lowered.limits)
    join_subs = [jnp.asarray(j.sub_mask) for j in lowered.joins]
    sort_subs = [jnp.asarray(s.sub_mask) for s in lowered.sorts]
    route_subs = [jnp.asarray(r.sub_mask) for r in lowered.routes]

    def cycle(storage, queries, updates):
        # 1. apply updates in arrival order (cycle-consistent snapshot)
        storage = dict(storage)
        for table, batch in updates.items():
            storage[table] = apply_updates(cat.schemas[table],
                                           storage[table], batch)

        # 2. shared scans (ClockScan): one pass per table for ALL queries,
        #    each touching only its subscribers' word window.
        scan_masks = {}
        for st in lowered.scans:
            tbl = storage[st.table]
            C = max(len(st.cols), 1)
            T = cat.schemas[st.table].capacity
            q_sub = st.q_window
            lo = jnp.full((C, q_sub), INT_MAX, jnp.int32)  # default: fail
            hi = jnp.full((C, q_sub), INT_MIN, jnp.int32)
            # referencing templates: default pass-all on their active slots
            for name, o, c in st.slots:
                act = queries[name]["active"]
                lo = lo.at[:, o:o + c].set(
                    jnp.where(act[None, :], INT_MIN, INT_MAX))
                hi = hi.at[:, o:o + c].set(
                    jnp.where(act[None, :], INT_MAX, INT_MIN))
            # bound predicated columns from query params
            for name, col_idx, param_idx, o, c in st.bindings:
                act = queries[name]["active"]
                p = queries[name]["params"][:, param_idx]     # [cap, 2]
                lo = lo.at[col_idx, o:o + c].set(
                    jnp.where(act, p[:, 0], INT_MAX))
                hi = hi.at[col_idx, o:o + c].set(
                    jnp.where(act, p[:, 1], INT_MIN))
            cols = (jnp.stack([tbl[c] for c in st.cols])
                    if st.cols else jnp.zeros((1, T), jnp.int32))
            m = backend.scan(cols, lo, hi, tbl["_valid"])
            scan_masks[st.table] = jnp.pad(m, ((0, 0),
                                               (st.wlo, W - st.whi)))

        # 3. shared joins: ONE big join per signature, query_id in the
        #    predicate via bitmask intersection; non-subscribers pass
        #    through untouched.
        spine_masks = dict(scan_masks)
        join_rids = {}
        for st, sub in zip(lowered.joins, join_subs):
            tbl = storage[st.spine]
            m = spine_masks[st.spine]
            if st.kind == "gather":
                rid, combined = ops.shared_join_fk(
                    tbl[st.fk_col], m,
                    storage[st.pk_table]["_pk_index"],
                    scan_masks[st.pk_table])
            else:  # block: key-equality kernel, no dense index
                pk_tbl = storage[st.pk_table]
                rid, combined = backend.join_block(
                    tbl[st.fk_col], m, pk_tbl[st.pk_col],
                    scan_masks[st.pk_table], pk_tbl["_valid"])
            spine_masks[st.spine] = (combined & sub[None, :]) \
                | (m & ~sub[None, :])
            join_rids[(st.spine, st.fk_col, st.pk_table)] = rid

        # 4. shared sorts + fused per-query top-n + routing (Gamma): the
        #    sort runs over the bounded UNION of tuples wanted by the
        #    node's subscribers (Fig. 4); overflow past the cap is counted.
        results = {}
        overflow = jnp.zeros((), jnp.int32)
        for st, sub in zip(lowered.sorts, sort_subs):
            mask = spine_masks[st.spine][:, st.wlo:st.whi] & sub[None, :]
            rows_c, cmask, n_want = ops.compress_union(mask, st.union_cap)
            overflow += jnp.maximum(n_want - st.union_cap, 0)
            keys = storage[st.spine][st.col][jnp.maximum(rows_c, 0)]
            keys = jnp.where(rows_c >= 0,
                             -keys if st.desc else keys, ops.INT_MAX)
            perm = jnp.argsort(keys, stable=True)
            rows = ops.route_topn(cmask[perm],
                                  limits[st.wlo * 32:st.whi * 32],
                                  plan.max_results, rows=rows_c[perm])
            for name, o, c in st.slots:
                results[name] = {"rows": rows[o:o + c]}

        # 5. shared group-bys (phase 1 shared over the union, phase 2 per
        #    query)
        for st in lowered.groups:
            agg = st.agg
            tbl = storage[st.spine]
            rows_c, cmask, n_want = ops.compress_union(
                spine_masks[st.spine][:, st.wlo:st.whi], st.union_cap)
            overflow += jnp.maximum(n_want - st.union_cap, 0)
            safe = jnp.maximum(rows_c, 0)
            gcodes = jnp.where(rows_c >= 0, tbl[agg.group_col][safe], 0)
            gvals = jnp.where(rows_c >= 0, tbl[agg.agg_col][safe], 0)
            count, ssum = backend.groupby(gcodes, gvals, cmask,
                                          agg.n_groups)
            score = ssum if agg.order_by == "sum" else count
            top_val, top_grp = jax.lax.top_k(score.T, agg.top_k)  # [q, K]
            for name, o, c in st.slots:
                results[name] = {
                    "groups": top_grp[o:o + c].astype(jnp.int32),
                    "scores": top_val[o:o + c],
                    "counts": jnp.take_along_axis(
                        count.T[o:o + c], top_grp[o:o + c], axis=1)}

        # 6. unsorted templates route in natural row order — ONE routing
        #    pass per spine shared by all such templates
        for st, sub in zip(lowered.routes, route_subs):
            mask = spine_masks[st.spine][:, st.wlo:st.whi] & sub[None, :]
            rows_c, cmask, n_want = ops.compress_union(mask, st.union_cap)
            overflow += jnp.maximum(n_want - st.union_cap, 0)
            rows = ops.route_topn(cmask, limits[st.wlo * 32:st.whi * 32],
                                  plan.max_results, rows=rows_c)
            for name, o, c in st.slots:
                results[name] = {"rows": rows[o:o + c]}
        results["_overflow"] = overflow

        # attach join rids so hosts can materialize joined tuples
        results["_join_rids"] = join_rids
        return storage, results

    return cycle

"""Lowering: CompiledPlan -> explicit staged operator graph (the IR).

``compile_plan`` (plan.py) performs the paper's *logical* optimization:
predicate pushdown and operator merging across templates (Fig. 2/3).
This module performs the *physical* lowering: it turns the merged plan
into an explicit pipeline of stages

    update-apply -> shared scans -> shared joins
                 -> shared sorts / group-bys -> result routing

with every piece of static metadata — per-node word windows, subscriber
bitmasks, slot layouts, bounded union caps, per-query limit vectors —
computed HERE, at lowering time, instead of inside the traced closure.
The lowered graph is inspectable (``LoweredPlan.stages()``), and executing
it is a mechanical walk that delegates each hot loop to an operator
backend (backends.py): the jnp reference ops or the Pallas TPU kernels.

Join access paths are chosen at lowering time, per node, from table
capacities:

  * ``gather``      — the PK table maintains a dense key->row index
    (storage.py), so the shared PK-FK join is an O(1) gather per spine
    row.  This is the TPU-native replacement for the paper's hash join
    and needs no kernel; both backends share it.
  * ``partitioned`` — no dense index but a large table: the PK side is
    range-partitioned into fixed-capacity buckets once per heartbeat at
    update-apply time (storage.build_key_partitions) and each spine row
    probes exactly one bucket — O(Tl*Tr/P) instead of O(Tl*Tr)
    (kernels/partitioned_join.py on the Pallas backend).
  * ``block``       — no dense index and a small table
    (< PARTITIONED_MIN_CAPACITY rows): the dense blocked key-equality
    kernel fused with query-set intersection (kernels/bitmask_join.py);
    partitioning overhead is not worth it at this size.

Scan predicate binding is likewise precomputed: each ScanStage carries
static gather index arrays (``covered``, ``param_idx``) built ONCE here,
so the traced cycle binds a stage's whole lo/hi predicate matrix from the
packed admission buffers with one vectorized op — no per-template python
scatter loops on the hot path, regardless of template count.

Scans are also INCREMENTAL: ``build_cycle`` returns each predicated
stage's window-local bitmask words as a carry, and ``build_delta_cycle``
consumes that carry to re-evaluate only (changed admission word columns)
∪ (the update batch's dirty rows, storage.apply_updates) per heartbeat —
steady-state scan cost drops from O(rows × queries) to
O(rows × changed_slots + dirty × queries).  The executor picks the
flavour host-side per heartbeat and falls back to the full rescan when
the deltas overflow their fixed capacities.

JOINS are incremental too.  The heartbeat carry is widened from scan
words to (scan words, key partitions) plus the per-join rid arrays the
executor threads from ``results["_join_rids"]``: a JoinStage's rid
vector is a pure function of (the spine's fk column, the PK table's
keys/validity) — query admission only changes the MASKS, never the
rids — so on a heartbeat where the PK side was untouched, the carried
rids stay exact for every spine row outside the update batch's dirty
set.  ``build_delta_cycle(..., delta_joins=True)`` re-probes ONLY the
dirty spine rows (``backend.join_delta`` / kernels/fused_delta.py for
partitioned stages, a dense dirty-row probe for block stages) and merges
them into the carried rid array with the same sorted-scatter fast path
as delta scans.  The executor falls back to the full probe — within the
delta-scan cycle, via the ``delta_joins=False`` flavour — whenever a PK
table was written this heartbeat (its partitions rebuild, see
storage.refresh_key_partitions), a dirty set overflowed, or no rid
carry exists yet (first heartbeat / post-relayout), and the full-rescan
cycle reseeds BOTH carry halves.  The O(1) gather joins carry nothing:
the index gather is already cheaper than any merge.

Per-cycle work remains a static function of table/slot capacities — the
bounded-computation property (§3.5) — because every shape below is fixed
at lowering time.

The lowered stage graph is also the input to the MESH-AWARE lowering in
core/sharding.py: ``build_sharded_cycle`` / ``build_sharded_delta_cycle``
re-thread the same stages through a ``shard_map`` over a row mesh
(row-sharded spines and carries, replicated probe sides), reusing this
module's predicate binding and post-scan verbatim — a 1-shard mesh is
bit-identical to the cycles built here.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import operators as ops
from repro.core.backends import OperatorBackend
from repro.core.plan import CompiledPlan, GroupAgg

INT_MIN = ops.INT_MIN
INT_MAX = ops.INT_MAX

# join access-path thresholds: an index-less PK table below the minimum
# capacity runs the dense block kernel; at or above it, the bucketed
# partitioned probe (bucket capacity targets one lane-friendly tile)
PARTITIONED_MIN_CAPACITY = 512
PARTITION_BUCKET_CAP = 256

# incremental scans: each stage's admission pane covers a CONTIGUOUS
# range of window_words / DELTA_PANE_DIVISOR words (min 1).  The pane is
# a static shape, paid on every delta heartbeat, so it trades
# steady-state cost against how much admission churn still qualifies for
# the delta path; a contiguous range (rather than scattered words) keeps
# the merge an in-place dynamic_update_slice on the donated carry —
# scatter-style merges cost as much as the full compare on small tables.
DELTA_PANE_DIVISOR = 8

# (template, q_offset_in_window, slot_capacity)
SlotRange = Tuple[str, int, int]


def _round_up_128(x: int) -> int:
    return ((max(1, x) + 127) // 128) * 128


def partition_layout(capacity: int,
                     stats: Optional[Dict[str, int]] = None
                     ) -> Tuple[int, int]:
    """(n_partitions, bucket_cap) for a PK table of this capacity.

    With measured key ``stats`` — {"n_live": valid rows, "max_dup":
    widest duplicate-key run}, recorded from the initial snapshot at
    engine-construction time — the bucket capacity adapts to real
    occupancy instead of the static PARTITION_BUCKET_CAP heuristic: a
    sparsely loaded table gets narrower buckets (a cheaper probe pane
    for the delta/fused kernels, whose work per dirty row is O(B)), and
    a duplicate-heavy key column gets buckets at least as wide as its
    widest run.  Correctness never depends on the layout — a key run
    spanning buckets p..q resolves to bucket q, which holds the run's
    tail (the max row id), for ANY bucket_cap — so stats steer only the
    probe pane width, rounded to a 128-lane multiple.  Stats are
    measured once and baked into the JoinStage: shapes stay static, the
    bounded-computation property holds.
    """
    if stats is None:
        bucket_cap = min(PARTITION_BUCKET_CAP, capacity)
        return -(-capacity // bucket_cap), bucket_cap
    occupancy = min(1.0, max(0, int(stats.get("n_live", capacity)))
                    / capacity)
    target = _round_up_128(int(PARTITION_BUCKET_CAP * occupancy))
    bucket_cap = min(capacity,
                     max(target, _round_up_128(int(stats.get("max_dup",
                                                             1)))))
    return -(-capacity // bucket_cap), bucket_cap


# ---------------------------------------------------------------------------
# Stage IR
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ScanStage:
    """One ClockScan pass over a base table for ALL referencing queries.

    The predicate scatter plan is precomputed at lowering time: given the
    packed admission buffers (params int32[qcap, P_max, 2], active
    bool[qcap]), the stage's whole lo/hi predicate matrix binds with one
    vectorized gather — ``covered`` marks window slots belonging to a
    referencing template, ``param_idx`` maps (predicated column, window
    slot) to the packed parameter row (-1 = unbound -> pass-all when
    active).

    ``delta_words`` is the stage's admission-pane capacity on the
    incremental path (``build_delta_cycle``): the CONTIGUOUS range of
    window words whose slots may change admission between consecutive
    heartbeats and still take the delta scan.  The pane recomputes
    exactly that many adjacent word columns over all rows, so a smaller
    capacity means a cheaper steady-state heartbeat but an earlier
    fallback to the full rescan — the executor checks the changed span
    host-side before dispatch.
    """
    table: str
    cols: Tuple[str, ...]
    wlo: int                                  # word window [wlo, whi)
    whi: int
    slots: Tuple[SlotRange, ...]              # referencing templates
    covered: np.ndarray                       # bool[q_window]
    param_idx: np.ndarray                     # int32[max(C,1), q_window]
    delta_words: int = 1                      # admission-pane word cap

    @property
    def q_window(self) -> int:
        return (self.whi - self.wlo) * 32


@dataclasses.dataclass(frozen=True)
class JoinStage:
    """One shared PK-FK join per (spine, fk, pk) signature.

    Non-``gather`` stages are DELTA-ELIGIBLE: their rid vector depends
    only on the spine's fk column and the PK table's snapshot — not on
    admission — so the executor carries it across heartbeats and
    ``build_delta_cycle(delta_joins=True)`` re-probes just the dirty
    spine rows, falling back to the full probe when the PK side was
    written (partitions rebuilt) or the dirty set overflowed.
    """
    spine: str
    fk_col: str
    pk_table: str
    kind: str                                 # "gather"|"partitioned"|"block"
    pk_col: str                               # key column on the PK side
    sub_mask: np.ndarray                      # uint32[W] subscriber words
    n_partitions: int = 0                     # partitioned kind only
    bucket_cap: int = 0

    @property
    def key(self) -> Tuple[str, str, str]:
        """The stage's identity in ``results["_join_rids"]`` / rid carry."""
        return (self.spine, self.fk_col, self.pk_table)


@dataclasses.dataclass(frozen=True)
class SortStage:
    """Shared sort over the bounded union + fused per-query top-n."""
    spine: str
    col: str
    desc: bool
    wlo: int
    whi: int
    sub_mask: np.ndarray                      # uint32[whi-wlo], window-local
    union_cap: int
    slots: Tuple[SlotRange, ...]


@dataclasses.dataclass(frozen=True)
class GroupStage:
    """Shared group-by: phase 1 over the union, phase 2 per query."""
    spine: str
    agg: GroupAgg
    wlo: int
    whi: int
    union_cap: int
    slots: Tuple[SlotRange, ...]


@dataclasses.dataclass(frozen=True)
class RouteStage:
    """Natural-order routing for unsorted templates, one pass per spine."""
    spine: str
    wlo: int
    whi: int
    sub_mask: np.ndarray                      # uint32[whi-wlo], window-local
    union_cap: int
    slots: Tuple[SlotRange, ...]


@dataclasses.dataclass(frozen=True)
class LoweredPlan:
    plan: CompiledPlan
    qcap: int
    W: int
    n_params_max: int                         # packed params depth P_max
    scans: Tuple[ScanStage, ...]
    joins: Tuple[JoinStage, ...]
    sorts: Tuple[SortStage, ...]
    groups: Tuple[GroupStage, ...]
    routes: Tuple[RouteStage, ...]
    limits: np.ndarray                        # int32[qcap] per-slot top-n

    def stages(self) -> Iterator[Tuple[str, object]]:
        """The staged execution order (the IR, for inspection/debug)."""
        for s in self.scans:
            yield "scan", s
        for j in self.joins:
            yield "join", j
        for s in self.sorts:
            yield "sort", s
        for g in self.groups:
            yield "group", g
        for r in self.routes:
            yield "route", r


# ---------------------------------------------------------------------------
# Lowering
# ---------------------------------------------------------------------------


def _slot_ranges(plan: CompiledPlan, names: List[str],
                 base: int) -> Tuple[SlotRange, ...]:
    return tuple((n, plan.offsets[n] - base, plan.caps[n]) for n in names)


def lower_plan(plan: CompiledPlan,
               key_stats: Optional[Dict[str, Dict[str, int]]] = None
               ) -> LoweredPlan:
    """Lower the compiled plan to the staged IR.

    ``key_stats`` optionally maps PK table name -> measured key skew
    ({"n_live", "max_dup"}, see ``partition_layout``) so partitioned
    joins adapt their bucket layout to real occupancy; the executor
    measures it from the initial snapshot.  ``None`` keeps the static
    layout (runtime relayout paths that have no snapshot in hand).
    """
    cat = plan.catalog
    W = plan.qcap // 32

    scans = []
    for table, node in plan.scans.items():
        wlo, whi = plan.word_range(node.referencing)
        base = wlo * 32
        q_sub = (whi - wlo) * 32
        # lowering-time predicate scatter plan: static gather indices into
        # the packed admission buffers (no python loops in the cycle)
        covered = np.zeros(q_sub, bool)
        for name in node.referencing:
            o = plan.offsets[name] - base
            covered[o:o + plan.caps[name]] = True
        param_idx = np.full((max(len(node.cols), 1), q_sub), -1, np.int32)
        for name, col_idx, pidx in node.bindings:
            o = plan.offsets[name] - base
            param_idx[col_idx, o:o + plan.caps[name]] = pidx
        scans.append(ScanStage(
            table=table, cols=tuple(node.cols), wlo=wlo, whi=whi,
            slots=_slot_ranges(plan, node.referencing, base),
            covered=covered, param_idx=param_idx,
            delta_words=max(1, (whi - wlo) // DELTA_PANE_DIVISOR)))

    joins = []
    for j in plan.joins:
        schema = cat.schemas[j.pk_table]
        if schema.pk is None:
            raise ValueError(
                f"join {j.spine}->{j.pk_table}: PK table has no key column")
        n_parts, bucket_cap = 0, 0
        if schema.key_space > 0:
            kind = "gather"
        elif schema.capacity >= PARTITIONED_MIN_CAPACITY:
            kind = "partitioned"
            n_parts, bucket_cap = partition_layout(
                schema.capacity,
                None if key_stats is None else key_stats.get(j.pk_table))
        else:
            kind = "block"
        joins.append(JoinStage(
            spine=j.spine, fk_col=j.fk_col, pk_table=j.pk_table,
            kind=kind, pk_col=schema.pk,
            sub_mask=plan.sub_mask(j.subscribers),
            n_partitions=n_parts, bucket_cap=bucket_cap))

    sorts = []
    for s in plan.sorts:
        wlo, whi = plan.word_range(s.subscribers)
        T = cat.schemas[s.spine].capacity
        sorts.append(SortStage(
            spine=s.spine, col=s.col, desc=s.desc, wlo=wlo, whi=whi,
            sub_mask=plan.sub_mask(s.subscribers)[wlo:whi],
            union_cap=min(T, plan.union_cap),
            slots=_slot_ranges(plan, s.subscribers, wlo * 32)))

    groups = []
    for g in plan.groups:
        wlo, whi = plan.word_range(g.subscribers)
        T = cat.schemas[g.spine].capacity
        groups.append(GroupStage(
            spine=g.spine, agg=g.agg, wlo=wlo, whi=whi,
            union_cap=min(T, plan.group_union_cap),
            slots=_slot_ranges(plan, g.subscribers, wlo * 32)))

    routed = {name for st in sorts + groups for name, _, _ in st.slots}
    by_spine: Dict[str, List[str]] = {}
    for name, t in plan.templates.items():
        if name not in routed:
            by_spine.setdefault(t.spine, []).append(name)
    routes = []
    for spine, names in by_spine.items():
        wlo, whi = plan.word_range(names)
        T = cat.schemas[spine].capacity
        routes.append(RouteStage(
            spine=spine, wlo=wlo, whi=whi,
            sub_mask=plan.sub_mask(names)[wlo:whi],
            union_cap=min(T, plan.union_cap),
            slots=_slot_ranges(plan, names, wlo * 32)))

    limits = np.ones(plan.qcap, np.int32)
    for name, t in plan.templates.items():
        o, c = plan.offsets[name], plan.caps[name]
        limits[o:o + c] = min(t.limit, plan.max_results)

    return LoweredPlan(
        plan=plan, qcap=plan.qcap, W=W, n_params_max=plan.n_params_max,
        scans=tuple(scans), joins=tuple(joins), sorts=tuple(sorts),
        groups=tuple(groups), routes=tuple(routes), limits=limits)


def check_extension_prefix(old: LoweredPlan, new: LoweredPlan) -> None:
    """Validate that ``new`` prefix-stably EXTENDS ``old`` at the stage
    level — the IR contract dynamic plan folding (core/folding.py) rests
    on.

    Appending templates to a plan reuses the same static schedule
    builder (``lower_plan``), and because templates are appended AFTER
    every existing one, every node-dedup dict above re-encounters its
    old keys in the old order before any new key: existing stages keep
    their position, scan windows only widen on the high side (new
    templates own higher slot ranges), predicated column lists only
    append, and join stages keep their access path (same catalog + same
    key stats).  The actual derivation checks live in the planlint pass
    ``analysis_static.ir_passes.lint_extension_prefix`` (rule
    ``fold-prefix-stability``) — this entry point is kept so folding and
    tests keep one import path, and raises ``ValueError`` as before.
    """
    from repro.analysis_static.diagnostics import raise_on_error
    from repro.analysis_static.ir_passes import lint_extension_prefix
    raise_on_error(lint_extension_prefix(old, new), exc=ValueError)


# ---------------------------------------------------------------------------
# Executing the lowered graph: one heartbeat of the always-on plan
# ---------------------------------------------------------------------------
#
# Two cycle flavours share everything but the scan phase (the delta
# flavour additionally comes in two JOIN variants):
#
#   build_cycle        — full rescan: every scan re-evaluates the whole
#                        table (the bounded worst case, and the seeding
#                        cycle for the carried scan state).
#   build_delta_cycle  — incremental: each predicated scan re-evaluates
#                        only (changed admission word columns) ∪ (the
#                        update batch's dirty rows) against the PREVIOUS
#                        heartbeat's carried bitmask words.  With
#                        ``delta_joins=True`` the non-gather joins also
#                        re-probe only the dirty spine rows against the
#                        previous heartbeat's carried rid arrays.
#
# Both return ``carry = {"scan": {table: words}, "parts": {table:
# partitions}}`` so the executor can thread it into the next heartbeat;
# the rid half of the widened carry travels through
# ``results["_join_rids"]`` (distinct buffers from the donated carry, so
# pipelined in-flight results never alias a later dispatch's donation).


def _build_apply_phase(lowered: LoweredPlan):
    """Update-apply + partition refresh (step 1, shared by all cycles)."""
    from repro.core.storage import (apply_updates, build_key_partitions,
                                    refresh_key_partitions)

    cat = lowered.plan.catalog
    # PK tables probed by partitioned joins: partition once per heartbeat,
    # shared by every join into the same table
    part_specs = {}
    for j in lowered.joins:
        if j.kind == "partitioned":
            part_specs.setdefault(
                j.pk_table, (j.pk_col, j.n_partitions, j.bucket_cap))

    def apply_phase(storage, updates, prev_parts=None):
        # apply updates in arrival order (cycle-consistent snapshot),
        # then refresh the partitioned joins' bucket structures from the
        # fresh snapshot (update-apply time, paper §4.4 access paths).
        # With a carried ``prev_parts`` (the delta cycles) a table whose
        # batch touched nothing keeps its partitions — rebuilding an
        # untouched table is idempotent, so skipping the sort is exact —
        # and ``rebuilt`` records which tables actually re-sorted.
        storage = dict(storage)
        for table, batch in updates.items():
            storage[table] = apply_updates(cat.schemas[table],
                                           storage[table], batch)
        partitions, rebuilt = {}, {}
        for table, (pk_col, n_parts, bucket_cap) in part_specs.items():
            t = storage[table]
            if prev_parts is None:
                partitions[table] = build_key_partitions(
                    t[pk_col], t["_valid"], n_parts, bucket_cap)
                rebuilt[table] = jnp.ones((), bool)
            else:
                partitions[table], rebuilt[table] = refresh_key_partitions(
                    t, pk_col, n_parts, bucket_cap, prev_parts[table])
        return storage, partitions, rebuilt

    return apply_phase


def _pane_window(st: ScanStage, covered, changed):
    """One stage's admission-pane geometry, host-free: (span, w0, over).

    ``span`` is the contiguous changed-word span over the stage's
    covered slots (0 = no admission change), ``w0`` the pane's first
    word column clamped so the static-width pane stays in range, and
    ``over`` the words by which the span exceeds the pane capacity
    (positive only on ineligible beats the executor should never have
    dispatched — the defensive invariant).
    """
    base = st.wlo * 32
    w = st.whi - st.wlo
    A = st.delta_words
    qd = changed[base:base + st.q_window] & covered
    wch = jnp.any(qd.reshape(w, 32), axis=1)
    first = jnp.argmax(wch).astype(jnp.int32)
    last = (w - 1 - jnp.argmax(wch[::-1])).astype(jnp.int32)
    span = jnp.where(jnp.any(wch), last - first + 1, 0)
    over = jnp.maximum(span - A, 0)
    w0 = jnp.minimum(first, w - A)
    return span, w0, over


def _pseudo_partitions(pk_tbl, pk_col: str):
    """A block join's PK side as a single-bucket partition structure.

    The whole key column is one bucket pane with bound INT_MIN (every
    probe routes to it), invalid rows padded with the key sentinel and
    row id -1 — exactly the ``storage.build_key_partitions`` encoding,
    so the one-bucket probe (max valid row with an equal key) matches
    ``storage.locate_rows_by_key`` bit for bit.  This funnels block-kind
    carried joins through the same fused/partitioned dirty-probe path
    instead of keeping a separate dense compare alive.
    """
    from repro.core.storage import INT_SENTINEL

    keys = pk_tbl[pk_col]
    valid = pk_tbl["_valid"]
    bkeys = jnp.where(valid, keys, INT_SENTINEL)[None, :]
    brows = jnp.where(valid, jnp.arange(keys.shape[0], dtype=jnp.int32),
                      -1)[None, :]
    bounds = jnp.full((1,), INT_MIN, jnp.int32)
    return bkeys, brows, bounds


def _bind_predicates(st: ScanStage, covered, pidx, queries):
    """One stage's (qok, lo, hi) from the packed admission buffers.

    The whole lo/hi predicate matrix binds in one vectorized gather —
    the scatter plan (covered, param_idx) is precomputed at lowering
    time, so there are no per-template python loops on the hot path.
    """
    base = st.wlo * 32
    act = queries["active"][base:base + st.q_window]
    qok = act & covered                          # admitted subscribers
    p = queries["params"][base:base + st.q_window]
    bound = pidx >= 0
    safe = jnp.maximum(pidx, 0)
    qs = jnp.arange(st.q_window)
    p_lo = p[qs[None, :], safe, 0]               # [C, q_window]
    p_hi = p[qs[None, :], safe, 1]
    lo = jnp.where(qok[None, :],
                   jnp.where(bound, p_lo, INT_MIN), INT_MAX)
    hi = jnp.where(qok[None, :],
                   jnp.where(bound, p_hi, INT_MAX), INT_MIN)
    return qok, lo, hi


def build_cycle(lowered: LoweredPlan, backend: OperatorBackend):
    """Returns cycle(storage, queries, updates) -> (storage', carry,
    results).

    queries: the packed admission batch —
             {"params": int32[qcap, P_max, 2], "active": bool[qcap]}
             (ONE host->device transfer per buffer per heartbeat; each
             template's slot range is a static view into it)
    updates: {table: update batch dict (see storage.empty_update_batch)}
    carry:   {"scan": {table: uint32[T, whi-wlo]} window-local scan words
             of every predicated stage, "parts": {table: key partitions
             of every partitioned-join PK table}} — the state
             ``build_delta_cycle`` consumes next heartbeat.
    results: per template row-id matrices / group top-k; all fixed shapes,
    plus "_overflow" (union-cap overflow count), "_join_rids" (whose
    arrays the executor threads forward as the rid half of the widened
    carry) and "_parts_rebuilt" (which PK tables re-sorted this beat).
    """
    from repro.core import dataquery as dq

    W = lowered.W
    apply_phase = _build_apply_phase(lowered)
    post_scan = _build_post_scan(lowered, backend)
    # lowering-time predicate scatter plans as device constants
    scan_covered = [jnp.asarray(s.covered) for s in lowered.scans]
    scan_pidx = [jnp.asarray(s.param_idx) for s in lowered.scans]

    def cycle(storage, queries, updates):
        storage, partitions, rebuilt = apply_phase(storage, updates)

        # shared scans (ClockScan): one pass per table for ALL queries,
        # each touching only its subscribers' word window.
        scan_masks, scan_carry = {}, {}
        for st, covered, pidx in zip(lowered.scans, scan_covered,
                                     scan_pidx):
            tbl = storage[st.table]
            if not st.cols:
                # no predicated columns: the scan degenerates to
                # valid-row x active-subscriber — skip the compare kernel
                base = st.wlo * 32
                act = queries["active"][base:base + st.q_window]
                m = dq.pack(tbl["_valid"][:, None] & (act & covered)[None])
            else:
                _, lo, hi = _bind_predicates(st, covered, pidx, queries)
                cols = jnp.stack([tbl[c] for c in st.cols])
                m = backend.scan(cols, lo, hi, tbl["_valid"])
                scan_carry[st.table] = m
            scan_masks[st.table] = jnp.pad(m, ((0, 0),
                                               (st.wlo, W - st.whi)))

        carry = {"scan": scan_carry, "parts": partitions}
        results = post_scan(storage, partitions, scan_masks)
        results["_parts_rebuilt"] = rebuilt
        return storage, carry, results

    return cycle


def build_delta_cycle(lowered: LoweredPlan, backend: OperatorBackend,
                      delta_joins: bool = False):
    """Returns the incremental heartbeat:
    cycle(storage, carry, queries, updates) -> (storage', carry',
    results), or — with ``delta_joins=True`` —
    cycle(storage, carry, rid_carry, queries, updates).

    ``carry`` is the previous heartbeat's ``{"scan": window-local scan
    words, "parts": key partitions}`` (the ``build_cycle`` carry).
    ``queries`` additionally holds "changed": bool[qcap], true for slots
    whose (active, params) differ from the previously DISPATCHED
    heartbeat (computed host-side by the executor).  Each predicated
    scan then refreshes only

      * the admission pane — the contiguous ``st.delta_words``-word
        range containing every changed slot, recomputed over ALL rows
        with the regular compare kernel at pane width
        (32 * delta_words ≪ q_window) and merged with one in-place
        dynamic_update_slice on the donated carry, and
      * the dirty rows — the update batch's sorted/unique
        ``_dirty_rows`` re-evaluated against the FULL window via
        ``backend.scan_delta`` and scattered back by row on the
        sorted-unique fast path,

    and carries every other (row, word) pair forward verbatim.  Key
    partitions refresh the same way: a PK table whose batch touched
    nothing keeps its carried buckets (storage.refresh_key_partitions).

    With ``delta_joins=True``, ``rid_carry`` is the previous heartbeat's
    ``results["_join_rids"]`` and every non-gather JoinStage re-probes
    ONLY its spine's dirty rows (``backend.join_delta`` for partitioned
    stages, a dirty-row key-equality probe for block stages), merging
    the fresh rids into the carried array with the same sorted-scatter
    fast path.  The executor only dispatches this variant when NO
    carried stage's PK table was touched this heartbeat, so every
    carried rid was probed against partitions identical to this
    snapshot's.

    The executor guarantees eligibility host-side (the changed-word SPAN
    fits the pane, distinct dirty rows fit every table's set);
    ``results["_delta_overflow"]`` counts violations as a defensive
    invariant (0 on every eligible heartbeat).

    Correctness: a carried (row, slot) scan bit has an unchanged row
    (not dirty), unchanged slot binding (not changed), and an unchanged
    snapshot outside the dirty set — so its previous word is exactly
    what the full rescan would recompute.  A carried join rid is a pure
    function of (fk value, PK snapshot), BOTH unchanged for non-dirty
    spine rows on a PK-untouched heartbeat — admission changes never
    invalidate rids, they only change the masks, which are recomputed
    from the merged scan words every heartbeat.
    """
    from repro.core import dataquery as dq
    from repro.core.backends import FusedJoinIn, FusedScanIn
    from repro.core.storage import scatter_dirty_rows

    plan = lowered.plan
    cat = plan.catalog
    W = lowered.W
    apply_phase = _build_apply_phase(lowered)
    post_scan = _build_post_scan(lowered, backend)
    scan_covered = [jnp.asarray(s.covered) for s in lowered.scans]
    scan_pidx = [jnp.asarray(s.param_idx) for s in lowered.scans]
    carried_joins = [j for j in lowered.joins if j.kind != "gather"]
    carried_spines = sorted({j.spine for j in carried_joins})
    # the fused path: every predicated stage's pane + dirty rescan and
    # (with delta_joins) every carried join's dirty probe collapse into
    # ONE backend op; a backend without it keeps the chained ops
    fused = backend.fused_delta is not None

    def cycle(storage, carry, rid_carry, queries, updates):
        storage, partitions, rebuilt = apply_phase(storage, updates,
                                                   carry["parts"])
        changed = queries["changed"]

        scan_masks, new_carry = {}, {}
        delta_over = jnp.zeros((), jnp.int32)
        fused_scan_in, fused_stages = [], []
        for st, covered, pidx in zip(lowered.scans, scan_covered,
                                     scan_pidx):
            tbl = storage[st.table]
            base = st.wlo * 32
            if not st.cols:
                # degenerate scans are O(T*w) bit ops — cheaper to
                # recompute than to track, so they carry no state (and
                # stay outside the fused op)
                act = queries["active"][base:base + st.q_window]
                m = dq.pack(tbl["_valid"][:, None] & (act & covered)[None])
                scan_masks[st.table] = jnp.pad(m, ((0, 0),
                                                   (st.wlo, W - st.whi)))
                continue
            _, lo, hi = _bind_predicates(st, covered, pidx, queries)
            cols = jnp.stack([tbl[c] for c in st.cols])
            A = st.delta_words

            # admission pane: the contiguous word range holding every
            # changed slot (recomputed over all rows at pane width) and
            # the dirty rows (rescanned at full window width); both
            # merge in place into the donated carry — fused in one op,
            # or chained through scan / scan_delta / the scatter
            span, w0, over = _pane_window(st, covered, changed)
            delta_over += over
            lo_a = jax.lax.dynamic_slice(lo, (0, w0 * 32),
                                         (lo.shape[0], A * 32))
            hi_a = jax.lax.dynamic_slice(hi, (0, w0 * 32),
                                         (hi.shape[0], A * 32))
            dr = tbl["_dirty_rows"]
            delta_over += tbl["_dirty_overflow"].astype(jnp.int32)
            if fused:
                fused_scan_in.append(FusedScanIn(
                    cols=cols, lo=lo, hi=hi, lo_p=lo_a, hi_p=hi_a,
                    valid=tbl["_valid"], carry=carry["scan"][st.table],
                    w0=w0, span=span, rows=dr,
                    dn=tbl["_dirty_n"].astype(jnp.int32)))
                fused_stages.append(st)
                continue
            pane = backend.scan(cols, lo_a, hi_a, tbl["_valid"])
            m = jax.lax.dynamic_update_slice(carry["scan"][st.table],
                                             pane, (0, w0))
            dwords = backend.scan_delta(cols, lo, hi, tbl["_valid"], dr)
            m = scatter_dirty_rows(m, dr, dwords,
                                   cat.schemas[st.table].capacity)
            new_carry[st.table] = m
            scan_masks[st.table] = jnp.pad(m, ((0, 0),
                                               (st.wlo, W - st.whi)))

        fused_join_in = []
        if delta_joins:
            # defensive: a carried join's spine dirty set must not have
            # overflowed either (the host checks the same thing exactly)
            for spine in carried_spines:
                delta_over += \
                    storage[spine]["_dirty_overflow"].astype(jnp.int32)
            if fused:
                for j in carried_joins:
                    tbl = storage[j.spine]
                    if j.kind == "partitioned":
                        bkeys, brows, bounds = partitions[j.pk_table]
                    else:  # block: single-bucket pseudo-partitions
                        bkeys, brows, bounds = _pseudo_partitions(
                            storage[j.pk_table], j.pk_col)
                    fused_join_in.append(FusedJoinIn(
                        keys=tbl[j.fk_col], rows=tbl["_dirty_rows"],
                        dn=tbl["_dirty_n"].astype(jnp.int32),
                        bkeys=bkeys, brows=brows, bounds=bounds,
                        rid_carry=rid_carry[j.key]))

        fused_rids = None
        if fused and (fused_scan_in or fused_join_in):
            words, rids = backend.fused_delta(tuple(fused_scan_in),
                                              tuple(fused_join_in))
            for st, m in zip(fused_stages, words):
                new_carry[st.table] = m
                scan_masks[st.table] = jnp.pad(m, ((0, 0),
                                                   (st.wlo, W - st.whi)))
            if delta_joins:
                fused_rids = {j.key: r
                              for j, r in zip(carried_joins, rids)}

        results = post_scan(storage, partitions, scan_masks,
                            rid_carry=rid_carry, fused_rids=fused_rids)
        results["_delta_overflow"] = delta_over
        results["_parts_rebuilt"] = rebuilt
        return storage, {"scan": new_carry, "parts": partitions}, results

    if delta_joins:
        return cycle
    # full-probe variant: same signature minus the rid carry
    return lambda storage, carry, queries, updates: cycle(
        storage, carry, None, queries, updates)


def _build_post_scan(lowered: LoweredPlan, backend: OperatorBackend):
    """Joins, sorts, group-bys and routing (steps 3-6, shared by all
    cycle flavours; ``rid_carry`` switches the joins to the delta
    probe)."""
    from repro.core.storage import locate_rows_by_key, scatter_dirty_rows

    plan = lowered.plan
    cat = plan.catalog
    limits = jnp.asarray(lowered.limits)
    join_subs = [jnp.asarray(j.sub_mask) for j in lowered.joins]
    sort_subs = [jnp.asarray(s.sub_mask) for s in lowered.sorts]
    route_subs = [jnp.asarray(r.sub_mask) for r in lowered.routes]

    def post_scan(storage, partitions, scan_masks, rid_carry=None,
                  fused_rids=None):
        # 3. shared joins: ONE big join per signature, query_id in the
        #    predicate via bitmask intersection; non-subscribers pass
        #    through untouched.  With a carried rid array (delta-join
        #    heartbeats) the probe shrinks to the spine's dirty rows:
        #    fresh rids merge into the carry on the sorted-scatter fast
        #    path and the bitmask intersection — which DOES depend on
        #    this heartbeat's admission — is recomputed from the merged
        #    scan words as usual.  With ``fused_rids`` the fused delta
        #    op already merged every carried join's rids; only the
        #    intersection remains here.
        spine_masks = dict(scan_masks)
        join_rids = {}
        for st, sub in zip(lowered.joins, join_subs):
            tbl = storage[st.spine]
            m = spine_masks[st.spine]
            if st.kind == "gather":
                rid, combined = ops.shared_join_fk(
                    tbl[st.fk_col], m,
                    storage[st.pk_table]["_pk_index"],
                    scan_masks[st.pk_table])
            elif fused_rids is not None:
                rid = fused_rids[st.key]
                mask_r = scan_masks[st.pk_table]
                gathered = mask_r[jnp.clip(rid, 0, mask_r.shape[0] - 1)]
                combined = jnp.where((rid >= 0)[:, None], m & gathered,
                                     jnp.uint32(0))
            elif rid_carry is not None:
                cap = cat.schemas[st.spine].capacity
                dr = tbl["_dirty_rows"]
                if st.kind == "partitioned":
                    bkeys, brows, bounds = partitions[st.pk_table]
                    rid_d = backend.join_delta(tbl[st.fk_col], dr,
                                               bkeys, brows, bounds)
                else:  # block: dirty-row key-equality probe (tiny PK)
                    pk_tbl = storage[st.pk_table]
                    kd = tbl[st.fk_col][jnp.clip(dr, 0, cap - 1)]
                    rid_d = locate_rows_by_key(pk_tbl[st.pk_col], kd,
                                               pk_tbl["_valid"])
                rid = scatter_dirty_rows(rid_carry[st.key], dr, rid_d,
                                         cap)
                mask_r = scan_masks[st.pk_table]
                gathered = mask_r[jnp.clip(rid, 0, mask_r.shape[0] - 1)]
                combined = jnp.where((rid >= 0)[:, None], m & gathered,
                                     jnp.uint32(0))
            elif st.kind == "partitioned":
                bkeys, brows, bounds = partitions[st.pk_table]
                rid, combined = backend.join_partitioned(
                    tbl[st.fk_col], m, bkeys, brows, bounds,
                    scan_masks[st.pk_table])
            else:  # block: dense key-equality kernel, small index-less PK
                pk_tbl = storage[st.pk_table]
                rid, combined = backend.join_block(
                    tbl[st.fk_col], m, pk_tbl[st.pk_col],
                    scan_masks[st.pk_table], pk_tbl["_valid"])
            spine_masks[st.spine] = (combined & sub[None, :]) \
                | (m & ~sub[None, :])
            join_rids[st.key] = rid

        # 4. shared sorts + fused per-query top-n + routing (Gamma): the
        #    sort runs over the bounded UNION of tuples wanted by the
        #    node's subscribers (Fig. 4); overflow past the cap is counted.
        results = {}
        overflow = jnp.zeros((), jnp.int32)
        for st, sub in zip(lowered.sorts, sort_subs):
            mask = spine_masks[st.spine][:, st.wlo:st.whi] & sub[None, :]
            rows_c, cmask, n_want = ops.compress_union(mask, st.union_cap)
            overflow += jnp.maximum(n_want - st.union_cap, 0)
            keys = storage[st.spine][st.col][jnp.maximum(rows_c, 0)]
            keys = jnp.where(rows_c >= 0,
                             -keys if st.desc else keys, ops.INT_MAX)
            perm = jnp.argsort(keys, stable=True)
            rows = ops.route_topn(cmask[perm],
                                  limits[st.wlo * 32:st.whi * 32],
                                  plan.max_results, rows=rows_c[perm])
            for name, o, c in st.slots:
                results[name] = {"rows": rows[o:o + c]}

        # 5. shared group-bys (phase 1 shared over the union, phase 2 per
        #    query)
        for st in lowered.groups:
            agg = st.agg
            tbl = storage[st.spine]
            rows_c, cmask, n_want = ops.compress_union(
                spine_masks[st.spine][:, st.wlo:st.whi], st.union_cap)
            overflow += jnp.maximum(n_want - st.union_cap, 0)
            safe = jnp.maximum(rows_c, 0)
            gcodes = jnp.where(rows_c >= 0, tbl[agg.group_col][safe], 0)
            gvals = jnp.where(rows_c >= 0, tbl[agg.agg_col][safe], 0)
            count, ssum = backend.groupby(gcodes, gvals, cmask,
                                          agg.n_groups)
            score = ssum if agg.order_by == "sum" else count
            top_val, top_grp = jax.lax.top_k(score.T, agg.top_k)  # [q, K]
            for name, o, c in st.slots:
                results[name] = {
                    "groups": top_grp[o:o + c].astype(jnp.int32),
                    "scores": top_val[o:o + c],
                    "counts": jnp.take_along_axis(
                        count.T[o:o + c], top_grp[o:o + c], axis=1)}

        # 6. unsorted templates route in natural row order — ONE routing
        #    pass per spine shared by all such templates
        for st, sub in zip(lowered.routes, route_subs):
            mask = spine_masks[st.spine][:, st.wlo:st.whi] & sub[None, :]
            rows_c, cmask, n_want = ops.compress_union(mask, st.union_cap)
            overflow += jnp.maximum(n_want - st.union_cap, 0)
            rows = ops.route_topn(cmask, limits[st.wlo * 32:st.whi * 32],
                                  plan.max_results, rows=rows_c)
            for name, o, c in st.slots:
                results[name] = {"rows": rows[o:o + c]}
        results["_overflow"] = overflow

        # attach join rids so hosts can materialize joined tuples
        results["_join_rids"] = join_rids
        return results

    return post_scan

"""Columnar storage manager with functional MVCC snapshots.

The Crescando-style storage layer of the paper (§4.4), adapted to JAX:
tables are fixed-capacity columnar int32 arrays (strings dictionary-encoded,
money in cents, dates as int days).  A *snapshot* is simply the immutable
pytree — a cycle physically cannot observe concurrent writes, which is the
paper's snapshot-isolation guarantee by construction.

Updates (insert / update / delete) are applied *in arrival order* at the
start of each cycle via fixed-capacity scatter batches, mirroring ClockScan
semantics: every select in cycle k sees exactly the updates admitted to
cycle k.

Primary-key tables maintain a dense key->row index (scatter-maintained) so
shared PK-FK joins are O(1) gathers — the TPU-native replacement for the
paper's hash join (see DESIGN.md).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

NULL = jnp.int32(-2147483648)


@dataclasses.dataclass(frozen=True)
class TableSchema:
    name: str
    columns: Tuple[str, ...]
    capacity: int
    pk: Optional[str] = None      # primary-key column
    # max pk value + 1 (dense index size).  key_space == 0 with pk set
    # means "unique key but unbounded domain": no dense index is kept and
    # shared joins into the table lower to the blocked key-equality
    # kernel instead of the O(1) index gather (see core/lowering.py).
    key_space: int = 0
    # fixed capacity of the per-cycle dirty-row set maintained by
    # ``apply_updates``: the distinct rows a cycle's update batch touched,
    # which is everything the incremental scan path must re-evaluate.  A
    # batch that touches more rows sets ``_dirty_overflow`` and the
    # executor falls back to a safe full rescan for that heartbeat.
    dirty_cap: int = 128

    @property
    def indexed(self) -> bool:
        return bool(self.pk) and self.key_space > 0


def empty_table(schema: TableSchema) -> Dict:
    t = {c: jnp.zeros((schema.capacity,), jnp.int32)
         for c in schema.columns}
    t["_valid"] = jnp.zeros((schema.capacity,), bool)
    t["_n"] = jnp.zeros((), jnp.int32)       # append cursor
    t["_version"] = jnp.zeros((), jnp.int32)
    # dirty-row set of the LAST applied update batch: ascending distinct
    # row ids, padded with the ``capacity`` sentinel (kept SORTED+UNIQUE
    # so the delta scan's scatter-back can use the fast in-place scatter
    # path; see apply_updates).  Fresh tables are fully clean.
    t["_dirty_rows"] = jnp.full((schema.dirty_cap,), schema.capacity,
                                jnp.int32)
    t["_dirty_n"] = jnp.zeros((), jnp.int32)
    t["_dirty_overflow"] = jnp.zeros((), bool)
    if schema.indexed:
        t["_pk_index"] = jnp.full((schema.key_space,), -1, jnp.int32)
    return t


def bulk_load(schema: TableSchema, data: Dict[str, jnp.ndarray]) -> Dict:
    """Load host arrays (all the same length) into a fresh table."""
    n = len(next(iter(data.values())))
    if n > schema.capacity:
        raise ValueError(
            f"[planlint:no-bare-assert] bulk_load of {schema.name}: "
            f"{n} rows exceed capacity {schema.capacity}")
    t = empty_table(schema)
    for c in schema.columns:
        col = jnp.asarray(data[c], jnp.int32)
        t[c] = t[c].at[:n].set(col)
    t["_valid"] = t["_valid"].at[:n].set(True)
    t["_n"] = jnp.int32(n)
    if schema.indexed:
        t["_pk_index"] = t["_pk_index"].at[t[schema.pk][:n]].set(
            jnp.arange(n, dtype=jnp.int32))
    return t


# ---------------------------------------------------------------------------
# Update batches: fixed-capacity, applied in arrival order.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class UpdateSlots:
    """Static shape of a table's per-cycle update batch."""
    n_insert: int
    n_update: int
    n_delete: int


# numpy-compatible fill defaults for the mutable batch fields — the
# executor's preallocated staging buffers reset exactly these between
# heartbeats (everything else is masked out and may hold stale values)
UPDATE_BATCH_RESET = {"ins_mask": False, "upd_mask": False,
                      "del_mask": False, "upd_key": -1, "del_key": -1}


def empty_update_batch(schema: TableSchema, slots: UpdateSlots,
                       xp=jnp) -> Dict:
    """One table's fixed-capacity update batch.

    ``xp`` selects the array namespace: jnp for device batches, np for
    the executor's preallocated host staging buffers — ONE layout
    definition either way.
    """
    int32 = xp.int32
    return {
        "ins_rows": {c: xp.zeros((slots.n_insert,), int32)
                     for c in schema.columns},
        "ins_mask": xp.zeros((slots.n_insert,), bool),
        # updates: set column `upd_col[i]` of row with pk `upd_key[i]`
        "upd_key": xp.full((slots.n_update,), -1, int32),
        "upd_col": xp.zeros((slots.n_update,), int32),
        "upd_val": xp.zeros((slots.n_update,), int32),
        "upd_mask": xp.zeros((slots.n_update,), bool),
        "del_key": xp.full((slots.n_delete,), -1, int32),
        "del_mask": xp.zeros((slots.n_delete,), bool),
    }


INT_SENTINEL = jnp.int32(2147483647)   # reserved: never a live key


def build_key_partitions(keys, valid, n_partitions: int, bucket_cap: int):
    """Range-partition a key column into fixed-capacity buckets.

    The partitioned shared join's access-path structure, rebuilt once per
    heartbeat right after updates commit (derived state, like ``_pk_index``
    but reconstructed rather than scatter-maintained).  Valid rows are
    sorted by key and split into ``n_partitions`` contiguous buckets of
    exactly ``bucket_cap`` entries, so — unlike a hashed radix partition —
    NO bucket can overflow: every valid row lands in exactly one bucket
    and the join stays exact for any key distribution.  Invalid rows and
    padding sort to the tail under the ``INT_MAX`` sentinel (key domains
    must exclude ``INT_MAX``, the same reservation the scan predicate
    bounds already make).

    Returns (bucket_keys int32[P, B], bucket_rows int32[P, B] (-1 = pad),
    bounds int32[P] — each bucket's smallest key, for the probe side's
    ``searchsorted``).  Requires n_partitions * bucket_cap >= len(keys).

    Probe contract (see kernels/ref.partitioned_join_ref): a key k lives
    in the LAST bucket whose bound <= k.  Duplicate keys sort adjacently
    (row id breaks ties ascending), so the last bucket containing k holds
    the highest-row duplicate — matching the dense block join's
    max-row-id resolution.
    """
    T = keys.shape[0]
    cap = n_partitions * bucket_cap
    if cap < T:
        raise ValueError(
            f"[planlint:no-bare-assert] partition capacity {cap} < "
            f"table capacity {T}")
    invalid = ~valid
    order = jnp.lexsort((jnp.arange(T, dtype=jnp.int32), keys,
                         invalid.astype(jnp.int32)))
    skeys = jnp.where(invalid[order], INT_SENTINEL, keys[order])
    srows = jnp.where(invalid[order], -1, order.astype(jnp.int32))
    skeys = jnp.pad(skeys, (0, cap - T), constant_values=INT_SENTINEL)
    srows = jnp.pad(srows, (0, cap - T), constant_values=-1)
    bucket_keys = skeys.reshape(n_partitions, bucket_cap)
    bucket_rows = srows.reshape(n_partitions, bucket_cap)
    return bucket_keys, bucket_rows, bucket_keys[:, 0]


def scatter_dirty_rows(dst, rows, vals, capacity: int):
    """Scatter per-dirty-row values into a row-indexed array on the
    sorted/unique fast path.

    ``rows`` is a ``_dirty_rows`` set (ascending DISTINCT row ids padded
    with the ``capacity`` sentinel — see ``apply_updates``); ``vals``
    holds one update per slot (leading axis D).  The tail pads all equal
    the sentinel, so they are spread by slot position to keep the
    scatter's sorted/unique hints exact while staying out of range —
    ``mode="drop"`` then discards them.  Shared by the delta scan's
    word scatter and the delta join's rid merge (core/lowering.py).
    """
    D = rows.shape[0]
    spread = rows + jnp.where(rows >= capacity,
                              jnp.arange(D, dtype=jnp.int32), 0)
    return dst.at[spread].set(vals, mode="drop",
                              indices_are_sorted=True,
                              unique_indices=True)


def partitions_stale(table: Dict):
    """True iff this cycle's update batch could have changed the table's
    key partitions (bool scalar, traced).

    ``apply_updates`` maintains the per-cycle dirty-row set; a table whose
    batch touched no rows (and did not overflow the set) has a snapshot
    identical to the previous heartbeat's, so its sorted bucket structure
    — a pure function of (key column, validity) — is identical too.
    """
    return (table["_dirty_n"] > 0) | table["_dirty_overflow"]


def refresh_key_partitions(table: Dict, pk_col: str, n_partitions: int,
                           bucket_cap: int, prev):
    """Rebuild a table's key partitions ONLY if this cycle dirtied it.

    ``prev`` is the previous heartbeat's ``build_key_partitions`` result
    (carried functionally by the executor, like the scan words).  Returns
    ``(partitions, rebuilt)`` where ``rebuilt`` — exposed to the cycle's
    results as ``_parts_rebuilt`` — says whether the sort actually ran
    this heartbeat: the signal the delta-join path's full-probe fallback
    keys off (a rebuilt PK side invalidates nothing for correctness —
    rebuilding an untouched table is idempotent — but a TOUCHED PK side
    means carried join rids may be stale).  The branch is a
    ``lax.cond``, so steady-state heartbeats skip the O(T log T) sort.
    """
    stale = partitions_stale(table)
    return jax.lax.cond(
        stale,
        lambda _: build_key_partitions(table[pk_col], table["_valid"],
                                       n_partitions, bucket_cap),
        lambda p: p,
        prev), stale


def locate_rows_by_key(keys_col, probe, valid):
    """Row holding key ``probe[i]`` among valid rows (-1 = absent).

    Broadcast key-equality scan for tables WITHOUT a dense pk index
    (schema.indexed == False); keys are unique among valid rows, a
    duplicate would resolve to the max row id.  Shared by the storage
    update path and the baseline engine's non-indexed join.
    """
    eq = (keys_col[None, :] == probe[:, None]) & valid[None, :]
    rows = jnp.arange(keys_col.shape[0], dtype=jnp.int32)
    return jnp.max(jnp.where(eq, rows[None, :], -1), axis=1)


def apply_updates(schema: TableSchema, table: Dict, batch: Dict,
                  commit_cap: Optional[int] = None) -> Dict:
    """Deletes, then column updates, then inserts — all in slot order.

    Slot order IS arrival order: the executor fills slots FIFO.

    ``commit_cap`` bounds the rows inserts may land in (default: the
    schema capacity).  The sharded engine stores tables PADDED to a
    multiple of the shard count (core/sharding.py) but must keep the
    padding rows permanently invalid, so it applies with the ORIGINAL
    capacity as the commit bound — insert-overflow semantics then
    match the unsharded engine exactly (rows past the bound are
    dropped and never dirty; the append cursor still advances).

    Besides committing the batch, this maintains the table's per-cycle
    dirty-row set: ``_dirty_rows`` (int32[schema.dirty_cap], ascending
    DISTINCT row ids, padded with the ``capacity`` sentinel) holds every
    row the batch touched — delete targets, update targets, insert
    landing rows — ``_dirty_n`` counts the distinct rows (capacity-
    clamped), and ``_dirty_overflow`` flags a batch that touched more
    distinct rows than the set can hold.  The incremental scan path
    (core/lowering.py ``build_delta_cycle``) re-evaluates exactly these
    rows against the carried bitmask words, scattering back with the
    sorted/unique fast path; an overflowed set forces a full rescan.
    """
    t = dict(table)
    n = t["_n"]
    touched = []                 # dirty-row candidates, -1 = no-op slot

    if schema.pk:
        def locate(keys, mask, valid):
            """Row holding pk `keys[i]` (-1 absent/masked): an O(1) index
            gather when the dense index exists, else a key-equality scan
            over the column (the block-join tables' path)."""
            if schema.indexed:
                return jnp.where(mask, t["_pk_index"][keys], -1)
            return jnp.where(
                mask, locate_rows_by_key(t[schema.pk], keys, valid), -1)

        # deletes: invalidate row, clear pk index
        del_row = locate(batch["del_key"], batch["del_mask"], t["_valid"])
        touched.append(del_row)
        ok = del_row >= 0
        t["_valid"] = t["_valid"].at[jnp.where(ok, del_row, 0)].set(
            jnp.where(ok, False, t["_valid"][0]))
        if schema.indexed:
            t["_pk_index"] = t["_pk_index"].at[
                jnp.where(ok, batch["del_key"], schema.key_space)].set(
                -1, mode="drop")

        # point updates by pk: scatter into (row, col).  Post-delete
        # `_valid`/index so a delete-then-update of the same key in one
        # batch finds nothing, matching arrival-order semantics.
        upd_row = locate(batch["upd_key"], batch["upd_mask"], t["_valid"])
        touched.append(upd_row)
        for ci, c in enumerate(schema.columns):
            sel = (batch["upd_col"] == ci) & (upd_row >= 0)
            rows = jnp.where(sel, upd_row, schema.capacity)
            t[c] = t[c].at[rows].set(
                jnp.where(sel, batch["upd_val"], 0), mode="drop")

    # inserts: append at cursor (slot order preserved by arange offset)
    cap_c = schema.capacity if commit_cap is None else commit_cap
    offs = jnp.cumsum(batch["ins_mask"].astype(jnp.int32)) - 1
    landing = n + offs
    rows = jnp.where(batch["ins_mask"] & (landing < cap_c), landing,
                     schema.capacity)
    for c in schema.columns:
        t[c] = t[c].at[rows].set(batch["ins_rows"][c], mode="drop")
    t["_valid"] = t["_valid"].at[rows].set(True, mode="drop")
    n_new = n + jnp.sum(batch["ins_mask"].astype(jnp.int32))
    if schema.indexed:
        keys = jnp.where(batch["ins_mask"], batch["ins_rows"][schema.pk],
                         schema.key_space)
        # a DROPPED insert (landing past the commit bound) must index as
        # absent (-1) — a row id >= capacity would later clip onto the
        # last real row in the gather join and fabricate a match
        t["_pk_index"] = t["_pk_index"].at[keys].set(
            jnp.where(batch["ins_mask"] & (landing < cap_c), landing,
                      -1).astype(jnp.int32), mode="drop")
    t["_n"] = n_new
    t["_version"] = t["_version"] + 1

    # dirty-row set: mark the touched rows (deletes, updates, insert
    # landing rows — rows the table dropped for being over the commit
    # bound are NOT dirty) on a row bitmap, then compress to the fixed-
    # capacity sorted/unique id list the delta scan consumes.
    touched.append(jnp.where(
        batch["ins_mask"] & (rows < schema.capacity),
        rows.astype(jnp.int32), -1))
    cand = jnp.concatenate([x.astype(jnp.int32) for x in touched])
    D = t["_dirty_rows"].shape[0]
    cap = schema.capacity
    if cand.shape[0] == 0:
        t["_dirty_rows"] = jnp.full((D,), cap, jnp.int32)
        t["_dirty_n"] = jnp.zeros((), jnp.int32)
        t["_dirty_overflow"] = jnp.zeros((), bool)
        return t
    mark = jnp.zeros((cap,), bool).at[
        jnp.where(cand >= 0, cand, cap)].set(True, mode="drop")
    count = jnp.sum(mark.astype(jnp.int32))
    t["_dirty_rows"] = jnp.nonzero(
        mark, size=D, fill_value=cap)[0].astype(jnp.int32)
    t["_dirty_n"] = jnp.minimum(count, D)
    t["_dirty_overflow"] = count > D
    return t


class Catalog:
    """Schema registry + initial state construction."""

    def __init__(self, schemas: List[TableSchema]):
        self.schemas = {s.name: s for s in schemas}

    def init_state(self, data: Dict[str, Dict[str, jnp.ndarray]]) -> Dict:
        return {name: bulk_load(s, data[name]) if name in data
                else empty_table(s)
                for name, s in self.schemas.items()}

"""Dynamic plan folding: admit new query templates into the running
shared plan without stopping the world (GraftDB-style folding on top of
the paper's always-on plan).

SharedDB compiles ONE global plan at startup, which freezes the template
set — a tenant with a novel query shape would have no path in.  Folding
re-compiles an EXTENDED plan (new templates appended; every existing
template keeps its slot range and every existing stage keeps its
position — ``extend_plan`` + ``lowering.check_extension_prefix`` enforce
this) in the background while the OLD compiled heartbeat keeps serving,
then swaps the compiled-cycle handle atomically at a beat boundary:

  1. ``begin_fold``   — validate the extension, start the background
                        re-lower + compile (the old plan keeps beating;
                        the elastic drain -> re-lower -> resume recipe of
                        runtime/elastic.py, run in its ``background``
                        variant);
  2. migration beat   — at the next dispatch after the new handle is
                        ready: drain in-flight beats, install the new
                        handle, width-extend the carries into the new
                        per-stage windows (``migrate_carry``) and
                        version the swap through the executor's
                        ``_layout_token`` / ``_carry_token`` pair;
  3. reseed beat      — the FIRST post-fold heartbeat is a forced full
                        rescan, which reseeds both carry halves under
                        the new layout; from then on the engine is
                        indistinguishable from a cold engine compiled
                        with the extended template set (the differential
                        suite proves ticket-for-ticket parity).

Carry-migration contract
------------------------
The carried scan words are positional in the admission layout: word
window [wlo, whi) of each stage, bit q = "row matches slot q".  Slots a
fold appends have never been admitted, and an un-admitted slot's
predicate binds to (INT_MAX, INT_MIN) — no row matches — so its carried
bits are exactly 0: width-extending a stage's words is a zero-pad on the
high side.  Key partitions depend only on the PK snapshot + partition
geometry (unchanged: same catalog, same measured key stats), and rid
arrays depend only on the spine fk column + PK snapshot — both pass
through untouched.  Any half the fold cannot prove migrable (a table
newly predicated, a new join stage) is returned as ``None`` and reseeds
instead; the forced full-rescan beat makes either route exact.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp

from repro.core.lowering import LoweredPlan, check_extension_prefix
from repro.core.plan import CompiledPlan, QueryTemplate, compile_plan


class FoldError(ValueError):
    """A requested fold cannot preserve the running plan as a prefix."""


def extend_plan(plan: CompiledPlan, new_templates: List[QueryTemplate],
                new_caps: Dict[str, int]) -> CompiledPlan:
    """Recompile ``plan`` with ``new_templates`` APPENDED.

    The extension is validated prefix-stable: every existing template
    keeps its (offset, cap) slot range, the global capacity only grows,
    and every shared node keeps its position (new subscribers join
    existing nodes; genuinely new nodes append at the end).  New
    templates may only reference tables the catalog already holds —
    folding registers QUERY shapes, not schema changes, so the table
    snapshots never migrate.

    Admission and prefix stability are both proven by planlint passes
    (``analysis_static.ir_passes``) — the same passes the lint CLI and
    the mutation corpus exercise — and rejected with the offending rule
    id in the ``FoldError`` message.
    """
    from repro.analysis_static.diagnostics import raise_on_error
    from repro.analysis_static.ir_passes import (lint_fold_batch,
                                                 lint_plan_prefix)
    raise_on_error(lint_fold_batch(plan, new_templates, new_caps),
                   exc=FoldError)
    merged = list(plan.templates.values()) + list(new_templates)
    caps = dict(plan.caps)
    caps.update({t.name: int(new_caps[t.name]) for t in new_templates})
    extended = compile_plan(plan.catalog, merged, caps,
                            max_results=plan.max_results,
                            union_cap=plan.union_cap,
                            group_union_cap=plan.group_union_cap)
    raise_on_error(lint_plan_prefix(plan, extended), exc=FoldError)
    return extended


def _check_plan_prefix(old: CompiledPlan, new: CompiledPlan) -> None:
    """Prefix-stability at the PLAN level — kept as a thin wrapper over
    the planlint pass (the IR level is re-checked by
    ``lowering.check_extension_prefix`` after the extended plan lowers)."""
    from repro.analysis_static.diagnostics import raise_on_error
    from repro.analysis_static.ir_passes import lint_plan_prefix
    raise_on_error(lint_plan_prefix(old, new), exc=FoldError)


def migrate_carry(old: LoweredPlan, new: LoweredPlan, carry,
                  rid_carry) -> Tuple[Optional[dict], Optional[dict]]:
    """Remap the executor's carries from ``old``'s layout into ``new``'s.

    Returns ``(carry', rid_carry')``; either half is ``None`` when it
    must be RE-SEEDED instead (the full-rescan beat regenerates both, so
    a ``None`` is always safe — never wrong, just not incremental).

    * scan words — zero-padded on the high (appended-slot) side into
      each surviving stage's new window; appended slots were never
      admitted, and un-admitted slots match no rows, so zero is their
      exact carried value.  A table that gains its FIRST predicated
      column has no old words to extend -> reseed.
    * key partitions — pass through verbatim when the fold adds no join
      stages (same partitioned PK set, same geometry — enforced by
      ``check_extension_prefix``); a new join stage may demand
      partitions of a table the old beat never partitioned -> reseed.
    * rid arrays — pass through per surviving join key; any new carried
      join has no rid history -> reseed the rid half.
    """
    check_extension_prefix(old, new)
    new_carry = None
    if carry is not None:
        scan, ok = {}, True
        old_scan = {s.table: s for s in old.scans if s.cols}
        for st in new.scans:
            if not st.cols:
                continue
            os = old_scan.get(st.table)
            if os is None or st.table not in carry["scan"]:
                ok = False      # newly predicated table: no words to pad
                break
            words = carry["scan"][st.table]
            pad = (st.whi - st.wlo) - (os.whi - os.wlo)
            scan[st.table] = jnp.pad(words, ((0, 0), (0, pad))) \
                if pad else words
        if ok and len(new.joins) == len(old.joins):
            new_carry = {"scan": scan, "parts": carry["parts"]}
    new_rids = None
    if rid_carry is not None:
        keys = [j.key for j in new.joins if j.kind != "gather"]
        if keys and all(k in rid_carry for k in keys):
            new_rids = {k: rid_carry[k] for k in keys}
    return new_carry, new_rids

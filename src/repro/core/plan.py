"""Global query plan (paper §3.2-3.3, Fig. 2/3/6).

The whole workload — a set of parameterized query *templates* (the JDBC
PreparedStatements of the paper) — compiles ONCE into a single dataflow
plan shared by every concurrent query:

  1. per query template, predicates are pushed down to base tables
     (logical optimization, Fig. 3 middle);
  2. templates are merged: ONE shared scan node per base table, ONE shared
     join node per (spine, fk, pk) signature, ONE shared sort node per
     (spine, column, direction), ONE shared group-by node per
     (spine, group-col, agg-col) — sharing across templates AND across
     concurrent instances of the same template falls out automatically;
  3. each template is assigned a static slot range in the global query-id
     space; per-node subscriber bitmasks select which queries a node's
     output applies to (queries become data).

The compiled plan is a pure function executed once per heartbeat
(executor.py); its jitted XLA executable is the paper's always-on plan.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dataquery as dq
from repro.core import operators as ops
from repro.core.storage import Catalog

INT_MIN = ops.INT_MIN
INT_MAX = ops.INT_MAX


# ---------------------------------------------------------------------------
# Template language
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Pred:
    table: str
    col: str               # parameterized inclusive range [lo, hi]


@dataclasses.dataclass(frozen=True)
class Join:
    fk_col: str            # on the spine
    pk_table: str


@dataclasses.dataclass(frozen=True)
class GroupAgg:
    group_col: str         # spine-local dict-encoded column
    n_groups: int
    agg_col: str           # spine-local value column (summed)
    top_k: int
    order_by: str = "sum"  # "sum" | "count"


@dataclasses.dataclass(frozen=True)
class QueryTemplate:
    name: str
    spine: str
    preds: Tuple[Pred, ...] = ()
    joins: Tuple[Join, ...] = ()
    sort_col: Optional[str] = None     # spine-local
    sort_desc: bool = False
    limit: int = 16
    group: Optional[GroupAgg] = None

    def tables(self) -> Tuple[str, ...]:
        return (self.spine,) + tuple(j.pk_table for j in self.joins)


# ---------------------------------------------------------------------------
# Compiled plan
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ScanNode:
    table: str
    cols: Tuple[str, ...]                  # predicated columns
    # per (template, pred) -> (col index, param index): filled by compiler
    bindings: List[Tuple[str, int, int]]   # (template, col_idx, param_idx)
    referencing: List[str]                 # templates whose graph has table


@dataclasses.dataclass
class JoinNode:
    spine: str
    fk_col: str
    pk_table: str
    subscribers: List[str]


@dataclasses.dataclass
class SortNode:
    spine: str
    col: str
    desc: bool
    subscribers: List[str]


@dataclasses.dataclass
class GroupNode:
    spine: str
    agg: GroupAgg
    subscribers: List[str]


@dataclasses.dataclass
class CompiledPlan:
    catalog: Catalog
    templates: Dict[str, QueryTemplate]
    caps: Dict[str, int]                   # per-template slot capacity
    offsets: Dict[str, int]                # slot range start per template
    qcap: int                              # global query-id capacity
    scans: Dict[str, ScanNode]
    joins: List[JoinNode]
    sorts: List[SortNode]
    groups: List[GroupNode]
    max_results: int
    # bounded union-extraction capacities (paper §3.5: work is a static
    # function of these, independent of query count; overflow is counted)
    union_cap: int = 8192
    group_union_cap: int = 16384

    def sub_mask(self, names: List[str]) -> np.ndarray:
        """uint32[W] subscriber word-mask for a set of templates."""
        bits = np.zeros(self.qcap, bool)
        for t in names:
            bits[self.offsets[t]:self.offsets[t] + self.caps[t]] = True
        W = self.qcap // 32
        out = np.zeros(W, np.uint32)
        for w in range(W):
            val = 0
            for b in range(32):
                if bits[w * 32 + b]:
                    val |= (1 << b)
            out[w] = val
        return out

    def word_range(self, names: List[str]) -> Tuple[int, int]:
        """Smallest [wlo, whi) word window covering these templates' slots.

        Templates are laid out spine-clustered (workload definition order),
        so per-node mask processing only touches its subscribers' words —
        the per-operator work no longer scales with the GLOBAL query
        capacity, only with the operator's own (paper §4.2: per-operator
        queues/capacity).
        """
        lo = min(self.offsets[t] for t in names)
        hi = max(self.offsets[t] + self.caps[t] for t in names)
        return lo // 32, -(-hi // 32)


def compile_plan(catalog: Catalog, templates: List[QueryTemplate],
                 caps: Dict[str, int], max_results: int = 64) -> CompiledPlan:
    offsets, off = {}, 0
    for t in templates:
        offsets[t.name] = off
        off += caps[t.name]
    qcap = -(-off // 32) * 32

    # --- scan nodes: one per table, union of predicated columns ----------
    scans: Dict[str, ScanNode] = {}
    for t in templates:
        for table in t.tables():
            node = scans.setdefault(
                table, ScanNode(table, (), [], []))
            if t.name not in node.referencing:
                node.referencing.append(t.name)
    for t in templates:
        for pi, p in enumerate(t.preds):
            node = scans[p.table]
            if p.col not in node.cols:
                node.cols = node.cols + (p.col,)
            node.bindings.append((t.name, node.cols.index(p.col), pi))

    # --- join nodes: dedupe by (spine, fk, pk) ----------------------------
    joins: Dict[Tuple[str, str, str], JoinNode] = {}
    for t in templates:
        for j in t.joins:
            key = (t.spine, j.fk_col, j.pk_table)
            node = joins.setdefault(
                key, JoinNode(t.spine, j.fk_col, j.pk_table, []))
            node.subscribers.append(t.name)

    # --- sort nodes: dedupe by (spine, col, desc) --------------------------
    sorts: Dict[Tuple[str, str, bool], SortNode] = {}
    for t in templates:
        if t.sort_col:
            key = (t.spine, t.sort_col, t.sort_desc)
            node = sorts.setdefault(
                key, SortNode(t.spine, t.sort_col, t.sort_desc, []))
            node.subscribers.append(t.name)

    # --- group-by nodes ----------------------------------------------------
    groups: Dict[Tuple[str, str, str], GroupNode] = {}
    for t in templates:
        if t.group:
            key = (t.spine, t.group.group_col, t.group.agg_col)
            node = groups.setdefault(key, GroupNode(t.spine, t.group, []))
            node.subscribers.append(t.name)

    return CompiledPlan(
        catalog=catalog,
        templates={t.name: t for t in templates},
        caps=dict(caps), offsets=offsets, qcap=qcap,
        scans=scans, joins=list(joins.values()),
        sorts=list(sorts.values()), groups=list(groups.values()),
        max_results=max_results)


# ---------------------------------------------------------------------------
# The cycle function: one heartbeat of the always-on plan
# ---------------------------------------------------------------------------


def build_cycle_fn(plan: CompiledPlan, update_slots, kernels: str = "auto"):
    """Returns cycle(storage, queries, updates) -> (storage', results).

    queries: {template: {"params": int32[cap, n_preds, 2],
                          "active": bool[cap]}}
    updates: {table: update batch dict (see storage.empty_update_batch)}
    results: per template row-id matrices / group top-k; all fixed shapes.
    """
    from repro.core.storage import apply_updates

    cat = plan.catalog
    W = plan.qcap // 32
    # precompute static subscriber masks
    join_subs = [jnp.asarray(plan.sub_mask(j.subscribers)) for j in plan.joins]
    sort_subs = [jnp.asarray(plan.sub_mask(s.subscribers)) for s in plan.sorts]

    # per-template static n-limit vector for shared top-n
    limits = np.ones(plan.qcap, np.int32)
    for name, t in plan.templates.items():
        o, c = plan.offsets[name], plan.caps[name]
        limits[o:o + c] = min(t.limit, plan.max_results)
    limits = jnp.asarray(limits)

    def cycle(storage, queries, updates):
        # 1. apply updates in arrival order (cycle-consistent snapshot)
        storage = dict(storage)
        for table, batch in updates.items():
            storage[table] = apply_updates(cat.schemas[table],
                                           storage[table], batch)

        # 2. shared scans (ClockScan): one pass per table for ALL queries.
        #    Each scan only evaluates the word window of templates that
        #    reference its table (zero elsewhere: nobody subscribed).
        scan_masks = {}
        W_full = plan.qcap // 32
        for table, node in plan.scans.items():
            tbl = storage[table]
            C = max(len(node.cols), 1)
            T = cat.schemas[table].capacity
            wlo, whi = plan.word_range(node.referencing)
            q_sub = (whi - wlo) * 32
            base = wlo * 32
            lo = jnp.full((C, q_sub), INT_MAX, jnp.int32)  # default: fail
            hi = jnp.full((C, q_sub), INT_MIN, jnp.int32)
            # referencing templates: default pass-all on their slots
            for name in node.referencing:
                o, c = plan.offsets[name] - base, plan.caps[name]
                act = queries[name]["active"]
                lo = lo.at[:, o:o + c].set(
                    jnp.where(act[None, :], INT_MIN, INT_MAX))
                hi = hi.at[:, o:o + c].set(
                    jnp.where(act[None, :], INT_MAX, INT_MIN))
            # bound predicated columns from query params
            for name, col_idx, param_idx in node.bindings:
                o, c = plan.offsets[name] - base, plan.caps[name]
                act = queries[name]["active"]
                p = queries[name]["params"][:, param_idx]     # [cap, 2]
                lo = lo.at[col_idx, o:o + c].set(
                    jnp.where(act, p[:, 0], INT_MAX))
                hi = hi.at[col_idx, o:o + c].set(
                    jnp.where(act, p[:, 1], INT_MIN))
            cols = (jnp.stack([tbl[c] for c in node.cols])
                    if node.cols else jnp.zeros((1, T), jnp.int32))
            m = ops.shared_scan(cols, lo, hi, tbl["_valid"])
            scan_masks[table] = jnp.pad(m, ((0, 0), (wlo, W_full - whi)))

        # 3. shared joins: ONE big join per signature, query_id in the
        #    predicate via bitmask intersection; non-subscribers pass through
        spine_masks = {t: scan_masks[t] for t in plan.scans}
        join_rids = {}
        for node, sub in zip(plan.joins, join_subs):
            tbl = storage[node.spine]
            pk_schema = cat.schemas[node.pk_table]
            rid, combined = ops.shared_join_fk(
                tbl[node.fk_col], spine_masks[node.spine],
                storage[node.pk_table]["_pk_index"],
                scan_masks[node.pk_table])
            m = spine_masks[node.spine]
            spine_masks[node.spine] = (combined & sub[None, :]) \
                | (m & ~sub[None, :])
            join_rids[(node.spine, node.fk_col, node.pk_table)] = rid

        # 4. shared sorts + fused per-query top-n + routing (Gamma).
        #    Per the paper (Fig. 4), the sort runs over the UNION of
        #    tuples wanted by the node's subscribers — extracted with a
        #    bounded cap; each node only touches its subscribers' words.
        results = {}
        routed = set()
        overflow = jnp.zeros((), jnp.int32)
        for node, sub in zip(plan.sorts, sort_subs):
            wlo, whi = plan.word_range(node.subscribers)
            mask = spine_masks[node.spine][:, wlo:whi] \
                & sub[None, wlo:whi]
            T = cat.schemas[node.spine].capacity
            cap = min(T, plan.union_cap)
            rows_c, cmask, n_want = ops.compress_union(mask, cap)
            overflow += jnp.maximum(n_want - cap, 0)
            keys = storage[node.spine][node.col][
                jnp.maximum(rows_c, 0)]
            keys = jnp.where(rows_c >= 0,
                             -keys if node.desc else keys, ops.INT_MAX)
            perm = jnp.argsort(keys, stable=True)
            rows = ops.route_topn(cmask[perm],
                                  limits[wlo * 32:whi * 32],
                                  plan.max_results, rows=rows_c[perm])
            for name in node.subscribers:
                o, c = plan.offsets[name], plan.caps[name]
                results[name] = {"rows": rows[o - wlo * 32:
                                              o - wlo * 32 + c]}
                routed.add(name)

        # 5. shared group-bys (phase 1 shared over the union, phase 2 per
        #    query)
        for node in plan.groups:
            agg = node.agg
            tbl = storage[node.spine]
            wlo, whi = plan.word_range(node.subscribers)
            T = cat.schemas[node.spine].capacity
            cap = min(T, plan.group_union_cap)
            rows_c, cmask, n_want = ops.compress_union(
                spine_masks[node.spine][:, wlo:whi], cap)
            overflow += jnp.maximum(n_want - cap, 0)
            safe = jnp.maximum(rows_c, 0)
            gcodes = jnp.where(rows_c >= 0, tbl[agg.group_col][safe], 0)
            gvals = jnp.where(rows_c >= 0, tbl[agg.agg_col][safe], 0)
            count, ssum = ops.shared_groupby(gcodes, gvals, cmask,
                                             agg.n_groups)
            score = ssum if agg.order_by == "sum" else count
            top_val, top_grp = jax.lax.top_k(score.T, agg.top_k)  # [q, K]
            for name in node.subscribers:
                o = plan.offsets[name] - wlo * 32
                c = plan.caps[name]
                results[name] = {
                    "groups": top_grp[o:o + c].astype(jnp.int32),
                    "scores": top_val[o:o + c],
                    "counts": jnp.take_along_axis(
                        count.T[o:o + c], top_grp[o:o + c], axis=1)}
                routed.add(name)

        # 6. unsorted templates route in natural row order — ONE routing
        #    pass per spine shared by all such templates
        by_spine: Dict[str, List[str]] = {}
        for name, t in plan.templates.items():
            if name not in routed:
                by_spine.setdefault(t.spine, []).append(name)
        for spine, names in by_spine.items():
            wlo, whi = plan.word_range(names)
            sub = jnp.asarray(plan.sub_mask(names))
            mask = spine_masks[spine][:, wlo:whi] & sub[None, wlo:whi]
            T = cat.schemas[spine].capacity
            cap = min(T, plan.union_cap)
            rows_c, cmask, n_want = ops.compress_union(mask, cap)
            overflow += jnp.maximum(n_want - cap, 0)
            rows = ops.route_topn(cmask, limits[wlo * 32:whi * 32],
                                  plan.max_results, rows=rows_c)
            for name in names:
                o, c = plan.offsets[name], plan.caps[name]
                results[name] = {"rows": rows[o - wlo * 32:
                                              o - wlo * 32 + c]}
        results["_overflow"] = overflow

        # attach join rids so hosts can materialize joined tuples
        results["_join_rids"] = join_rids
        return storage, results

    return cycle

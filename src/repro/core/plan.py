"""Global query plan (paper §3.2-3.3, Fig. 2/3/6).

The whole workload — a set of parameterized query *templates* (the JDBC
PreparedStatements of the paper) — compiles ONCE into a single dataflow
plan shared by every concurrent query:

  1. per query template, predicates are pushed down to base tables
     (logical optimization, Fig. 3 middle);
  2. templates are merged: ONE shared scan node per base table, ONE shared
     join node per (spine, fk, pk) signature, ONE shared sort node per
     (spine, column, direction), ONE shared group-by node per
     (spine, group-col, agg-col) — sharing across templates AND across
     concurrent instances of the same template falls out automatically;
  3. each template is assigned a static slot range in the global query-id
     space; per-node subscriber bitmasks select which queries a node's
     output applies to (queries become data).

The compiled plan is then LOWERED to an explicit staged operator graph
(lowering.py) whose hot loops resolve through the operator-backend
registry (backends.py: jnp reference ops or Pallas TPU kernels), and the
resulting pure cycle function executes once per heartbeat (executor.py);
its jitted XLA executable is the paper's always-on plan.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import operators as ops
from repro.core.storage import Catalog

INT_MIN = ops.INT_MIN
INT_MAX = ops.INT_MAX


# ---------------------------------------------------------------------------
# Template language
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Pred:
    table: str
    col: str               # parameterized inclusive range [lo, hi]


@dataclasses.dataclass(frozen=True)
class Join:
    fk_col: str            # on the spine
    pk_table: str


@dataclasses.dataclass(frozen=True)
class GroupAgg:
    group_col: str         # spine-local dict-encoded column
    n_groups: int
    agg_col: str           # spine-local value column (summed)
    top_k: int
    order_by: str = "sum"  # "sum" | "count"


@dataclasses.dataclass(frozen=True)
class QueryTemplate:
    name: str
    spine: str
    preds: Tuple[Pred, ...] = ()
    joins: Tuple[Join, ...] = ()
    sort_col: Optional[str] = None     # spine-local
    sort_desc: bool = False
    limit: int = 16
    group: Optional[GroupAgg] = None

    def tables(self) -> Tuple[str, ...]:
        return (self.spine,) + tuple(j.pk_table for j in self.joins)


# ---------------------------------------------------------------------------
# Compiled plan
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ScanNode:
    table: str
    cols: Tuple[str, ...]                  # predicated columns
    # per (template, pred) -> (col index, param index): filled by compiler
    bindings: List[Tuple[str, int, int]]   # (template, col_idx, param_idx)
    referencing: List[str]                 # templates whose graph has table


@dataclasses.dataclass
class JoinNode:
    spine: str
    fk_col: str
    pk_table: str
    subscribers: List[str]


@dataclasses.dataclass
class SortNode:
    spine: str
    col: str
    desc: bool
    subscribers: List[str]


@dataclasses.dataclass
class GroupNode:
    spine: str
    agg: GroupAgg
    subscribers: List[str]


@dataclasses.dataclass
class CompiledPlan:
    catalog: Catalog
    templates: Dict[str, QueryTemplate]
    caps: Dict[str, int]                   # per-template slot capacity
    offsets: Dict[str, int]                # slot range start per template
    qcap: int                              # global query-id capacity
    scans: Dict[str, ScanNode]
    joins: List[JoinNode]
    sorts: List[SortNode]
    groups: List[GroupNode]
    max_results: int
    # bounded union-extraction capacities (paper §3.5: work is a static
    # function of these, independent of query count; overflow is counted)
    union_cap: int = 8192
    group_union_cap: int = 16384

    @property
    def n_params_max(self) -> int:
        """Packed admission depth: max predicate count over templates.

        The executor stages ONE [qcap, n_params_max, 2] parameter buffer
        per heartbeat; each template's slots use rows [0, len(preds))."""
        return max([len(t.preds) for t in self.templates.values()] + [1])

    def sub_mask(self, names: List[str]) -> np.ndarray:
        """uint32[W] subscriber word-mask for a set of templates."""
        bits = np.zeros(self.qcap, bool)
        for t in names:
            bits[self.offsets[t]:self.offsets[t] + self.caps[t]] = True
        W = self.qcap // 32
        out = np.zeros(W, np.uint32)
        for w in range(W):
            val = 0
            for b in range(32):
                if bits[w * 32 + b]:
                    val |= (1 << b)
            out[w] = val
        return out

    def word_range(self, names: List[str]) -> Tuple[int, int]:
        """Smallest [wlo, whi) word window covering these templates' slots.

        Templates are laid out spine-clustered (workload definition order),
        so per-node mask processing only touches its subscribers' words —
        the per-operator work no longer scales with the GLOBAL query
        capacity, only with the operator's own (paper §4.2: per-operator
        queues/capacity).
        """
        lo = min(self.offsets[t] for t in names)
        hi = max(self.offsets[t] + self.caps[t] for t in names)
        return lo // 32, -(-hi // 32)


def compile_plan(catalog: Catalog, templates: List[QueryTemplate],
                 caps: Dict[str, int], max_results: int = 64,
                 union_cap: int = 8192,
                 group_union_cap: int = 16384) -> CompiledPlan:
    offsets, off = {}, 0
    for t in templates:
        offsets[t.name] = off
        off += caps[t.name]
    qcap = -(-off // 32) * 32

    # --- scan nodes: one per table, union of predicated columns ----------
    scans: Dict[str, ScanNode] = {}
    for t in templates:
        for table in t.tables():
            node = scans.setdefault(
                table, ScanNode(table, (), [], []))
            if t.name not in node.referencing:
                node.referencing.append(t.name)
    for t in templates:
        for pi, p in enumerate(t.preds):
            node = scans[p.table]
            if p.col not in node.cols:
                node.cols = node.cols + (p.col,)
            node.bindings.append((t.name, node.cols.index(p.col), pi))

    # --- join nodes: dedupe by (spine, fk, pk) ----------------------------
    joins: Dict[Tuple[str, str, str], JoinNode] = {}
    for t in templates:
        for j in t.joins:
            key = (t.spine, j.fk_col, j.pk_table)
            node = joins.setdefault(
                key, JoinNode(t.spine, j.fk_col, j.pk_table, []))
            node.subscribers.append(t.name)

    # --- sort nodes: dedupe by (spine, col, desc) --------------------------
    sorts: Dict[Tuple[str, str, bool], SortNode] = {}
    for t in templates:
        if t.sort_col:
            key = (t.spine, t.sort_col, t.sort_desc)
            node = sorts.setdefault(
                key, SortNode(t.spine, t.sort_col, t.sort_desc, []))
            node.subscribers.append(t.name)

    # --- group-by nodes ----------------------------------------------------
    groups: Dict[Tuple[str, str, str], GroupNode] = {}
    for t in templates:
        if t.group:
            key = (t.spine, t.group.group_col, t.group.agg_col)
            node = groups.setdefault(key, GroupNode(t.spine, t.group, []))
            node.subscribers.append(t.name)

    return CompiledPlan(
        catalog=catalog,
        templates={t.name: t for t in templates},
        caps=dict(caps), offsets=offsets, qcap=qcap,
        scans=scans, joins=list(joins.values()),
        sorts=list(sorts.values()), groups=list(groups.values()),
        max_results=max_results,
        union_cap=union_cap, group_union_cap=group_union_cap)


# The cycle functions themselves live in lowering.py: ``build_cycle``
# (full rescan, seeds the carried scan words) and ``build_delta_cycle``
# (the incremental heartbeat).  The executor lowers the plan once and
# binds both to one operator backend (backends.py: kernels="jnp" |
# "pallas" | "auto", REPRO_KERNELS override honoured).

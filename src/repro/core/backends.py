"""Operator backend registry: jnp reference ops vs Pallas TPU kernels.

The lowered operator graph (lowering.py) is backend-agnostic: every stage
that has a compute hot-spot resolves its implementation through this
registry at plan-build time.  Two backends ship:

  * ``jnp``    — the pure-jnp reference operators (kernels/ref.py).  This
                 is the CPU execution path AND the semantic oracle every
                 Pallas kernel is validated against.
  * ``pallas`` — the TPU kernels (kernels/clockscan.py, bitmask_join.py,
                 shared_groupby.py), run in interpret mode off-TPU so the
                 full engine path stays testable on CPU.

Backend surface (the shared-operator hot loops):

  scan(cols, lo, hi, valid)                 -> uint32[T, W]   (ClockScan)
  scan_delta(cols, lo, hi, valid, rows)     -> uint32[D, W]   (dirty rows)
  join_block(kl, ml, kr, mr, valid_r)       -> (rid, mask)    (shared join)
  join_partitioned(kl, ml, bkeys, brows,
                   bounds, mr)              -> (rid, mask)    (bucketed join)
  join_delta(kl, rows, bkeys, brows,
             bounds)                        -> rid int32[D]   (dirty probe)
  groupby(codes, vals, mask, n_groups)      -> (count, sum)
  fused_delta(scan_in, join_in)             -> (words, rids)  (OPTIONAL —
      the whole delta beat in ONE op: every predicated stage's admission
      pane + dirty-row rescan merged into its carried words, every
      carried join's dirty-spine-row probe merged into its carried rid
      array.  ``scan_in``/``join_in`` are tuples of FusedScanIn /
      FusedJoinIn below.  A backend that leaves this None falls back to
      the chained scan/scan_delta/join_delta ops in build_delta_cycle.)

Everything else in the cycle — the dense PK-index gather join, union
compression, argsort and result routing — lowers directly to XLA
gather/sort/scatter and is shared verbatim by both backends (see
core/operators.py).

Resolution: ``resolve_backend("jnp"|"pallas"|"auto")``.  ``auto`` honours
the ``REPRO_KERNELS`` environment override (the kernel test-suite's knob;
``ref`` is accepted as an alias of ``jnp``), else picks Pallas exactly
when a TPU backend is present.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Callable, Dict, NamedTuple, Optional, Tuple

import jax


class FusedScanIn(NamedTuple):
    """One predicated scan stage's inputs to the fused delta op.

    The lowering computes the pane window host-free (``_pane_window``)
    and pre-slices the pane predicate matrix, so the op itself never
    re-derives admission state.  ``rows`` is the stage table's sorted
    distinct dirty-row set padded with the capacity sentinel (==
    ``cols.shape[1]``), ``dn`` its live count — the op may use ``dn``
    (and ``span``) to skip no-op phases, which is exact because a
    zero-span pane recompute and an all-sentinel scatter are both
    identities on the carried words.
    """
    cols: object          # int32[C, T] predicated columns
    lo: object            # int32[C, Q] full-window predicate lows
    hi: object            # int32[C, Q] full-window predicate highs
    lo_p: object          # int32[C, 32*A] pane slice of lo at w0
    hi_p: object          # int32[C, 32*A] pane slice of hi at w0
    valid: object         # bool[T]
    carry: object         # uint32[T, w] previous heartbeat's words
    w0: object            # int32 scalar: pane's first word column
    span: object          # int32 scalar: changed-word span (0 = none)
    rows: object          # int32[D] dirty rows (sentinel == T pads)
    dn: object            # int32 scalar: live dirty-row count


class FusedJoinIn(NamedTuple):
    """One carried (non-gather) join's inputs to the fused delta op.

    Block-kind joins arrive as single-bucket pseudo-partitions (the
    whole PK side is one pane with bound INT_MIN), so every carried join
    probes through the same one-bucket-per-dirty-row path.
    """
    keys: object          # int32[Tl] the spine's full fk column
    rows: object          # int32[D] dirty spine rows (sentinel == Tl)
    dn: object            # int32 scalar: live dirty-row count
    bkeys: object         # int32[P, B] bucket keys
    brows: object         # int32[P, B] bucket row ids (-1 pad)
    bounds: object        # int32[P] bucket lower bounds
    rid_carry: object     # int32[Tl] previous heartbeat's rids


@dataclasses.dataclass(frozen=True)
class OperatorBackend:
    """Implementations for the shared-operator hot loops.

    All callables must be traceable (pure jax) — they are baked into the
    always-on compiled plan at build time.
    """
    name: str
    scan: Callable        # (cols[C,T], lo[C,Q], hi[C,Q], valid[T]) -> u32[T,W]
    join_block: Callable  # (kl[Tl], ml[Tl,W], kr[Tr], mr[Tr,W], vr[Tr])
                          #   -> (rid int32[Tl], mask u32[Tl,W])
    join_partitioned: Callable  # (kl[Tl], ml[Tl,W], bkeys[P,B], brows[P,B],
                                #  bounds[P], mr[Tr,W]) -> (rid, mask)
    groupby: Callable     # (codes[T], vals[T], mask[T,W], G) -> (cnt, sum)
    scan_delta: Callable  # (cols[C,T], lo[C,Q], hi[C,Q], valid[T],
                          #  rows[D] (-1 pad)) -> u32[D,W]  (dirty rescan)
    join_delta: Callable  # (kl[Tl], rows[D] (pad >= Tl), bkeys[P,B],
                          #  brows[P,B], bounds[P]) -> rid int32[D]
                          #  (dirty-spine-row partitioned probe)
    # the whole delta beat in ONE op (None -> chained fallback):
    # (scan_in: tuple[FusedScanIn], join_in: tuple[FusedJoinIn])
    #   -> (tuple of merged uint32[T, w] words — one per scan_in entry,
    #       tuple of merged int32[Tl] rids — one per join_in entry)
    fused_delta: Optional[Callable] = None


_REGISTRY: Dict[str, OperatorBackend] = {}


def register_backend(backend: OperatorBackend) -> None:
    _REGISTRY[backend.name] = backend


def available_backends() -> Tuple[str, ...]:
    _ensure_registered()
    return tuple(sorted(_REGISTRY))


def _ensure_registered() -> None:
    if "pallas" not in _REGISTRY:
        import repro.kernels  # noqa: F401  (registers the pallas backend)


def get_backend(name: str) -> OperatorBackend:
    _ensure_registered()
    if name not in _REGISTRY:
        raise KeyError(f"unknown backend {name!r}; "
                       f"available: {available_backends()}")
    return _REGISTRY[name]


def resolve_backend(kernels: str = "auto") -> OperatorBackend:
    """Map a ``kernels=`` spec to a concrete backend.

    "jnp" / "ref" -> the reference backend; "pallas" -> the TPU kernels;
    "auto" -> REPRO_KERNELS override if set, else Pallas iff running on a
    TPU backend.  Any other explicitly REGISTERED backend name resolves
    too (instrumented/wrapped backends in tests); unknown names raise
    ValueError.
    """
    if kernels in ("jnp", "ref"):
        return get_backend("jnp")
    if kernels == "pallas":
        return get_backend("pallas")
    if kernels != "auto":
        _ensure_registered()
        if kernels in _REGISTRY:
            return _REGISTRY[kernels]
        raise ValueError(f"kernels must be 'jnp', 'pallas', 'auto' or a "
                         f"registered backend name, got {kernels!r}")
    forced = os.environ.get("REPRO_KERNELS")
    if forced and forced != "auto":
        try:
            return resolve_backend(forced)
        except ValueError as e:
            raise ValueError(f"REPRO_KERNELS: {e}") from None
    return get_backend(
        "pallas" if jax.default_backend() == "tpu" else "jnp")


# ---------------------------------------------------------------------------
# The jnp reference backend (oracle + CPU execution path)
# ---------------------------------------------------------------------------


def _jnp_scan(cols, lo, hi, valid):
    from repro.kernels import ref
    return ref.clockscan_ref(cols, lo, hi, valid)


def _jnp_join_block(keys_l, mask_l, keys_r, mask_r, valid_r):
    from repro.kernels import ref
    return ref.bitmask_join_ref(keys_l, mask_l, keys_r, mask_r, valid_r)


def _jnp_join_partitioned(keys_l, mask_l, bucket_keys, bucket_rows, bounds,
                          mask_r):
    from repro.kernels import ref
    return ref.partitioned_join_ref(keys_l, mask_l, bucket_keys,
                                    bucket_rows, bounds, mask_r)


def _jnp_groupby(group_code, values, mask, n_groups):
    from repro.kernels import ref
    return ref.shared_groupby_ref(group_code, values, mask, n_groups)


def _jnp_scan_delta(cols, lo, hi, valid, rows):
    from repro.kernels import ref
    return ref.delta_scan_ref(cols, lo, hi, valid, rows)


def _jnp_join_delta(keys_l, rows, bucket_keys, bucket_rows, bounds):
    from repro.kernels import ref
    return ref.delta_join_ref(keys_l, rows, bucket_keys, bucket_rows,
                              bounds)


def _jnp_fused_delta(scan_in, join_in):
    from repro.kernels import ref
    return ref.fused_delta_ref(scan_in, join_in)


register_backend(OperatorBackend(
    name="jnp", scan=_jnp_scan, join_block=_jnp_join_block,
    join_partitioned=_jnp_join_partitioned, groupby=_jnp_groupby,
    scan_delta=_jnp_scan_delta, join_delta=_jnp_join_delta,
    fused_delta=_jnp_fused_delta))


# ---------------------------------------------------------------------------
# Instrumentation: per-op launch counting
# ---------------------------------------------------------------------------

_COUNTED_OPS = ("scan", "join_block", "join_partitioned", "groupby",
                "scan_delta", "join_delta", "fused_delta")


def counting_backend(base: OperatorBackend, counts: Dict[str, int],
                     name: Optional[str] = None) -> OperatorBackend:
    """Wrap every op of ``base`` to bump ``counts[op]`` per invocation.

    Backend ops are invoked at TRACE time (the cycles are jitted), so
    with a jitted engine the counts are the per-beat STATIC launch
    counts of the traced cycle — the executor clears the dict at traced-
    function entry, so retraces never double-count.  With ``jit=False``
    the same wrapper counts actual per-call invocations.  The wrapped
    ops delegate verbatim, so stacking this over a recording backend
    keeps the recording intact.
    """
    def wrap(op, opname):
        if op is None:
            return None

        def counted(*args, **kwargs):
            counts[opname] = counts.get(opname, 0) + 1
            return op(*args, **kwargs)
        return counted

    return OperatorBackend(
        name=name or f"counting-{base.name}",
        **{op: wrap(getattr(base, op), op) for op in _COUNTED_OPS})

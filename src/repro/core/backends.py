"""Operator backend registry: jnp reference ops vs Pallas TPU kernels.

The lowered operator graph (lowering.py) is backend-agnostic: every stage
that has a compute hot-spot resolves its implementation through this
registry at plan-build time.  Two backends ship:

  * ``jnp``    — the pure-jnp reference operators (kernels/ref.py).  This
                 is the CPU execution path AND the semantic oracle every
                 Pallas kernel is validated against.
  * ``pallas`` — the TPU kernels (kernels/clockscan.py, bitmask_join.py,
                 shared_groupby.py), run in interpret mode off-TPU so the
                 full engine path stays testable on CPU.

Backend surface (the shared-operator hot loops):

  scan(cols, lo, hi, valid)                 -> uint32[T, W]   (ClockScan)
  scan_delta(cols, lo, hi, valid, rows)     -> uint32[D, W]   (dirty rows)
  join_block(kl, ml, kr, mr, valid_r)       -> (rid, mask)    (shared join)
  join_partitioned(kl, ml, bkeys, brows,
                   bounds, mr)              -> (rid, mask)    (bucketed join)
  join_delta(kl, rows, bkeys, brows,
             bounds)                        -> rid int32[D]   (dirty probe)
  groupby(codes, vals, mask, n_groups)      -> (count, sum)

Everything else in the cycle — the dense PK-index gather join, union
compression, argsort and result routing — lowers directly to XLA
gather/sort/scatter and is shared verbatim by both backends (see
core/operators.py).

Resolution: ``resolve_backend("jnp"|"pallas"|"auto")``.  ``auto`` honours
the ``REPRO_KERNELS`` environment override (the kernel test-suite's knob;
``ref`` is accepted as an alias of ``jnp``), else picks Pallas exactly
when a TPU backend is present.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Callable, Dict, Tuple

import jax


@dataclasses.dataclass(frozen=True)
class OperatorBackend:
    """Implementations for the shared-operator hot loops.

    All callables must be traceable (pure jax) — they are baked into the
    always-on compiled plan at build time.
    """
    name: str
    scan: Callable        # (cols[C,T], lo[C,Q], hi[C,Q], valid[T]) -> u32[T,W]
    join_block: Callable  # (kl[Tl], ml[Tl,W], kr[Tr], mr[Tr,W], vr[Tr])
                          #   -> (rid int32[Tl], mask u32[Tl,W])
    join_partitioned: Callable  # (kl[Tl], ml[Tl,W], bkeys[P,B], brows[P,B],
                                #  bounds[P], mr[Tr,W]) -> (rid, mask)
    groupby: Callable     # (codes[T], vals[T], mask[T,W], G) -> (cnt, sum)
    scan_delta: Callable  # (cols[C,T], lo[C,Q], hi[C,Q], valid[T],
                          #  rows[D] (-1 pad)) -> u32[D,W]  (dirty rescan)
    join_delta: Callable  # (kl[Tl], rows[D] (pad >= Tl), bkeys[P,B],
                          #  brows[P,B], bounds[P]) -> rid int32[D]
                          #  (dirty-spine-row partitioned probe)


_REGISTRY: Dict[str, OperatorBackend] = {}


def register_backend(backend: OperatorBackend) -> None:
    _REGISTRY[backend.name] = backend


def available_backends() -> Tuple[str, ...]:
    _ensure_registered()
    return tuple(sorted(_REGISTRY))


def _ensure_registered() -> None:
    if "pallas" not in _REGISTRY:
        import repro.kernels  # noqa: F401  (registers the pallas backend)


def get_backend(name: str) -> OperatorBackend:
    _ensure_registered()
    if name not in _REGISTRY:
        raise KeyError(f"unknown backend {name!r}; "
                       f"available: {available_backends()}")
    return _REGISTRY[name]


def resolve_backend(kernels: str = "auto") -> OperatorBackend:
    """Map a ``kernels=`` spec to a concrete backend.

    "jnp" / "ref" -> the reference backend; "pallas" -> the TPU kernels;
    "auto" -> REPRO_KERNELS override if set, else Pallas iff running on a
    TPU backend.  Any other explicitly REGISTERED backend name resolves
    too (instrumented/wrapped backends in tests); unknown names raise
    ValueError.
    """
    if kernels in ("jnp", "ref"):
        return get_backend("jnp")
    if kernels == "pallas":
        return get_backend("pallas")
    if kernels != "auto":
        _ensure_registered()
        if kernels in _REGISTRY:
            return _REGISTRY[kernels]
        raise ValueError(f"kernels must be 'jnp', 'pallas', 'auto' or a "
                         f"registered backend name, got {kernels!r}")
    forced = os.environ.get("REPRO_KERNELS")
    if forced and forced != "auto":
        try:
            return resolve_backend(forced)
        except ValueError as e:
            raise ValueError(f"REPRO_KERNELS: {e}") from None
    return get_backend(
        "pallas" if jax.default_backend() == "tpu" else "jnp")


# ---------------------------------------------------------------------------
# The jnp reference backend (oracle + CPU execution path)
# ---------------------------------------------------------------------------


def _jnp_scan(cols, lo, hi, valid):
    from repro.kernels import ref
    return ref.clockscan_ref(cols, lo, hi, valid)


def _jnp_join_block(keys_l, mask_l, keys_r, mask_r, valid_r):
    from repro.kernels import ref
    return ref.bitmask_join_ref(keys_l, mask_l, keys_r, mask_r, valid_r)


def _jnp_join_partitioned(keys_l, mask_l, bucket_keys, bucket_rows, bounds,
                          mask_r):
    from repro.kernels import ref
    return ref.partitioned_join_ref(keys_l, mask_l, bucket_keys,
                                    bucket_rows, bounds, mask_r)


def _jnp_groupby(group_code, values, mask, n_groups):
    from repro.kernels import ref
    return ref.shared_groupby_ref(group_code, values, mask, n_groups)


def _jnp_scan_delta(cols, lo, hi, valid, rows):
    from repro.kernels import ref
    return ref.delta_scan_ref(cols, lo, hi, valid, rows)


def _jnp_join_delta(keys_l, rows, bucket_keys, bucket_rows, bounds):
    from repro.kernels import ref
    return ref.delta_join_ref(keys_l, rows, bucket_keys, bucket_rows,
                              bounds)


register_backend(OperatorBackend(
    name="jnp", scan=_jnp_scan, join_block=_jnp_join_block,
    join_partitioned=_jnp_join_partitioned, groupby=_jnp_groupby,
    scan_delta=_jnp_scan_delta, join_delta=_jnp_join_delta))

"""Bounded-computation model + SLA provisioning (paper §3.5).

SharedDB's key property: per-cycle work is a STATIC function of table
capacities and the query-slot capacity — never of the number of submitted
queries.  This module derives the worst-case cycle cost analytically from a
compiled plan and answers the paper's provisioning question: "if the SLA
says 3 seconds, provision so a worst-case cycle takes <= 1.5 s" (a query
waits at most one cycle and executes in the next).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict

from repro.core.plan import CompiledPlan


@dataclasses.dataclass(frozen=True)
class HwModel:
    flops_per_s: float = 197e12      # per chip (TPU v5e bf16)
    bytes_per_s: float = 819e9       # HBM
    sort_const: float = 8.0          # comparisons per element per log2


def cycle_cost(plan: CompiledPlan, hw: HwModel = HwModel()) -> Dict:
    """Worst-case per-cycle flops/bytes per plan node (single chip)."""
    Q = plan.qcap
    W = Q // 32
    nodes = {}
    total_flops = total_bytes = 0.0
    for table, node in plan.scans.items():
        T = plan.catalog.schemas[table].capacity
        C = max(len(node.cols), 1)
        f = 4.0 * T * Q * C + 2.0 * T * Q          # compares + pack
        b = 4.0 * T * C + 4.0 * T * W
        nodes[f"scan:{table}"] = {"flops": f, "bytes": b}
        total_flops += f
        total_bytes += b
    for j in plan.joins:
        T = plan.catalog.schemas[j.spine].capacity
        f = 2.0 * T * W
        b = T * (8.0 + 8.0 * W)                    # fk+rid gather + masks
        nodes[f"join:{j.spine}->{j.pk_table}"] = {"flops": f, "bytes": b}
        total_flops += f
        total_bytes += b
    for s in plan.sorts:
        T = plan.catalog.schemas[s.spine].capacity
        f = hw.sort_const * T * max(math.log2(T), 1.0)
        b = 8.0 * T * (1 + W)
        nodes[f"sort:{s.spine}.{s.col}"] = {"flops": f, "bytes": b}
        total_flops += f
        total_bytes += b
    for g in plan.groups:
        T = plan.catalog.schemas[g.spine].capacity
        f = 4.0 * T * g.agg.n_groups * Q / 1024    # MXU contraction, tiled
        f = max(f, 4.0 * T * Q)                    # segment-sum floor
        b = 4.0 * T * (1 + W) + 8.0 * g.agg.n_groups * Q
        nodes[f"group:{g.spine}.{g.agg.group_col}"] = {"flops": f,
                                                       "bytes": b}
        total_flops += f
        total_bytes += b
    t_flops = total_flops / hw.flops_per_s
    t_bytes = total_bytes / hw.bytes_per_s
    return {"nodes": nodes, "total_flops": total_flops,
            "total_bytes": total_bytes,
            "worst_cycle_s": max(t_flops, t_bytes)}


def provision(plan: CompiledPlan, sla_seconds: float,
              hw: HwModel = HwModel()) -> Dict:
    """Chips needed so worst-case latency (2 cycles) meets the SLA,
    assuming operator replication / partitioning scales linearly (§4.5)."""
    cost = cycle_cost(plan, hw)
    budget = sla_seconds / 2.0
    chips = max(1, math.ceil(cost["worst_cycle_s"] / budget))
    return {"worst_cycle_s": cost["worst_cycle_s"],
            "cycle_budget_s": budget,
            "chips_required": chips,
            "guarantee": f"p100 latency <= {sla_seconds}s at ANY "
                         f"concurrency <= {plan.qcap} queries/cycle"}

"""Mesh-aware sharding of the always-on plan (multi-device heartbeats).

SharedDB scales shared operators by giving each one its own core (paper
§4.5); on a JAX device mesh the analogue is sharding the spine tables —
and the heartbeat carry itself — by spine-row range, so a full-rescan /
reseed beat scatters its bounded work across every shard while a
steady-state delta beat stays entirely shard-local.

Layout (the sharding contract):

  * ROW-SHARDED — every table that is NOT a join probe side.  Columns,
    validity, the carried scan words and the carried per-join rid
    arrays live as flat ``[Tp]``-leading arrays laid out in S
    contiguous shard blocks of ``Ts = Tp // S`` rows
    (``NamedSharding(mesh, P("row"))``; ``Tp`` is the table capacity
    rounded up to a multiple of S, padding rows permanently invalid).
    Each shard also keeps a PRIVATE dirty-row set of the update-batch
    rows it owns (``[S, dirty_cap]`` local row ids), so dirty rows
    route to their owning shard and the delta scan / delta join
    re-probes are per-shard gathers with no communication.
  * REPLICATED — every join PK-side table (the probe sides; dimension
    tables in TPC-W terms) is mirrored in full on every shard, plus
    the small replicated side state of sharded tables: the append
    cursor ``_n``, the dense ``_pk_index`` (global row ids) and — for
    index-less PK tables — a slim (key, valid) mirror so update
    targeting (``storage.locate_rows_by_key``) stays a replicated
    computation instead of a cross-shard reduction.

Beat structure (the whole heartbeat runs inside ONE ``shard_map``, so
every cross-shard transfer is an explicit collective in the jaxpr):

  * full / reseed beat (``build_sharded_cycle``) — replicated tables'
    predicated scan stages are computed SHARDED (each shard scans its
    row slice of the mirror) and ``all_gather``-ed back into the
    replicated words: the one collective in the system, touching every
    shard exactly once per stage.  Row-sharded stages rescan
    shard-locally.
  * delta beat (``build_sharded_delta_cycle``) — admission panes and
    dirty rows of replicated tables refresh by replicated compute from
    the mirror; row-sharded stages refresh shard-locally from their
    private dirty sets and carried words/rids.  The compiled delta
    heartbeat contains NO cross-shard collective (asserted on both the
    jaxpr and the optimized HLO by tests/test_sharding_locality.py).

Results: stages whose spine is replicated run replicated and return
final per-template results (reusing lowering's post-scan verbatim on
the filtered plan); stages on row-sharded spines return per-shard
partials — route/sort candidates with their comparison keys, group-by
partial aggregates — that ``build_merge``'s host-side merge folds into
final results at collect time.  Cross-shard result routing costs one
tiny host pass on data already bounded by the per-template limits,
instead of a device collective on every beat.

``SharedDBEngine(mesh=...)`` threads all of this through the executor;
a 1-shard mesh degrades to bit-identical behavior: padded shapes equal
the originals, each shard body sees the full row range, and the reseed
all_gather over one device is the identity.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import dataquery as dq
from repro.core import operators as ops
from repro.core.backends import (FusedJoinIn, FusedScanIn,
                                 OperatorBackend)
from repro.core.lowering import (LoweredPlan, _bind_predicates,
                                 _build_post_scan, _pane_window,
                                 _pseudo_partitions)
from repro.core.plan import CompiledPlan
from repro.core.storage import (Catalog, TableSchema, apply_updates,
                                build_key_partitions, bulk_load,
                                empty_table, locate_rows_by_key,
                                refresh_key_partitions,
                                scatter_dirty_rows)

ROW_AXIS = "row"

# replicated side-state keys of a row-sharded table (everything else in
# the table dict is a [Tp] / [S, ...] sharded leaf)
_SIDE_KEYS = ("_n", "_version", "_pk_index", "_mkey", "_mvalid")
# per-shard (stacked, NOT flat-row) leaves: leading axis is the shard
_STACKED_KEYS = ("_dirty_rows", "_dirty_n", "_dirty_overflow")


def make_row_mesh(n_shards: int) -> Mesh:
    """A 1-D ``(n_shards,)`` mesh over the first host devices."""
    devs = jax.devices()
    if len(devs) < n_shards:
        raise RuntimeError(
            f"need {n_shards} devices for a {n_shards}-shard row mesh, "
            f"have {len(devs)}; on CPU set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=8")
    return jax.make_mesh((n_shards,), (ROW_AXIS,),
                         devices=devs[:n_shards])


@dataclasses.dataclass(frozen=True)
class ShardSpec:
    """The sharding layout derived from (plan, mesh).

    ``mirrored`` — replicated tables (every join PK side).
    ``shard_rows``/``padded`` — per-table ``Ts`` and ``Tp = S * Ts``.
    ``plan`` — the compiled plan with the PADDED catalog (capacities
    rounded up so row ranges divide evenly; at S=1 this is the original
    plan object's geometry exactly).
    """
    mesh: Mesh
    axis: str
    n_shards: int
    mirrored: Tuple[str, ...]
    shard_rows: Dict[str, int]
    padded: Dict[str, int]
    # ORIGINAL capacities: the insert commit bound.  Rows in
    # [commit_rows, padded) exist only for shard alignment and stay
    # permanently invalid — the unsharded engine would have dropped
    # any insert landing there (storage.apply_updates commit_cap).
    commit_rows: Dict[str, int]
    plan: CompiledPlan

    def is_mirrored(self, table: str) -> bool:
        return table in self.mirrored

    def schema(self, table: str) -> TableSchema:
        return self.plan.catalog.schemas[table]

    def repl_sharding(self) -> NamedSharding:
        return NamedSharding(self.mesh, P())

    def row_sharding(self) -> NamedSharding:
        return NamedSharding(self.mesh, P(self.axis))

    def state_sharding(self, state):
        """Pytree of NamedSharding matching an engine state pytree."""
        repl, rows = self.repl_sharding(), self.row_sharding()
        out = {}
        for t, d in state.items():
            if self.is_mirrored(t):
                out[t] = {k: repl for k in d}
            else:
                out[t] = {k: (repl if k in _SIDE_KEYS else rows)
                          for k in d}
        return out


def build_shard_spec(plan: CompiledPlan, mesh: Mesh) -> ShardSpec:
    if len(mesh.axis_names) != 1:
        raise ValueError(f"row mesh must be 1-D, got {mesh.axis_names}")
    axis = mesh.axis_names[0]
    S = int(np.prod(mesh.devices.shape))
    mirrored = tuple(sorted({j.pk_table for j in plan.joins}))
    shard_rows, padded, commit_rows, schemas = {}, {}, {}, []
    for name, schema in plan.catalog.schemas.items():
        ts = -(-schema.capacity // S)
        shard_rows[name] = ts
        padded[name] = ts * S
        commit_rows[name] = schema.capacity
        schemas.append(dataclasses.replace(schema, capacity=ts * S))
    padded_plan = dataclasses.replace(plan, catalog=Catalog(schemas))
    return ShardSpec(mesh=mesh, axis=axis, n_shards=S, mirrored=mirrored,
                     shard_rows=shard_rows, padded=padded,
                     commit_rows=commit_rows, plan=padded_plan)


def check_fold_mirrors(old_plan: CompiledPlan,
                       new_plan: CompiledPlan) -> None:
    """A fold under a mesh must keep the sharded STATE layout fixed.

    Whether a table is mirrored (replicated probe side) or row-sharded
    is decided by join membership, and the two layouts store different
    leaves under different shardings — flipping a table would demand a
    cross-shard state migration mid-serve, and un-mirroring a table
    would put collectives back into the delta beats its probes ride on.
    The catalog itself is shared by construction (extend_plan refuses
    new tables), so padded capacities never move; this check closes the
    remaining degree of freedom.  Folds that only subscribe to existing
    joins, or add joins into already-mirrored PK tables, pass.

    The mirror-set comparison itself is the planlint pass
    ``analysis_static.ir_passes.lint_fold_mirrors`` (rule
    ``fold-mirror-set``); this entry point raises ``ValueError`` as
    before.
    """
    from repro.analysis_static.diagnostics import raise_on_error
    from repro.analysis_static.ir_passes import lint_fold_mirrors
    raise_on_error(lint_fold_mirrors(old_plan, new_plan),
                   exc=ValueError)


# ---------------------------------------------------------------------------
# State construction
# ---------------------------------------------------------------------------


def init_sharded_state(spec: ShardSpec, initial_data: Dict) -> Dict:
    """Padded + sharded initial state, placed on the mesh.

    Mirrored tables are full replicated table dicts (the existing
    storage layout, padded capacity).  Row-sharded tables keep their
    columns/_valid as flat ``[Tp]`` row-sharded leaves, per-shard dirty
    sets as ``[S, dirty_cap]``, and the replicated side state (append
    cursor, dense pk index, and the (key, valid) locate mirror for
    index-less PK tables).
    """
    S = spec.n_shards
    state = {}
    for name, schema in spec.plan.catalog.schemas.items():
        full = bulk_load(schema, initial_data[name]) \
            if name in initial_data else empty_table(schema)
        if spec.is_mirrored(name):
            state[name] = full
            continue
        t = {c: full[c] for c in schema.columns}
        t["_valid"] = full["_valid"]
        D = schema.dirty_cap
        Ts = spec.shard_rows[name]
        # per-shard dirty sets: LOCAL row ids, sentinel = Ts (clean)
        t["_dirty_rows"] = jnp.full((S, D), Ts, jnp.int32)
        t["_dirty_n"] = jnp.zeros((S,), jnp.int32)
        t["_dirty_overflow"] = jnp.zeros((S,), bool)
        t["_n"] = full["_n"]
        t["_version"] = full["_version"]
        if schema.indexed:
            t["_pk_index"] = full["_pk_index"]
        elif schema.pk:
            # index-less PK table: replicated (key, valid) mirror so
            # update targeting stays a replicated computation.  COPIES —
            # they live under a different sharding than the column
            # leaves they mirror, and the donated state must never hold
            # the same buffer twice.
            t["_mkey"] = jnp.array(full[schema.pk])
            t["_mvalid"] = jnp.array(full["_valid"])
        state[name] = t
    sharding = spec.state_sharding(state)
    return jax.tree.map(jax.device_put, state, sharding)


def _split_table(t: Dict) -> Tuple[Dict, Dict]:
    sh = {k: v for k, v in t.items() if k not in _SIDE_KEYS}
    side = {k: v for k, v in t.items() if k in _SIDE_KEYS}
    return sh, side


# ---------------------------------------------------------------------------
# Per-shard update apply
# ---------------------------------------------------------------------------


def _apply_shard(schema: TableSchema, spec: ShardSpec, local: Dict,
                 side: Dict, batch: Dict, offset):
    """One shard's slice of ``storage.apply_updates``.

    ``local`` holds this shard's ``[Ts]`` column slices plus its private
    dirty set; ``side`` the replicated side state.  Row targeting uses
    only replicated inputs (the dense pk index or the (key, valid)
    mirror), so every shard computes identical global rows and commits
    exactly the ones it owns — a replicated computation plus a local
    scatter, never a cross-shard reduction.  The side state is updated
    identically on every shard (deterministic, so it stays replicated).
    Semantics mirror ``apply_updates`` field for field: deletes, then
    post-delete-located column updates, then inserts, in slot order.
    """
    Ts = spec.shard_rows[schema.name]
    Tp = spec.padded[schema.name]
    t, s = dict(local), dict(side)
    touched = []                      # LOCAL dirty candidates, -1 = no-op

    if schema.pk:
        def locate(keys, mask):
            """Global row holding pk ``keys[i]`` (-1 absent/masked)."""
            if schema.indexed:
                return jnp.where(mask, s["_pk_index"][keys], -1)
            return jnp.where(
                mask, locate_rows_by_key(s["_mkey"], keys, s["_mvalid"]),
                -1)

        # deletes: invalidate owned rows; replicated side bookkeeping
        del_g = locate(batch["del_key"], batch["del_mask"])
        ok = del_g >= 0
        dl = del_g - offset
        own = ok & (dl >= 0) & (dl < Ts)
        t["_valid"] = t["_valid"].at[jnp.where(own, dl, Ts)].set(
            False, mode="drop")
        touched.append(jnp.where(own, dl, -1))
        if schema.indexed:
            s["_pk_index"] = s["_pk_index"].at[
                jnp.where(ok, batch["del_key"], schema.key_space)].set(
                -1, mode="drop")
        else:
            s["_mvalid"] = s["_mvalid"].at[jnp.where(ok, del_g, Tp)].set(
                False, mode="drop")

        # point updates, located POST-delete (arrival-order semantics)
        upd_g = locate(batch["upd_key"], batch["upd_mask"])
        ul = upd_g - offset
        uown = (upd_g >= 0) & (ul >= 0) & (ul < Ts)
        touched.append(jnp.where(uown, ul, -1))
        for ci, c in enumerate(schema.columns):
            sel = (batch["upd_col"] == ci) & uown
            rows = jnp.where(sel, ul, Ts)
            t[c] = t[c].at[rows].set(
                jnp.where(sel, batch["upd_val"], 0), mode="drop")
        if not schema.indexed:
            # the locate mirror tracks the pk COLUMN (which updates may
            # rewrite), exactly like the column itself
            pk_ci = schema.columns.index(schema.pk)
            selk = (batch["upd_col"] == pk_ci) & (upd_g >= 0)
            s["_mkey"] = s["_mkey"].at[jnp.where(selk, upd_g, Tp)].set(
                jnp.where(selk, batch["upd_val"], 0), mode="drop")

    # inserts: append at the replicated cursor; commit owned rows.  The
    # commit bound is the ORIGINAL capacity: rows in [cap_c, Tp) exist
    # only for shard alignment and must stay invalid, exactly like the
    # unsharded engine drops inserts past its capacity.
    cap_c = spec.commit_rows[schema.name]
    offs = jnp.cumsum(batch["ins_mask"].astype(jnp.int32)) - 1
    rows_g = jnp.where(batch["ins_mask"], s["_n"] + offs, Tp)
    rl = rows_g - offset
    lown = batch["ins_mask"] & (rows_g < cap_c) & (rl >= 0) & (rl < Ts)
    lrows = jnp.where(lown, rl, Ts)
    for c in schema.columns:
        t[c] = t[c].at[lrows].set(batch["ins_rows"][c], mode="drop")
    t["_valid"] = t["_valid"].at[lrows].set(True, mode="drop")
    touched.append(jnp.where(lown, rl, -1))
    s["_n"] = s["_n"] + jnp.sum(batch["ins_mask"].astype(jnp.int32))
    if schema.indexed:
        keys = jnp.where(batch["ins_mask"], batch["ins_rows"][schema.pk],
                         schema.key_space)
        # dropped inserts index as absent, matching apply_updates
        s["_pk_index"] = s["_pk_index"].at[keys].set(
            jnp.where(batch["ins_mask"] & (rows_g < cap_c), rows_g,
                      -1).astype(jnp.int32), mode="drop")
    elif schema.pk:
        irows = jnp.where(batch["ins_mask"] & (rows_g < cap_c), rows_g,
                          Tp)
        s["_mkey"] = s["_mkey"].at[irows].set(
            batch["ins_rows"][schema.pk], mode="drop")
        s["_mvalid"] = s["_mvalid"].at[irows].set(True, mode="drop")
    s["_version"] = s["_version"] + 1

    # private dirty set: the LOCAL rows this shard's slice was touched at
    cand = jnp.concatenate([x.astype(jnp.int32) for x in touched])
    D = t["_dirty_rows"].shape[0]
    if cand.shape[0] == 0:
        t["_dirty_rows"] = jnp.full((D,), Ts, jnp.int32)
        t["_dirty_n"] = jnp.zeros((), jnp.int32)
        t["_dirty_overflow"] = jnp.zeros((), bool)
        return t, s
    mark = jnp.zeros((Ts,), bool).at[
        jnp.where(cand >= 0, cand, Ts)].set(True, mode="drop")
    count = jnp.sum(mark.astype(jnp.int32))
    t["_dirty_rows"] = jnp.nonzero(
        mark, size=D, fill_value=Ts)[0].astype(jnp.int32)
    t["_dirty_n"] = jnp.minimum(count, D)
    t["_dirty_overflow"] = count > D
    return t, s


# ---------------------------------------------------------------------------
# Scan-stage helpers (shared by the replicated and shard-local paths)
# ---------------------------------------------------------------------------


def _stage_full(st, backend, covered, pidx, tbl, queries):
    cols = jnp.stack([tbl[c] for c in st.cols])
    _, lo, hi = _bind_predicates(st, covered, pidx, queries)
    return backend.scan(cols, lo, hi, tbl["_valid"])


def _stage_degenerate(st, covered, valid, queries):
    base = st.wlo * 32
    act = queries["active"][base:base + st.q_window]
    return dq.pack(valid[:, None] & (act & covered)[None])


def _stage_delta(st, backend, covered, pidx, tbl, carry_words, queries,
                 dirty_rows, dirty_overflow, capacity):
    """Admission pane + dirty rows against carried words (one stage).

    Identical math to ``lowering.build_delta_cycle``'s scan block; the
    caller picks the row universe: the full mirror (``capacity = Tp``,
    replicated) or one shard's slice (``capacity = Ts``, local dirty
    set).  Returns (merged words, overflow count).
    """
    base = st.wlo * 32
    _, lo, hi = _bind_predicates(st, covered, pidx, queries)
    cols = jnp.stack([tbl[c] for c in st.cols])
    w = st.whi - st.wlo
    A = st.delta_words
    qd = queries["changed"][base:base + st.q_window] & covered
    wch = jnp.any(qd.reshape(w, 32), axis=1)
    first = jnp.argmax(wch).astype(jnp.int32)
    last = (w - 1 - jnp.argmax(wch[::-1])).astype(jnp.int32)
    span = jnp.where(jnp.any(wch), last - first + 1, 0)
    over = jnp.maximum(span - A, 0)
    w0 = jnp.minimum(first, w - A)
    lo_a = jax.lax.dynamic_slice(lo, (0, w0 * 32), (lo.shape[0], A * 32))
    hi_a = jax.lax.dynamic_slice(hi, (0, w0 * 32), (hi.shape[0], A * 32))
    pane = backend.scan(cols, lo_a, hi_a, tbl["_valid"])
    m = jax.lax.dynamic_update_slice(carry_words, pane, (0, w0))
    dwords = backend.scan_delta(cols, lo, hi, tbl["_valid"], dirty_rows)
    m = scatter_dirty_rows(m, dirty_rows, dwords, capacity)
    over = over + dirty_overflow.astype(jnp.int32)
    return m, over


def _fused_scan_in(st, covered, pidx, tbl, carry_words, queries,
                   dirty_rows, dirty_overflow, dn):
    """One stage's FusedScanIn + overflow count: the ``_stage_delta``
    prologue (predicate bind, pane geometry, pane slices) with the
    compute deferred to the single fused op."""
    _, lo, hi = _bind_predicates(st, covered, pidx, queries)
    cols = jnp.stack([tbl[c] for c in st.cols])
    A = st.delta_words
    span, w0, over = _pane_window(st, covered, queries["changed"])
    lo_a = jax.lax.dynamic_slice(lo, (0, w0 * 32), (lo.shape[0], A * 32))
    hi_a = jax.lax.dynamic_slice(hi, (0, w0 * 32), (hi.shape[0], A * 32))
    return FusedScanIn(
        cols=cols, lo=lo, hi=hi, lo_p=lo_a, hi_p=hi_a,
        valid=tbl["_valid"], carry=carry_words, w0=w0, span=span,
        rows=dirty_rows, dn=dn.astype(jnp.int32)), \
        over + dirty_overflow.astype(jnp.int32)


def _pad_words(st, m, W):
    return jnp.pad(m, ((0, 0), (st.wlo, W - st.whi)))


# ---------------------------------------------------------------------------
# The sharded heartbeat
# ---------------------------------------------------------------------------


def _build_impl(lowered: LoweredPlan, backend: OperatorBackend,
                spec: ShardSpec, delta: bool, delta_joins: bool):
    plan = spec.plan                       # padded catalog
    cat = plan.catalog
    W = lowered.W
    S = spec.n_shards
    mirrored = set(spec.mirrored)
    sharded_tables = [t for t in cat.schemas if t not in mirrored]

    # stage classification: replicated (mirror) vs shard-local
    mi_scans = [st for st in lowered.scans if st.table in mirrored]
    sh_scans = [st for st in lowered.scans if st.table not in mirrored]
    sh_joins = [j for j in lowered.joins if j.spine not in mirrored]
    mi_joins = tuple(j for j in lowered.joins if j.spine in mirrored)
    sh_sorts = [s for s in lowered.sorts if s.spine not in mirrored]
    mi_sorts = tuple(s for s in lowered.sorts if s.spine in mirrored)
    sh_groups = [g for g in lowered.groups if g.spine not in mirrored]
    mi_groups = tuple(g for g in lowered.groups if g.spine in mirrored)
    sh_routes = [r for r in lowered.routes if r.spine not in mirrored]
    mi_routes = tuple(r for r in lowered.routes if r.spine in mirrored)

    # mirrored-spine post stages reuse lowering's post-scan verbatim on
    # the filtered (padded-catalog) plan: replicated compute
    mirror_post = _build_post_scan(
        dataclasses.replace(lowered, plan=plan, joins=mi_joins,
                            sorts=mi_sorts, groups=mi_groups,
                            routes=mi_routes), backend)

    # partitioned-join layouts over the PADDED mirror (same bucket_cap,
    # bucket count rounded up so padding rows fit; identical at S=1)
    part_specs = {}
    for j in lowered.joins:
        if j.kind == "partitioned":
            n_parts = -(-spec.padded[j.pk_table] // j.bucket_cap)
            part_specs.setdefault(j.pk_table,
                                  (j.pk_col, n_parts, j.bucket_cap))

    scan_covered = {st.table: jnp.asarray(st.covered)
                    for st in lowered.scans}
    scan_pidx = {st.table: jnp.asarray(st.param_idx)
                 for st in lowered.scans}
    join_subs = {j.key: jnp.asarray(j.sub_mask) for j in lowered.joins}
    sort_subs = [jnp.asarray(s.sub_mask) for s in sh_sorts]
    route_subs = [jnp.asarray(r.sub_mask) for r in sh_routes]
    limits = jnp.asarray(lowered.limits)
    carried_sh_spines = sorted({j.spine for j in sh_joins
                                if j.kind != "gather"})
    # fused delta beat: every pane, dirty rescan and dirty probe — over
    # mirrors AND shard-local slices — collapses into ONE backend op per
    # shard (a backend without fused_delta keeps the chained stages)
    fused = delta and backend.fused_delta is not None

    def body(sh_in: Dict, repl_in: Dict):
        """One shard's slice of the heartbeat (the whole beat runs in
        here under shard_map, so every cross-shard transfer is an
        explicit collective — and the delta flavour has none)."""
        idx = jax.lax.axis_index(spec.axis)
        queries = repl_in["queries"]
        updates = repl_in["updates"]

        # -- 1. update apply: mirrors replicated, sharded tables local
        # (insert commits bounded by the ORIGINAL capacity either way —
        # alignment padding rows stay permanently invalid)
        mirror = {t: apply_updates(cat.schemas[t], repl_in["mirror"][t],
                                   updates[t],
                                   commit_cap=spec.commit_rows[t])
                  for t in spec.mirrored}
        tables, sides = {}, {}
        for t in sharded_tables:
            local = {k: (v[0] if k in _STACKED_KEYS else v)
                     for k, v in sh_in["tables"][t].items()}
            tables[t], sides[t] = _apply_shard(
                cat.schemas[t], spec, local, repl_in["sides"][t],
                updates[t], idx * spec.shard_rows[t])

        # -- 2. key partitions (replicated: derived from the mirror)
        partitions, rebuilt = {}, {}
        for t, (pk_col, n_parts, bucket_cap) in part_specs.items():
            m = mirror[t]
            if delta:
                partitions[t], rebuilt[t] = refresh_key_partitions(
                    m, pk_col, n_parts, bucket_cap,
                    repl_in["carry_parts"][t])
            else:
                partitions[t] = build_key_partitions(
                    m[pk_col], m["_valid"], n_parts, bucket_cap)
                rebuilt[t] = jnp.ones((), bool)

        # -- 3. mirrored scan stages
        mirror_words = {}                 # window-local, replicated
        delta_over_repl = jnp.zeros((), jnp.int32)   # identical per shard
        delta_over_local = jnp.zeros((), jnp.int32)  # this shard's own
        fused_scan, fused_own = [], []    # inputs + ("mi"/"sh", stage)
        for st in mi_scans:
            mt = mirror[st.table]
            if not st.cols:
                mirror_words[st.table] = _stage_degenerate(
                    st, scan_covered[st.table], mt["_valid"], queries)
            elif fused:
                e, o = _fused_scan_in(
                    st, scan_covered[st.table], scan_pidx[st.table], mt,
                    repl_in["carry_m"][st.table], queries,
                    mt["_dirty_rows"], mt["_dirty_overflow"],
                    mt["_dirty_n"])
                fused_scan.append(e)
                fused_own.append(("mi", st))
                delta_over_repl = delta_over_repl + o
            elif delta:
                # replicated maintenance: pane + global dirty rows
                m, o = _stage_delta(
                    st, backend, scan_covered[st.table],
                    scan_pidx[st.table], mt,
                    repl_in["carry_m"][st.table], queries,
                    mt["_dirty_rows"], mt["_dirty_overflow"],
                    spec.padded[st.table])
                mirror_words[st.table] = m
                delta_over_repl = delta_over_repl + o
            else:
                # reseed: each shard scans its row SLICE of the mirror,
                # then one all_gather rebuilds the replicated words —
                # the full rescan is spread over every shard exactly
                # once (the only collective in the system)
                Ts = spec.shard_rows[st.table]
                sl = {c: jax.lax.dynamic_slice_in_dim(mt[c], idx * Ts,
                                                      Ts)
                      for c in st.cols}
                sl["_valid"] = jax.lax.dynamic_slice_in_dim(
                    mt["_valid"], idx * Ts, Ts)
                pane = _stage_full(st, backend, scan_covered[st.table],
                                   scan_pidx[st.table], sl, queries)
                mirror_words[st.table] = jax.lax.all_gather(
                    pane, spec.axis, tiled=True)

        # -- 4. row-sharded scan stages (shard-local, both flavours)
        sh_words = {}
        scan_masks = {}
        for st in sh_scans:
            tbl = tables[st.table]
            if not st.cols:
                m = _stage_degenerate(st, scan_covered[st.table],
                                      tbl["_valid"], queries)
            elif fused:
                e, o = _fused_scan_in(
                    st, scan_covered[st.table], scan_pidx[st.table],
                    tbl, sh_in["carry"][st.table], queries,
                    tbl["_dirty_rows"], tbl["_dirty_overflow"],
                    tbl["_dirty_n"])
                fused_scan.append(e)
                fused_own.append(("sh", st))
                delta_over_local = delta_over_local + o
                continue
            elif delta:
                m, o = _stage_delta(
                    st, backend, scan_covered[st.table],
                    scan_pidx[st.table], tbl, sh_in["carry"][st.table],
                    queries, tbl["_dirty_rows"], tbl["_dirty_overflow"],
                    spec.shard_rows[st.table])
                delta_over_local = delta_over_local + o
                sh_words[st.table] = m
            else:
                m = _stage_full(st, backend, scan_covered[st.table],
                                scan_pidx[st.table], tbl, queries)
                sh_words[st.table] = m
            scan_masks[st.table] = _pad_words(st, m, W)

        # -- 4b. the ONE fused delta op: every deferred pane/dirty/probe
        #        unit — mirror and shard-local alike — in a single
        #        backend launch; the probe sides are replicated so the
        #        whole call is shard-local math (no collective)
        delta_probe = delta and delta_joins
        fused_join, fused_jkeys = [], []
        if fused and delta_probe:
            for st in sh_joins:
                if st.kind == "gather":
                    continue
                tbl = tables[st.spine]
                if st.kind == "partitioned":
                    bkeys, brows, bounds = partitions[st.pk_table]
                else:  # block: single-bucket pseudo-partitions
                    bkeys, brows, bounds = _pseudo_partitions(
                        mirror[st.pk_table], st.pk_col)
                fused_join.append(FusedJoinIn(
                    keys=tbl[st.fk_col], rows=tbl["_dirty_rows"],
                    dn=tbl["_dirty_n"].astype(jnp.int32),
                    bkeys=bkeys, brows=brows, bounds=bounds,
                    rid_carry=sh_in["rids"][st.key]))
                fused_jkeys.append(st.key)
            for st in mi_joins:
                if st.kind == "gather":
                    continue
                mt = mirror[st.spine]
                if st.kind == "partitioned":
                    bkeys, brows, bounds = partitions[st.pk_table]
                else:
                    bkeys, brows, bounds = _pseudo_partitions(
                        mirror[st.pk_table], st.pk_col)
                fused_join.append(FusedJoinIn(
                    keys=mt[st.fk_col], rows=mt["_dirty_rows"],
                    dn=mt["_dirty_n"].astype(jnp.int32),
                    bkeys=bkeys, brows=brows, bounds=bounds,
                    rid_carry=repl_in["rids_m"][st.key]))
                fused_jkeys.append(st.key)
        fused_rids = None
        if fused and (fused_scan or fused_join):
            words, rids = backend.fused_delta(tuple(fused_scan),
                                              tuple(fused_join))
            for (side, st), m in zip(fused_own, words):
                if side == "mi":
                    mirror_words[st.table] = m
                else:
                    sh_words[st.table] = m
                    scan_masks[st.table] = _pad_words(st, m, W)
            if delta_probe:
                fused_rids = dict(zip(fused_jkeys, rids))
        mirror_masks = {st.table: _pad_words(st, mirror_words[st.table],
                                             W) for st in mi_scans}

        # -- 5. joins on row-sharded spines (probe sides replicated:
        #       partitions / pk index / mirror words — shard-local math)
        spine_masks = dict(scan_masks)
        sh_rids = {}
        for st in sh_joins:
            tbl = tables[st.spine]
            m = spine_masks[st.spine]
            mask_r = mirror_masks[st.pk_table]
            Ts = spec.shard_rows[st.spine]
            if st.kind == "gather":
                rid, combined = ops.shared_join_fk(
                    tbl[st.fk_col], m, mirror[st.pk_table]["_pk_index"],
                    mask_r)
            elif delta_probe:
                if fused_rids is not None:
                    rid = fused_rids[st.key]   # merged in the fused op
                else:
                    dr = tbl["_dirty_rows"]
                    if st.kind == "partitioned":
                        bkeys, brows, bounds = partitions[st.pk_table]
                        rid_d = backend.join_delta(tbl[st.fk_col], dr,
                                                   bkeys, brows, bounds)
                    else:
                        pk_tbl = mirror[st.pk_table]
                        kd = tbl[st.fk_col][jnp.clip(dr, 0, Ts - 1)]
                        rid_d = locate_rows_by_key(pk_tbl[st.pk_col],
                                                   kd,
                                                   pk_tbl["_valid"])
                    rid = scatter_dirty_rows(sh_in["rids"][st.key], dr,
                                             rid_d, Ts)
                gathered = mask_r[jnp.clip(rid, 0, mask_r.shape[0] - 1)]
                combined = jnp.where((rid >= 0)[:, None], m & gathered,
                                     jnp.uint32(0))
            elif st.kind == "partitioned":
                bkeys, brows, bounds = partitions[st.pk_table]
                rid, combined = backend.join_partitioned(
                    tbl[st.fk_col], m, bkeys, brows, bounds, mask_r)
            else:
                pk_tbl = mirror[st.pk_table]
                rid, combined = backend.join_block(
                    tbl[st.fk_col], m, pk_tbl[st.pk_col], mask_r,
                    pk_tbl["_valid"])
            sub = join_subs[st.key]
            spine_masks[st.spine] = (combined & sub[None, :]) \
                | (m & ~sub[None, :])
            sh_rids[st.key] = rid
        if delta_probe:
            for spine in carried_sh_spines:
                delta_over_local = delta_over_local + \
                    tables[spine]["_dirty_overflow"].astype(jnp.int32)

        # -- 6. per-shard partials for row-sharded sort/group/route
        #       stages (merged host-side at collect; shard-local here)
        partials = {}
        over_local = jnp.zeros((), jnp.int32)
        for st, sub in zip(sh_sorts, sort_subs):
            mask = spine_masks[st.spine][:, st.wlo:st.whi] & sub[None, :]
            rows_c, cmask, n_want = ops.compress_union(mask,
                                                       st.union_cap)
            over_local = over_local + jnp.maximum(
                n_want - st.union_cap, 0)
            tbl = tables[st.spine]
            keys = tbl[st.col][jnp.maximum(rows_c, 0)]
            keys = jnp.where(rows_c >= 0,
                             -keys if st.desc else keys, ops.INT_MAX)
            perm = jnp.argsort(keys, stable=True)
            rows = ops.route_topn(cmask[perm],
                                  limits[st.wlo * 32:st.whi * 32],
                                  plan.max_results, rows=rows_c[perm])
            ksel = tbl[st.col][jnp.clip(rows, 0,
                                        spec.shard_rows[st.spine] - 1)]
            kcmp = jnp.where(rows >= 0, -ksel if st.desc else ksel,
                             ops.INT_MAX)
            offset = idx * spec.shard_rows[st.spine]
            rows_g = jnp.where(rows >= 0, rows + offset, -1)
            for name, o, c in st.slots:
                partials[name] = {"rows": rows_g[o:o + c][None],
                                  "keys": kcmp[o:o + c][None]}
        for st in sh_groups:
            agg = st.agg
            tbl = tables[st.spine]
            rows_c, cmask, n_want = ops.compress_union(
                spine_masks[st.spine][:, st.wlo:st.whi], st.union_cap)
            over_local = over_local + jnp.maximum(
                n_want - st.union_cap, 0)
            safe = jnp.maximum(rows_c, 0)
            gcodes = jnp.where(rows_c >= 0, tbl[agg.group_col][safe], 0)
            gvals = jnp.where(rows_c >= 0, tbl[agg.agg_col][safe], 0)
            count, ssum = backend.groupby(gcodes, gvals, cmask,
                                          agg.n_groups)
            gkey = f"group:{st.spine}:{agg.group_col}:{agg.agg_col}"
            partials[gkey] = {"count": count[None], "sum": ssum[None]}
        for st, sub in zip(sh_routes, route_subs):
            mask = spine_masks[st.spine][:, st.wlo:st.whi] & sub[None, :]
            rows_c, cmask, n_want = ops.compress_union(mask,
                                                       st.union_cap)
            over_local = over_local + jnp.maximum(
                n_want - st.union_cap, 0)
            rows = ops.route_topn(cmask,
                                  limits[st.wlo * 32:st.whi * 32],
                                  plan.max_results, rows=rows_c)
            offset = idx * spec.shard_rows[st.spine]
            rows_g = jnp.where(rows >= 0, rows + offset, -1)
            for name, o, c in st.slots:
                partials[name] = {"rows": rows_g[o:o + c][None]}

        # -- 7. mirrored-spine post stages: replicated, final results
        mi_rid_carry = None
        if delta_probe:
            mi_rid_carry = {j.key: repl_in["rids_m"][j.key]
                            for j in mi_joins if j.kind != "gather"}
            for spine in sorted({j.spine for j in mi_joins
                                 if j.kind != "gather"}):
                delta_over_repl = delta_over_repl + \
                    mirror[spine]["_dirty_overflow"].astype(jnp.int32)
        mi_storage = dict(mirror)
        mi_fused = None
        if fused_rids is not None:
            mi_fused = {j.key: fused_rids[j.key] for j in mi_joins
                        if j.kind != "gather"}
        mi_results = mirror_post(mi_storage, partitions, mirror_masks,
                                 rid_carry=mi_rid_carry,
                                 fused_rids=mi_fused)

        # -- 8. bundle outputs: (row-sharded, replicated)
        sh_out = {
            "tables": {t: {k: (v[None] if k in _STACKED_KEYS else v)
                           for k, v in tables[t].items()}
                       for t in sharded_tables},
            "words": sh_words,
            "rids": sh_rids,
            "partials": partials,
            "overflow": over_local[None],
        }
        if delta:
            sh_out["delta_overflow"] = delta_over_local[None]
        repl_out = {
            "mirror": mirror,
            "sides": sides,
            "mirror_words": mirror_words,
            "parts": partitions,
            "rebuilt": rebuilt,
            "results": mi_results,
        }
        if delta:
            repl_out["delta_overflow"] = delta_over_repl
        return sh_out, repl_out

    smap = shard_map(body, spec.mesh, in_specs=(P(spec.axis), P()),
                     out_specs=(P(spec.axis), P()), check_rep=False)

    def cycle(state, carry, rid_carry, queries, updates):
        sh_tables, sides = {}, {}
        for t in sharded_tables:
            sh_tables[t], sides[t] = _split_table(state[t])
        sh_in = {"tables": sh_tables}
        repl_in = {
            "mirror": {t: state[t] for t in spec.mirrored},
            "sides": sides,
            "queries": queries,
            "updates": updates,
        }
        if delta:
            sh_in["carry"] = {st.table: carry["scan"][st.table]
                              for st in sh_scans if st.cols}
            repl_in["carry_m"] = {st.table: carry["scan"][st.table]
                                  for st in mi_scans if st.cols}
            repl_in["carry_parts"] = carry["parts"]
        if delta and delta_joins:
            sh_in["rids"] = {j.key: rid_carry[j.key] for j in sh_joins
                             if j.kind != "gather"}
            repl_in["rids_m"] = {j.key: rid_carry[j.key]
                                 for j in mi_joins
                                 if j.kind != "gather"}
        sh_out, repl_out = smap(sh_in, repl_in)

        state_out = {}
        for t in spec.mirrored:
            state_out[t] = repl_out["mirror"][t]
        for t in sharded_tables:
            state_out[t] = {**sh_out["tables"][t],
                            **repl_out["sides"][t]}
        new_carry = {"scan": {**sh_out["words"],
                              **{st.table:
                                 repl_out["mirror_words"][st.table]
                                 for st in mi_scans if st.cols}},
                     "parts": repl_out["parts"]}
        results = dict(repl_out["results"])
        results["_join_rids"] = {**results["_join_rids"],
                                 **sh_out["rids"]}
        results["_overflow_sh"] = sh_out["overflow"]
        results["_shard"] = sh_out["partials"]
        results["_parts_rebuilt"] = repl_out["rebuilt"]
        if delta:
            results["_delta_overflow_sh"] = sh_out["delta_overflow"]
            results["_delta_overflow"] = repl_out["delta_overflow"]
        return state_out, new_carry, results

    if not delta:
        return lambda state, queries, updates: cycle(
            state, None, None, queries, updates)
    if delta_joins:
        return cycle
    return lambda state, carry, queries, updates: cycle(
        state, carry, None, queries, updates)


def build_sharded_cycle(lowered: LoweredPlan, backend: OperatorBackend,
                        spec: ShardSpec):
    """Full-rescan / reseed heartbeat over the mesh.

    Same signature and carry/results contract as ``lowering.build_cycle``
    (the sharded executor is a drop-in): the reseed work is scattered —
    every shard rescans its own row range exactly once, mirrored stages
    re-assemble via one all_gather per stage.
    """
    return _build_impl(lowered, backend, spec, delta=False,
                       delta_joins=False)


def build_sharded_delta_cycle(lowered: LoweredPlan,
                              backend: OperatorBackend, spec: ShardSpec,
                              delta_joins: bool = False):
    """Incremental heartbeat over the mesh — entirely shard-local.

    Same signature as ``lowering.build_delta_cycle``.  Dirty rows route
    to their owning shard (the per-shard dirty sets filled at update
    apply), admission panes refresh per shard (or replicated, for the
    mirrors), and carried rids merge shard-locally; the compiled beat
    contains no cross-shard collective.
    """
    return _build_impl(lowered, backend, spec, delta=True,
                       delta_joins=delta_joins)


# ---------------------------------------------------------------------------
# Host-side result merge (cross-shard routing at collect time)
# ---------------------------------------------------------------------------


def build_merge(lowered: LoweredPlan, spec: ShardSpec):
    """Cross-shard result routing, split into an ON-DEVICE merge and a
    host assemble: ``(device_merge, assemble)``.

    ``device_merge(shard_partials)`` is a jitted pytree function over
    ``results["_shard"]``: row-sharded route/sort templates merge their
    per-shard candidate lists with one stable device argsort per
    template — shard order IS global row order, so a stable sort on the
    returned comparison keys reproduces the unsharded sort exactly (key
    ties break by shard then local row, the global row order) — and
    group templates sum the per-shard partial aggregates before a
    device top-k.  The executor launches it right after the cycle at
    DISPATCH time, so the merge overlaps the pipeline and ``collect()``
    does no host-side key-merge at all.

    ``assemble(results, merged)`` is the host epilogue: per-template
    passthrough of mirrored (already final) results, the merged device
    arrays, and scalar overflow sums.  At S=1 every merge is an
    identity.
    """
    mirrored = set(spec.mirrored)
    R = spec.plan.max_results
    limits = lowered.limits
    sort_tpl, route_tpl, group_tpl = {}, {}, {}
    for st in lowered.sorts:
        if st.spine not in mirrored:
            for name, o, c in st.slots:
                sort_tpl[name] = (st, o, c)
    for st in lowered.routes:
        if st.spine not in mirrored:
            for name, o, c in st.slots:
                route_tpl[name] = (st, o, c)
    for st in lowered.groups:
        if st.spine not in mirrored:
            gkey = f"group:{st.spine}:{st.agg.group_col}:" \
                   f"{st.agg.agg_col}"
            for name, o, c in st.slots:
                group_tpl[name] = (st, gkey, o, c)

    def _merge_ordered(rows, keys, lim):
        """rows/keys [S, c, R] per-shard candidates (prefix-filled, -1
        padded, each in key order), lim int32[c] -> [c, R] first ``lim``
        rows per slot in global key order, -1 padded.  Stable: equal
        keys resolve in shard order == global row order."""
        c = rows.shape[1]
        flat_r = jnp.transpose(rows, (1, 0, 2)).reshape(c, -1)
        flat_k = jnp.transpose(keys, (1, 0, 2)).reshape(c, -1)
        order = jnp.argsort(flat_k, axis=1, stable=True)
        cand = jnp.take_along_axis(flat_r, order, axis=1)
        valid = cand >= 0
        pos = jnp.cumsum(valid, axis=1) - 1       # rank among survivors
        keep = valid & (pos < lim[:, None])
        out = jnp.full((c, R), -1, jnp.int32)
        return out.at[jnp.arange(c)[:, None],
                      jnp.where(keep, pos, R)].set(
            jnp.where(keep, cand, -1), mode="drop")

    def device_merge(shard) -> Dict:
        merged = {}
        for name, (st, o, c) in sort_tpl.items():
            base = st.wlo * 32
            lim = jnp.asarray(np.minimum(
                limits[base + o:base + o + c], R).astype(np.int32))
            p = shard[name]
            merged[name] = {"rows": _merge_ordered(p["rows"], p["keys"],
                                                   lim)}
        for name, (st, o, c) in route_tpl.items():
            base = st.wlo * 32
            lim = jnp.asarray(np.minimum(
                limits[base + o:base + o + c], R).astype(np.int32))
            rows = shard[name]["rows"]
            # natural order == global row order: merge on the row id
            keys = jnp.where(rows >= 0, rows, ops.INT_MAX)
            merged[name] = {"rows": _merge_ordered(rows, keys, lim)}
        done = set()
        for name, (st, gkey, o, c) in group_tpl.items():
            agg = st.agg
            if gkey not in done:
                done.add(gkey)
                merged[gkey] = {
                    "count": jnp.sum(shard[gkey]["count"], axis=0),
                    "sum": jnp.sum(shard[gkey]["sum"], axis=0)}
        for name, (st, gkey, o, c) in group_tpl.items():
            agg = st.agg
            count = merged[gkey]["count"]
            score = merged[gkey]["sum"] if agg.order_by == "sum" \
                else count
            cols_mat = score[:, o:o + c].T                  # [c, G]
            order = jnp.argsort(-cols_mat, axis=1,
                                stable=True)[:, :agg.top_k]
            merged[name] = {
                "groups": order.astype(jnp.int32),
                "scores": jnp.take_along_axis(cols_mat, order, axis=1),
                "counts": jnp.take_along_axis(count[:, o:o + c].T,
                                              order, axis=1)}
        return merged

    def assemble(results, merged) -> Dict:
        out = {}
        for name in spec.plan.templates:
            if name in sort_tpl or name in route_tpl or \
                    name in group_tpl:
                out[name] = merged[name]               # device-merged
            else:
                out[name] = results[name]              # mirrored: final
        out["_overflow"] = (
            int(results["_overflow"])
            + int(np.asarray(results["_overflow_sh"]).sum()))
        if "_delta_overflow" in results:
            out["_delta_overflow"] = (
                int(results["_delta_overflow"])
                + int(np.asarray(results["_delta_overflow_sh"]).sum()))
        out["_parts_rebuilt"] = results["_parts_rebuilt"]
        out["_join_rids"] = results["_join_rids"]
        return out

    return jax.jit(device_merge), assemble

"""Heartbeat executor (paper §3.2, §4.2, Algorithm 1).

While one batch of queries and updates executes, newly arriving work queues;
at each heartbeat the queues are drained (up to the per-template slot
capacity — excess stays queued for the next cycle, exactly the paper's
admission rule) and pushed through ONE jitted global-plan step.

Latency accounting matches §3.5: a query waits at most one cycle in the
queue plus one cycle of processing => worst-case latency = 2 x cycle time.
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.plan import CompiledPlan, build_cycle_fn
from repro.core.storage import UpdateSlots


@dataclasses.dataclass
class Ticket:
    id: int
    template: str
    params: Any
    submit_time: float
    done_time: Optional[float] = None
    result: Any = None

    @property
    def latency(self) -> float:
        return (self.done_time - self.submit_time) if self.done_time else None


class SharedDBEngine:
    """The always-on global plan + admission queues."""

    def __init__(self, plan: CompiledPlan, update_slots: UpdateSlots,
                 initial_data: Dict[str, Dict[str, np.ndarray]],
                 jit: bool = True):
        self.plan = plan
        self.update_slots = update_slots
        self.state = plan.catalog.init_state(initial_data)
        self._queues: Dict[str, collections.deque] = {
            name: collections.deque() for name in plan.templates}
        self._update_queue: collections.deque = collections.deque()
        self._ticket_ids = itertools.count()
        cycle = build_cycle_fn(plan, update_slots)
        # donate storage: the snapshot rolls forward functionally in place
        self._cycle = jax.jit(cycle, donate_argnums=(0,)) if jit else cycle
        self.cycles_run = 0
        self.queries_done = 0

    # ------------------------------------------------------------------ API
    def submit(self, template: str, params: Dict[str, Any]) -> Ticket:
        """params: {pred_index: (lo, hi)} inclusive int ranges."""
        t = Ticket(next(self._ticket_ids), template, params, time.time())
        self._queues[template].append(t)
        return t

    def submit_update(self, table: str, kind: str, payload: Dict) -> None:
        """kind: insert | update | delete (payload per storage slots)."""
        self._update_queue.append((table, kind, payload))

    def pending(self) -> int:
        return (sum(len(q) for q in self._queues.values())
                + len(self._update_queue))

    # ------------------------------------------------------------ one beat
    def _admit_queries(self):
        batch, admitted = {}, {}
        for name, tpl in self.plan.templates.items():
            cap = self.plan.caps[name]
            n_preds = max(len(tpl.preds), 1)
            params = np.zeros((cap, n_preds, 2), np.int32)
            active = np.zeros((cap,), bool)
            take: List[Ticket] = []
            q = self._queues[name]
            while q and len(take) < cap:
                take.append(q.popleft())
            for slot, ticket in enumerate(take):
                active[slot] = True
                for pi in range(len(tpl.preds)):
                    lo, hi = ticket.params[pi]
                    params[slot, pi] = (lo, hi)
            batch[name] = {"params": jnp.asarray(params),
                           "active": jnp.asarray(active)}
            admitted[name] = take
        return batch, admitted

    def _admit_updates(self):
        cat = self.plan.catalog
        s = self.update_slots
        np_batches = {}
        for t, schema in cat.schemas.items():
            np_batches[t] = {
                "ins_rows": {c: np.zeros((s.n_insert,), np.int32)
                             for c in schema.columns},
                "ins_mask": np.zeros((s.n_insert,), bool),
                "upd_key": np.full((s.n_update,), -1, np.int32),
                "upd_col": np.zeros((s.n_update,), np.int32),
                "upd_val": np.zeros((s.n_update,), np.int32),
                "upd_mask": np.zeros((s.n_update,), bool),
                "del_key": np.full((s.n_delete,), -1, np.int32),
                "del_mask": np.zeros((s.n_delete,), bool),
            }
        fill = {t: {"ins": 0, "upd": 0, "del": 0} for t in cat.schemas}
        hold = collections.deque()
        while self._update_queue:
            table, kind, payload = self._update_queue.popleft()
            b, f = np_batches[table], fill[table]
            if kind == "insert":
                if f["ins"] >= s.n_insert:
                    hold.append((table, kind, payload))
                    continue
                i = f["ins"]
                for c, v in payload.items():
                    b["ins_rows"][c][i] = int(v)
                b["ins_mask"][i] = True
                f["ins"] += 1
            elif kind == "update":
                if f["upd"] >= s.n_update:
                    hold.append((table, kind, payload))
                    continue
                i = f["upd"]
                schema = cat.schemas[table]
                b["upd_key"][i] = int(payload["key"])
                b["upd_col"][i] = schema.columns.index(payload["col"])
                b["upd_val"][i] = int(payload["val"])
                b["upd_mask"][i] = True
                f["upd"] += 1
            else:
                if f["del"] >= s.n_delete:
                    hold.append((table, kind, payload))
                    continue
                i = f["del"]
                b["del_key"][i] = int(payload["key"])
                b["del_mask"][i] = True
                f["del"] += 1
        self._update_queue = hold
        return jax.tree.map(jnp.asarray, np_batches)

    def run_cycle(self) -> Dict[str, List[Ticket]]:
        """One heartbeat: drain queues, execute the global plan, route."""
        queries, admitted = self._admit_queries()
        updates = self._admit_updates()
        self.state, results = self._cycle(self.state, queries, updates)
        jax.block_until_ready(results)
        now = time.time()
        out = {}
        for name, tickets in admitted.items():
            res = jax.tree.map(np.asarray, results[name])
            for slot, ticket in enumerate(tickets):
                ticket.result = jax.tree.map(lambda a: a[slot], res)
                ticket.done_time = now
            out[name] = tickets
            self.queries_done += len(tickets)
        self.cycles_run += 1
        return out

    def run_until_drained(self, max_cycles: int = 1000):
        done = []
        while self.pending() and max_cycles:
            done.append(self.run_cycle())
            max_cycles -= 1
        return done

    # --------------------------------------------------- host-side fetch
    def materialize(self, table: str, row_ids: np.ndarray,
                    cols: Optional[List[str]] = None) -> Dict[str, np.ndarray]:
        """Fetch tuples by row id from the current snapshot (result
        delivery — the Output operator of Fig. 5)."""
        t = self.state[table]
        schema = self.plan.catalog.schemas[table]
        cols = cols or list(schema.columns)
        ids = np.asarray(row_ids)
        safe = np.clip(ids, 0, schema.capacity - 1)
        out = {c: np.where(ids >= 0, np.asarray(t[c])[safe], 0)
               for c in cols}
        out["_row"] = ids
        return out

"""Heartbeat executor (paper §3.2, §4.2, Algorithm 1) — pipelined.

While one batch of queries and updates executes, newly arriving work queues;
at each heartbeat the queues are drained (up to the per-template slot
capacity — excess stays queued for the next cycle, exactly the paper's
admission rule) and pushed through ONE jitted global-plan step.

The heartbeat is split into two phases so host and device overlap:

  dispatch() — drain the queues into PREALLOCATED staging buffers, stage
               the batch onto the device, and launch the cycle.  JAX
               dispatch is asynchronous, so this returns while the device
               still computes.
  collect()  — block on the oldest in-flight cycle and route its results
               to the waiting tickets.

With double-buffered admission (two staging buffer sets, pipeline depth
2), the queue draining and numpy parameter staging for heartbeat N+1
overlap with device execution of heartbeat N.  A query admitted at
dispatch k completes at collect k, so the paper's latency accounting is
unchanged: a query waits at most one cycle in the queue plus one cycle of
processing => worst-case latency = 2 x cycle time (§3.5).

``run_cycle()`` (dispatch immediately followed by collect) preserves the
original synchronous semantics for callers that want them.

Scans AND joins are incremental: every heartbeat returns a functional
carry — the shared scans' bitmask words plus the partitioned joins' key
partitions — and exposes each join's matched-row-id arrays in
``results["_join_rids"]``, which the executor threads forward as the
rid half of the widened carry.  The next dispatch — when the carried
state exists and the heartbeat's deltas fit their fixed capacities
(changed admission slots per stage pane, update-touched rows per table
dirty set) — runs a DELTA cycle, which re-evaluates only those deltas
against the carried words (lowering.build_delta_cycle); when
additionally NO carried join's PK table was touched (its partitions
would rebuild, invalidating carried rids), the delta cycle's
``delta_joins`` variant also re-probes only the dirty spine rows
against the carried rid arrays.  All choices are made host-side from
exact admission knowledge, so ineligible heartbeats fall back — full
rescan for the scans (which reseeds BOTH carry halves), full probe for
the joins — without any data-dependent branching on device.  The
scan/parts carry is donated: it is produced by one heartbeat and
consumed by exactly the next, so pipelined in-flight cycles never alias
it.  The rid carry is NOT donated — its arrays are also the in-flight
``results["_join_rids"]`` a later collect still reads — and the
host-side ``changed`` staging vector is double-buffered with the rest
of the admission buffers for the same reason.
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
import os
import threading
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import folding
from repro.core.backends import counting_backend, resolve_backend
from repro.core.lowering import (PARTITIONED_MIN_CAPACITY, build_cycle,
                                 build_delta_cycle, lower_plan)
from repro.core.plan import CompiledPlan, QueryTemplate
from repro.core.storage import (UPDATE_BATCH_RESET, UpdateSlots,
                                empty_update_batch)


def check_carry_layout(carry_token, layout_token) -> None:
    """Always-on carry/layout guard (deliberately NOT an assert).

    A delta heartbeat must never consume a carry produced under a
    different admission layout — the carried words/rids are positional
    in it — and under ``python -O`` an assert would vanish, letting the
    mismatch corrupt results silently.  Both the delta dispatch path and
    the fold carry-migration path route through this one check.
    """
    if carry_token != layout_token:
        raise RuntimeError(
            "delta heartbeat would consume a carry produced under a "
            "different admission layout — reset the carries "
            f"(carry {carry_token} != plan {layout_token})")


def _measure_key_stats(plan: CompiledPlan,
                       initial_data) -> Dict[str, Dict[str, int]]:
    """Measured key skew of every partitioned-join candidate PK table
    (index-less, at or above the partitioned threshold), from the
    initial snapshot: live-row count and widest duplicate-key run.
    ``lower_plan`` feeds it to the adaptive ``partition_layout`` so the
    probe pane width matches real occupancy."""
    stats = {}
    for t, schema in plan.catalog.schemas.items():
        if (schema.pk is None or schema.key_space > 0
                or schema.capacity < PARTITIONED_MIN_CAPACITY):
            continue
        data = (initial_data or {}).get(t, {})
        keys = np.asarray(data.get(schema.pk, ()))
        if keys.size:
            _, counts = np.unique(keys, return_counts=True)
            stats[t] = {"n_live": int(keys.size),
                        "max_dup": int(counts.max())}
        else:
            stats[t] = {"n_live": 0, "max_dup": 1}
    return stats


def _clear_counts_at_entry(fn, counts: Dict[str, int]):
    """Reset a flavour's backend-op counter when its cycle (re)traces.

    Backend ops fire at TRACE time under jit, so the counts are the
    per-beat STATIC launch counts of the traced cycle; clearing at
    traced-function entry makes retraces overwrite rather than
    accumulate.  With ``jit=False`` every call re-enters, so the counts
    are per-call either way."""
    def wrapped(*args):
        counts.clear()
        return fn(*args)
    return wrapped


@dataclasses.dataclass
class Ticket:
    id: int
    template: str
    params: Any
    submit_time: float
    done_time: Optional[float] = None
    result: Any = None

    @property
    def latency(self) -> float:
        return (self.done_time - self.submit_time) if self.done_time else None


class _StagingBuffers:
    """Preallocated host-side admission buffers for ONE pipeline slot.

    Rebuilding every numpy array per heartbeat put allocation on the
    critical path; these persist for the engine's lifetime and only the
    activation/mask fields are cleared between uses (parameter/payload
    slots are masked out by ``active``/``*_mask`` and may hold stale
    values).

    Query admission is PACKED: one contiguous [qcap, P_max, 2] parameter
    buffer plus one [qcap] active vector cover every template (each
    template owns the rows of its static slot range), so staging a
    heartbeat is a single host->device copy per buffer instead of
    O(templates) transfers.
    """

    def __init__(self, plan: CompiledPlan, slots: UpdateSlots):
        self.params = np.zeros((plan.qcap, plan.n_params_max, 2), np.int32)
        self.active = np.zeros((plan.qcap,), bool)
        # per-slot staging for the delta path's changed-slot vector: like
        # params/active it is staged with a zero-copy-capable asarray, so
        # it must be double-buffered with the rest — an in-flight delta
        # cycle must never alias a later dispatch's overwrite
        self.changed = np.zeros((plan.qcap,), bool)
        # same layout as the device batches, numpy-backed (ONE source of
        # truth: storage.empty_update_batch)
        self.updates: Dict[str, Dict[str, Any]] = {
            t: empty_update_batch(schema, slots, xp=np)
            for t, schema in plan.catalog.schemas.items()}

    def reset(self) -> None:
        self.active[:] = False
        for b in self.updates.values():
            for field, fill in UPDATE_BATCH_RESET.items():
                b[field][:] = fill


@dataclasses.dataclass
class CycleResult:
    """One collected heartbeat: routed tickets + its observed wall time.

    ``wall_s`` is the collector-side inter-completion time (elapsed from
    the previous collect's return — or the drain start — to this one),
    which under pipelining is the achieved cycle time the paper's
    2 x cycle-time latency bound is stated against (§3.5).

    ``admitted``/``dirty`` count the queries and update-touched rows the
    heartbeat carried and ``scan_path``/``join_path`` name the scan and
    join flavours it ran ("delta" or "full"; "mixed" when backpressure
    folded several heartbeats into one collect; ``join_path`` is ""
    when the plan has no delta-eligible join stages) — the attribution
    benchmarks and the SLA gate need to split cycle time between the
    paths.

    The ``t_*_s`` fields are the beat's per-phase host-time breakdown —
    staging (queue drain + buffer fill + H2D), dispatch (the async
    cycle launch), kernel (the collect-side block_until_ready wait) and
    collect (result assemble + ticket routing) — and ``backend_ops``
    its per-op backend launch counts (from the traced cycle), so the
    fused path's one-launch claim is machine-checkable per beat."""
    tickets: Dict[str, List[Ticket]]
    wall_s: float
    admitted: int = 0
    dirty: int = 0
    scan_path: str = ""
    join_path: str = ""
    t_stage_s: float = 0.0
    t_dispatch_s: float = 0.0
    t_kernel_s: float = 0.0
    t_collect_s: float = 0.0
    backend_ops: Dict[str, int] = dataclasses.field(default_factory=dict)


#: The shipped donation contract: cycle flavour -> donate_argnums.  The
#: snapshot (arg 0) rolls forward functionally in place; the delta
#: flavours additionally donate the carried scan words + key partitions
#: (arg 1).  The rid carry (arg 2 of the delta-join flavour) is
#: deliberately NOT donated — its arrays double as the previous beat's
#: in-flight ``results["_join_rids"]``.  planlint's use-after-donate
#: pass and the lint CLI verify this spec against the aliasing the
#: lowering actually emits.
DONATION_SPEC: Dict[str, tuple] = {
    "full": (0,), "delta": (0, 1), "delta_join": (0, 1)}


@dataclasses.dataclass
class _CompiledHandle:
    """One fully-built compiled-cycle generation.

    The executor is double-buffered across a FOLD (core/folding.py): it
    keeps serving from the installed handle while a background thread
    builds the next one for the extended plan; the swap installs the new
    handle atomically at a beat boundary.  Everything layout-dependent
    lives here, so installing a handle IS the layout swap."""
    plan: CompiledPlan
    lowered: Any
    backend_ops: Dict[str, Dict[str, int]]
    cycle: Any
    cycle_delta: Any
    cycle_delta_join: Any
    shard_spec: Any
    device_merge: Any
    assemble: Any
    stage: Any
    carried_joins: tuple
    layout_token: tuple
    #: the shipped donation contract per cycle flavour (flavour ->
    #: donate_argnums), recorded so planlint's use-after-donate pass and
    #: the lint CLI verify the REAL spec instead of a hardcoded copy;
    #: empty when the engine runs unjitted
    donation: Dict[str, tuple] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class _PendingFold:
    """A fold in flight: the extended plan + its background build."""
    plan: CompiledPlan
    handle: Optional[_CompiledHandle] = None
    error: Optional[BaseException] = None
    thread: Optional[threading.Thread] = None
    built: threading.Event = dataclasses.field(
        default_factory=threading.Event)

    def ready(self) -> bool:
        return self.built.is_set()


@dataclasses.dataclass
class _InFlight:
    """One dispatched-but-not-collected heartbeat."""
    admitted: Dict[str, List[Ticket]]
    results: Any
    merged: Any = None          # sharded: device merge, launched at dispatch
    n_admitted: int = 0
    n_dirty: int = 0
    scan_path: str = "full"
    join_path: str = ""
    t_stage_s: float = 0.0
    t_dispatch_s: float = 0.0
    t_kernel_s: float = 0.0
    t_collect_s: float = 0.0
    backend_ops: Dict[str, int] = dataclasses.field(default_factory=dict)


class SharedDBEngine:
    """The always-on global plan + admission queues."""

    def __init__(self, plan: CompiledPlan, update_slots: UpdateSlots,
                 initial_data: Dict[str, Dict[str, np.ndarray]],
                 jit: bool = True, kernels: str = "auto",
                 pipeline_depth: int = 2, delta_scans: bool = True,
                 delta_joins: bool = True, mesh=None):
        """``mesh``: an optional 1-D ``jax.sharding.Mesh`` — the always-on
        plan then runs SHARDED by spine-row range (core/sharding.py):
        row-sharded spine tables + carries, replicated join probe sides,
        shard-local delta beats, all-shard reseed beats, and an
        on-device cross-shard result merge launched at dispatch (collect
        is a device-to-host copy).  ``mesh=None`` (the default)
        is the existing single-device path, untouched; a 1-device mesh is
        bit-identical to it."""
        self.plan = plan
        self.update_slots = update_slots
        self._queues: Dict[str, collections.deque] = {
            name: collections.deque() for name in plan.templates}
        self._update_queue: collections.deque = collections.deque()
        self._ticket_ids = itertools.count()
        self._backend = resolve_backend(kernels)
        self._jit = jit
        self._mesh = mesh
        # measured once from the initial snapshot and reused by every
        # re-lower (folds): the partition geometry must stay identical
        # across generations for the carried key partitions to remap
        self._key_stats = _measure_key_stats(plan, initial_data)
        self.delta_scans = delta_scans
        self.delta_joins = delta_joins
        handle = self._build_compiled(plan)
        if handle.shard_spec is not None:
            from repro.core import sharding
            self.state = sharding.init_sharded_state(handle.shard_spec,
                                                     initial_data)
        else:
            self.state = plan.catalog.init_state(initial_data)
        self._install_handle(handle)
        self._fold: Optional[_PendingFold] = None
        self.folds_done = 0
        # set by a fold commit: the first post-fold heartbeat is a FORCED
        # full-rescan reseed under the new layout (the migration beat's
        # other half) — after it the engine is indistinguishable from a
        # cold engine compiled with the extended template set
        self._force_full = False
        self._carry = None           # previous heartbeat's scan words +
        #                              key partitions (donated halves)
        self._rid_carry = None       # previous heartbeat's join rids
        self._carry_token = None
        # (active, params) of the last DISPATCHED heartbeat: the delta
        # path diffs against these to find changed admission slots
        self._prev_params = np.zeros((plan.qcap, plan.n_params_max, 2),
                                     np.int32)
        self._prev_active = np.zeros((plan.qcap,), bool)
        self.pipeline_depth = max(1, pipeline_depth)
        # double-buffered admission: one staging set per pipeline slot
        self._staging = [_StagingBuffers(plan, update_slots)
                         for _ in range(self.pipeline_depth)]
        self._staging_idx = 0
        self._inflight: collections.deque[_InFlight] = collections.deque()
        # routing dicts from backpressure collects inside dispatch(),
        # surfaced by the next public collect() so no cycle's routed
        # tickets vanish from the return-value stream
        self._spilled: Dict[str, List[Ticket]] = {}
        self._spilled_stats: List[_InFlight] = []
        self.cycles_run = 0
        self.queries_done = 0
        self.last_overflow = 0    # union-cap overflow of the last collect
        self.delta_cycles = 0     # heartbeats dispatched down each path
        self.full_cycles = 0
        self.delta_join_cycles = 0    # ... and down each JOIN path
        self.full_join_cycles = 0
        self.last_scan_path = ""  # paths of the last dispatch
        self.last_join_path = ""
        self.last_delta_overflow = 0   # defensive invariant (always 0)
        self.last_parts_rebuilt: Dict[str, bool] = {}
        self.last_collect_stats = {"admitted": 0, "dirty": 0,
                                   "scan_path": "", "join_path": "",
                                   "t_stage_s": 0.0, "t_dispatch_s": 0.0,
                                   "t_kernel_s": 0.0, "t_collect_s": 0.0,
                                   "backend_ops": {}}

    # --------------------------------------------- compiled-cycle handle
    def _build_compiled(self, plan: CompiledPlan) -> _CompiledHandle:
        """Lower + build + wrap one plan generation's three cycle
        flavours.  Pure with respect to the engine's serving state, so a
        background fold thread can run it while the installed generation
        keeps beating."""
        lowered = lower_plan(plan, key_stats=self._key_stats)
        # always-on planlint: the cheap IR passes gate EVERY generation
        # (cold start and every background fold build) before anything
        # compiles against its layout
        from repro.analysis_static.ir_passes import run_construction_passes
        run_construction_passes(lowered, key_stats=self._key_stats)
        # per-flavour backend-op launch counters
        # (CycleResult.backend_ops): each cycle flavour traces through
        # its own counting wrapper and clears its dict at traced-function
        # entry, so the counts always reflect the CURRENT trace's static
        # launch count per beat
        backend_ops: Dict[str, Dict[str, int]] = {
            "full": {}, "delta": {}, "delta_join": {}}
        cb = {f: counting_backend(self._backend, c)
              for f, c in backend_ops.items()}
        if self._mesh is not None:
            from repro.core import sharding
            spec = sharding.build_shard_spec(plan, self._mesh)
            cycle = sharding.build_sharded_cycle(lowered, cb["full"],
                                                 spec)
            delta = sharding.build_sharded_delta_cycle(lowered,
                                                       cb["delta"], spec)
            delta_j = sharding.build_sharded_delta_cycle(
                lowered, cb["delta_join"], spec, delta_joins=True)
            # cross-shard result routing runs ON DEVICE, launched at
            # dispatch right behind the cycle; collect only assembles
            device_merge, assemble = sharding.build_merge(lowered, spec)
            repl = spec.repl_sharding()
            stage = lambda a: jax.device_put(np.asarray(a), repl)  # noqa: E731
        else:
            spec = None
            cycle = build_cycle(lowered, cb["full"])
            delta = build_delta_cycle(lowered, cb["delta"])
            delta_j = build_delta_cycle(lowered, cb["delta_join"],
                                        delta_joins=True)
            device_merge, assemble = None, None
            stage = jnp.asarray
        cycle = _clear_counts_at_entry(cycle, backend_ops["full"])
        delta = _clear_counts_at_entry(delta, backend_ops["delta"])
        delta_j = _clear_counts_at_entry(delta_j,
                                         backend_ops["delta_join"])
        # donate storage: the snapshot rolls forward functionally in
        # place; the delta cycles additionally donate the carried scan
        # words + key partitions (each carry is produced by one heartbeat
        # and consumed by exactly the next, so in-flight cycles never
        # alias it).  The rid carry (arg 2 of the delta-join cycle) is
        # deliberately NOT donated: its arrays double as the previous
        # heartbeat's in-flight ``results["_join_rids"]``.
        donation: Dict[str, tuple] = {}
        if self._jit:
            donation = dict(DONATION_SPEC)
            cycle = jax.jit(cycle, donate_argnums=donation["full"])
            delta = jax.jit(delta, donate_argnums=donation["delta"])
            delta_j = jax.jit(delta_j,
                              donate_argnums=donation["delta_join"])
        # the admission layout this generation's carries live under: a
        # delta heartbeat must never consume a carry whose slot layout
        # differs (word windows, offsets and packed depth all bake into
        # the carried shapes/meanings), e.g. across a fold or an elastic
        # re-lower
        layout_token = (plan.qcap, plan.n_params_max,
                        tuple(sorted(plan.offsets.items())),
                        tuple(sorted(plan.caps.items())),
                        spec.n_shards if spec else 0)
        return _CompiledHandle(
            plan=plan, lowered=lowered, backend_ops=backend_ops,
            cycle=cycle, cycle_delta=delta, cycle_delta_join=delta_j,
            shard_spec=spec, device_merge=device_merge,
            assemble=assemble, stage=stage,
            # join stages with carried rid state (non-gather paths)
            carried_joins=tuple(j for j in lowered.joins
                                if j.kind != "gather"),
            layout_token=layout_token, donation=donation)

    def _install_handle(self, h: _CompiledHandle) -> None:
        """Atomically swap the serving generation (a beat boundary)."""
        self.plan = h.plan
        self._lowered = h.lowered
        self.backend_ops = h.backend_ops
        self._cycle = h.cycle
        self._cycle_delta = h.cycle_delta
        self._cycle_delta_join = h.cycle_delta_join
        self._shard_spec = h.shard_spec
        self._device_merge = h.device_merge
        self._assemble = h.assemble
        self._stage = h.stage
        self._carried_joins = h.carried_joins
        self._layout_token = h.layout_token

    # ------------------------------------------------------ plan folding
    def begin_fold(self, new_templates: List[QueryTemplate],
                   new_caps: Dict[str, int],
                   background: bool = True) -> dict:
        """Fold new templates into the running plan (core/folding.py).

        Validates the extension synchronously (cheap — a recompile of
        the plan graph, no lowering), opens admission queues for the new
        templates immediately (their queries queue and are served after
        the fold commits), and builds + compiles the extended
        generation in a background thread while the current one keeps
        beating.  The swap happens at the next dispatch() after the
        build finishes: drain in-flight beats, install the new handle,
        migrate the carries, force one full-rescan reseed beat.

        Returns the structured drain -> re-lower -> resume recipe (the
        ``background`` variant of runtime/elastic.relower_recipe — the
        same machinery that drives elastic re-meshing).
        """
        from repro.runtime.elastic import relower_recipe
        if self._fold is not None:
            raise RuntimeError(
                "[planlint:fold-in-flight] a fold is already in flight "
                "— wait for it to commit before starting another "
                "(serving front ends batch registrations instead)")
        new_templates = list(new_templates)
        new_plan = folding.extend_plan(self.plan, new_templates,
                                       dict(new_caps))
        if self._shard_spec is not None:
            from repro.core import sharding
            sharding.check_fold_mirrors(self.plan, new_plan)
        for t in new_templates:
            self._queues.setdefault(t.name, collections.deque())
        fold = _PendingFold(plan=new_plan)
        self._fold = fold
        if background:
            fold.thread = threading.Thread(target=self._fold_build,
                                           args=(fold,),
                                           name="plan-fold", daemon=True)
            fold.thread.start()
        else:
            self._fold_build(fold)
        return relower_recipe(tuple(self.plan.templates),
                              tuple(new_plan.templates),
                              what="the extended always-on plan",
                              background=True)

    def fold_in_flight(self) -> bool:
        return self._fold is not None

    def fold_ready(self) -> bool:
        return self._fold is not None and self._fold.ready()

    def _fold_build(self, fold: _PendingFold) -> None:
        """Background half of a fold: lower, build, compile, warm.

        When it runs on the fold thread it denices itself first: the
        build is pure slack work (the old generation keeps serving and
        commits the swap whenever the build lands), so on a saturated
        host the serving beats keep the cores and the build fills the
        gaps — the cost of a fold is paid in fold LATENCY, never in
        serving-beat wall (the BENCH_PR8 gate)."""
        try:
            if fold.thread is not None:
                try:
                    os.setpriority(os.PRIO_PROCESS,
                                   threading.get_native_id(), 19)
                except (AttributeError, OSError):
                    pass    # non-Linux / restricted: build at normal prio
            handle = self._build_compiled(fold.plan)
            if self._jit:
                self._fold_warmup(handle)
            fold.handle = handle
        except BaseException as e:  # noqa: BLE001 — surfaced at commit
            fold.error = e
        finally:
            fold.built.set()

    def _fold_warmup(self, h: _CompiledHandle) -> None:
        """Populate the new generation's jit caches OFF the serving
        path: one dummy beat per cycle flavour, on throwaway state of
        the real shapes/shardings, so the migration beat pays a cache
        hit instead of a trace + XLA compile."""
        plan = h.plan
        if h.shard_spec is not None:
            from repro.core import sharding
            state = sharding.init_sharded_state(h.shard_spec, {})
        else:
            state = plan.catalog.init_state({})
        queries = {
            "params": h.stage(np.zeros(
                (plan.qcap, plan.n_params_max, 2), np.int32)),
            "active": h.stage(np.zeros((plan.qcap,), bool))}

        def batches():
            return jax.tree.map(h.stage, {
                t: empty_update_batch(schema, self.update_slots, xp=np)
                for t, schema in plan.catalog.schemas.items()})

        state, carry, results = h.cycle(state, queries, batches())
        rids = results["_join_rids"]
        dq = dict(queries, changed=h.stage(np.zeros((plan.qcap,), bool)))
        state, carry, _ = h.cycle_delta(state, carry, dq, batches())
        if h.carried_joins:
            state, carry, _ = h.cycle_delta_join(state, carry, rids, dq,
                                                 batches())
        jax.block_until_ready(state)

    def _commit_fold(self) -> None:
        """The migration beat boundary: swap generations atomically.

        Runs at dispatch() once the background build is ready.  In-flight
        beats drain first (their results are positional in the OLD
        layout), the new handle installs, the admission-diff state
        prefix-copies into the wider layout, and the carries migrate —
        routed through the same always-on carry/layout check as the
        delta dispatch path — before one forced full-rescan beat reseeds
        everything under the new layout."""
        fold, self._fold = self._fold, None
        if fold.thread is not None:
            fold.thread.join()
        if fold.error is not None:
            raise RuntimeError(
                f"background fold of {sorted(set(fold.plan.templates) - set(self.plan.templates))} "
                "failed to build") from fold.error
        while self._inflight:
            for name, tickets in self._collect_oldest().items():
                self._spilled.setdefault(name, []).extend(tickets)
        old_plan, old_lowered = self.plan, self._lowered
        self._install_handle(fold.handle)
        plan = self.plan
        # admission-diff state: the old slot ranges are a prefix of the
        # new layout, appended slots have never been admitted
        prev_p = np.zeros((plan.qcap, plan.n_params_max, 2), np.int32)
        prev_p[:old_plan.qcap, :old_lowered.n_params_max] = \
            self._prev_params
        prev_a = np.zeros((plan.qcap,), bool)
        prev_a[:old_plan.qcap] = self._prev_active
        self._prev_params, self._prev_active = prev_p, prev_a
        self._staging = [_StagingBuffers(plan, self.update_slots)
                         for _ in range(self.pipeline_depth)]
        self._staging_idx = 0
        carry, rids = folding.migrate_carry(
            old_lowered, self._lowered, self._carry, self._rid_carry)
        self._carry, self._rid_carry = carry, rids
        if carry is not None:
            # version the swap: the migrated carry now lives under the
            # NEW layout token, proven through the always-on guard
            self._carry_token = self._layout_token
            check_carry_layout(self._carry_token, self._layout_token)
        else:
            self._carry_token = None
        self._force_full = True
        self.folds_done += 1

    # ------------------------------------------------------------------ API
    def submit(self, template: str, params: Dict[str, Any]) -> Ticket:
        """params: {pred_index: (lo, hi)} inclusive int ranges."""
        t = self.make_ticket(template, params)
        self.submit_ticket(t)
        return t

    def make_ticket(self, template: str, params: Dict[str, Any]) -> Ticket:
        """Mint a ticket WITHOUT enqueueing it (serving front ends hold
        tickets for templates still waiting on a fold batch)."""
        return Ticket(next(self._ticket_ids), template, params,
                      time.time())

    def accepts(self, template: str) -> bool:
        """True iff the engine has an admission queue for the template
        (compiled in, or in/awaiting an in-flight fold)."""
        return template in self._queues

    def submit_ticket(self, ticket: Ticket) -> None:
        self._queues[ticket.template].append(ticket)

    def submit_update(self, table: str, kind: str, payload: Dict) -> None:
        """kind: insert | update | delete (payload per storage slots)."""
        self._update_queue.append((table, kind, payload))

    def pending(self) -> int:
        return (sum(len(q) for q in self._queues.values())
                + len(self._update_queue))

    def in_flight(self) -> int:
        return len(self._inflight)

    # ------------------------------------------------------------ one beat
    def _admit_queries(self, buf: _StagingBuffers):
        """Drain the queues into the packed staging buffers.

        Fills each admitted query's static slot range in the shared
        [qcap, P_max, 2] / [qcap] buffers, then stages BOTH with one
        ``jnp.asarray`` each — a single H2D copy per heartbeat instead of
        one per template."""
        admitted = {}
        params, active = buf.params, buf.active
        for name, tpl in self.plan.templates.items():
            cap = self.plan.caps[name]
            off = self.plan.offsets[name]
            take: List[Ticket] = []
            q = self._queues[name]
            while q and len(take) < cap:
                take.append(q.popleft())
            for slot, ticket in enumerate(take):
                g = off + slot
                active[g] = True
                for pi in range(len(tpl.preds)):
                    lo, hi = ticket.params[pi]
                    params[g, pi, 0] = lo
                    params[g, pi, 1] = hi
            admitted[name] = take
        batch = {"params": self._stage(params),
                 "active": self._stage(active)}
        return batch, admitted

    def _admit_updates(self, buf: _StagingBuffers):
        cat = self.plan.catalog
        s = self.update_slots
        np_batches = buf.updates
        fill = {t: {"ins": 0, "upd": 0, "del": 0} for t in cat.schemas}
        hold = collections.deque()
        while self._update_queue:
            table, kind, payload = self._update_queue.popleft()
            b, f = np_batches[table], fill[table]
            if kind == "insert":
                if f["ins"] >= s.n_insert:
                    hold.append((table, kind, payload))
                    continue
                i = f["ins"]
                for c, v in payload.items():
                    b["ins_rows"][c][i] = int(v)
                b["ins_mask"][i] = True
                f["ins"] += 1
            elif kind == "update":
                if f["upd"] >= s.n_update:
                    hold.append((table, kind, payload))
                    continue
                i = f["upd"]
                schema = cat.schemas[table]
                b["upd_key"][i] = int(payload["key"])
                b["upd_col"][i] = schema.columns.index(payload["col"])
                b["upd_val"][i] = int(payload["val"])
                b["upd_mask"][i] = True
                f["upd"] += 1
            else:
                if f["del"] >= s.n_delete:
                    hold.append((table, kind, payload))
                    continue
                i = f["del"]
                b["del_key"][i] = int(payload["key"])
                b["del_mask"][i] = True
                f["del"] += 1
        self._update_queue = hold
        # per-table admitted touch counts: an exact upper bound on the
        # rows this batch can dirty (delta-path eligibility + accounting)
        touches = {t: f["ins"] + f["upd"] + f["del"]
                   for t, f in fill.items()}
        return jax.tree.map(self._stage, np_batches), touches

    # -------------------------------------------------- incremental scans
    def _diff_admission(self, buf: _StagingBuffers) -> np.ndarray:
        """Changed-slot vector vs the previously dispatched heartbeat.

        A slot changed iff its activation flipped, or it stayed active
        with different parameters — exactly the columns of the carried
        scan words that the delta cycle's admission pane must refresh.
        """
        changed = buf.changed
        np.not_equal(buf.active, self._prev_active, out=changed)
        both = buf.active & self._prev_active
        if both.any():
            diff = (buf.params != self._prev_params).any(axis=(1, 2))
            np.logical_or(changed, both & diff, out=changed)
        return changed

    def _delta_eligible(self, changed: np.ndarray,
                        touches: Dict[str, int]) -> bool:
        """Host-side delta-path admission control (conservative).

        True iff every predicated scan's changed slots fit inside its
        CONTIGUOUS admission pane (span of changed words <= delta_words)
        and every table's batch fits its dirty set — so the traced delta
        cycle can assume its fixed delta capacities suffice and never
        needs a data-dependent fallback branch.
        """
        schemas = self.plan.catalog.schemas
        for table, n in touches.items():
            if n > schemas[table].dirty_cap:
                return False
        for st in self._lowered.scans:
            if not st.cols:
                continue
            sc = changed[st.wlo * 32:st.whi * 32] & st.covered
            words = np.flatnonzero(sc.reshape(-1, 32).any(axis=1))
            if words.size and words[-1] - words[0] + 1 > st.delta_words:
                return False
        return True

    def _join_delta_eligible(self, touches: Dict[str, int]) -> bool:
        """Host-side delta-JOIN admission control (conservative).

        True iff the plan has carried join stages, a rid carry exists,
        and NO carried stage's PK table was touched this heartbeat — a
        touched PK side rebuilds its partitions
        (storage.refresh_key_partitions), which can move/retire the rows
        the carried rids point at.  Spine-side dirty capacity is already
        guaranteed by ``_delta_eligible`` (it bounds every table's
        touches), so the delta probe's dirty set is exact.
        """
        if not self._carried_joins or self._rid_carry is None:
            return False
        return all(touches[j.pk_table] == 0 for j in self._carried_joins)

    def dispatch(self) -> None:
        """Admit one heartbeat's work and launch the global plan.

        Returns as soon as the computation is dispatched (JAX async);
        results are claimed by a later collect().  At full pipeline depth
        the oldest in-flight cycle is collected first (backpressure), so
        at most ``pipeline_depth`` cycles are ever outstanding — which
        also makes staging-buffer reuse safe: a buffer is only rewritten
        after the cycle that consumed it has completed.
        """
        if self._fold is not None and self._fold.ready():
            # migration beat boundary: the background build finished —
            # swap generations before admitting this heartbeat's work
            self._commit_fold()
        while len(self._inflight) >= self.pipeline_depth:
            for name, tickets in self._collect_oldest().items():
                self._spilled.setdefault(name, []).extend(tickets)
        t0 = time.perf_counter()
        buf = self._staging[self._staging_idx]
        self._staging_idx = (self._staging_idx + 1) % len(self._staging)
        buf.reset()
        queries, admitted = self._admit_queries(buf)
        updates, touches = self._admit_updates(buf)
        # incremental-scan path choice, made HOST-side so the traced
        # delta cycle never contains the full-table compare: eligible
        # when the carried words exist and every delta fits its fixed
        # capacity, else a safe full rescan (which reseeds the carry)
        changed = self._diff_admission(buf)
        force_full, self._force_full = self._force_full, False
        use_delta = (not force_full and self.delta_scans
                     and self._carry is not None
                     and self._delta_eligible(changed, touches))
        use_delta_join = (use_delta and self.delta_joins
                          and self._join_delta_eligible(touches))
        t_staged = time.perf_counter()
        if use_delta:
            # carry-invalidation audit: a delta heartbeat must never
            # consume a carry produced under a different admission
            # layout (the carried words/rids are positional in it); a
            # full-rescan heartbeat reseeds BOTH halves below, so the
            # token always matches unless the plan was re-lowered
            # without resetting the carries.  An always-on RuntimeError,
            # not an assert: ``python -O`` must not strip it.
            check_carry_layout(self._carry_token, self._layout_token)
            queries = dict(queries, changed=self._stage(changed))
            if use_delta_join:
                self.state, self._carry, results = self._cycle_delta_join(
                    self.state, self._carry, self._rid_carry, queries,
                    updates)
            else:
                self.state, self._carry, results = self._cycle_delta(
                    self.state, self._carry, queries, updates)
            self.delta_cycles += 1
        else:
            self.state, self._carry, results = self._cycle(
                self.state, queries, updates)
            self.full_cycles += 1
        merged = None
        if self._device_merge is not None:
            # launch the on-device cross-shard merge right behind the
            # cycle (async); collect only blocks + copies
            merged = self._device_merge(results["_shard"])
        t_launched = time.perf_counter()
        # both carry halves are (re)seeded by EVERY heartbeat: the
        # scan/parts half from the cycle's carry output, the rid half
        # from the results (full-probe heartbeats — including every full
        # rescan — return freshly probed rids for all spine rows)
        self._rid_carry = results["_join_rids"]
        self._carry_token = self._layout_token
        self.last_scan_path = "delta" if use_delta else "full"
        if self._carried_joins:
            self.last_join_path = "delta" if use_delta_join else "full"
            if use_delta_join:
                self.delta_join_cycles += 1
            else:
                self.full_join_cycles += 1
        self._prev_params[...] = buf.params
        self._prev_active[...] = buf.active
        flavour = ("delta_join" if use_delta_join else "delta") \
            if use_delta else "full"
        self._inflight.append(_InFlight(
            admitted, results, merged=merged,
            n_admitted=sum(len(ts) for ts in admitted.values()),
            n_dirty=sum(touches.values()),
            scan_path=self.last_scan_path,
            join_path=self.last_join_path,
            t_stage_s=t_staged - t0,
            t_dispatch_s=t_launched - t_staged,
            backend_ops=dict(self.backend_ops[flavour])))

    def collect(self) -> Dict[str, List[Ticket]]:
        """Block on the oldest in-flight heartbeat and route its results.

        Also surfaces any routing spilled by dispatch()-side
        backpressure, so every admitted ticket appears in exactly one
        collect() return.  ``last_collect_stats`` aggregates the
        surfaced heartbeats' admitted/dirty counts and scan path for the
        caller's CycleResult accounting."""
        out, self._spilled = self._spilled, {}
        for name, tickets in self._collect_oldest().items():
            out.setdefault(name, []).extend(tickets)
        stats, self._spilled_stats = self._spilled_stats, []

        def one_path(paths):
            paths = {p for p in paths if p}
            return (paths.pop() if len(paths) == 1
                    else "mixed" if paths else "")

        ops: Dict[str, int] = {}
        for f in stats:
            for op, n in f.backend_ops.items():
                ops[op] = ops.get(op, 0) + n
        self.last_collect_stats = {
            "admitted": sum(f.n_admitted for f in stats),
            "dirty": sum(f.n_dirty for f in stats),
            "scan_path": one_path(f.scan_path for f in stats),
            "join_path": one_path(f.join_path for f in stats),
            "t_stage_s": sum(f.t_stage_s for f in stats),
            "t_dispatch_s": sum(f.t_dispatch_s for f in stats),
            "t_kernel_s": sum(f.t_kernel_s for f in stats),
            "t_collect_s": sum(f.t_collect_s for f in stats),
            "backend_ops": ops}
        return out

    def _collect_oldest(self) -> Dict[str, List[Ticket]]:
        if not self._inflight:
            return {}
        flight = self._inflight.popleft()
        self._spilled_stats.append(flight)
        results = flight.results
        t0 = time.perf_counter()
        jax.block_until_ready(results)
        if flight.merged is not None:
            jax.block_until_ready(flight.merged)
        t_ready = time.perf_counter()
        if self._assemble is not None:
            # sharded heartbeat: the cross-shard routing pass already ran
            # on-device (launched at dispatch); assembling the final
            # per-template results is a device-to-host copy + passthrough
            results = self._assemble(results, flight.merged)
        self.last_overflow = int(results["_overflow"])
        # full-rescan heartbeats have no delta capacities to violate, so
        # the invariant reads 0 rather than a stale delta-cycle value
        self.last_delta_overflow = int(results.get("_delta_overflow", 0))
        self.last_parts_rebuilt = {
            t: bool(v) for t, v in results["_parts_rebuilt"].items()}
        now = time.time()
        out = {}
        for name, tickets in flight.admitted.items():
            res = jax.tree.map(np.asarray, results[name])
            for slot, ticket in enumerate(tickets):
                ticket.result = jax.tree.map(lambda a: a[slot], res)
                ticket.done_time = now
            out[name] = tickets
            self.queries_done += len(tickets)
        flight.t_kernel_s = t_ready - t0
        flight.t_collect_s = time.perf_counter() - t_ready
        self.cycles_run += 1
        return out

    def run_cycle(self) -> Dict[str, List[Ticket]]:
        """One synchronous heartbeat: dispatch then drain all in-flight."""
        self.dispatch()
        out: Dict[str, List[Ticket]] = {}
        while self._inflight:
            for name, tickets in self.collect().items():
                out.setdefault(name, []).extend(tickets)
        return out

    def run_until_drained(self, max_cycles: int = 1000,
                          pipelined: bool = False) -> List[CycleResult]:
        """Cycle until the queues are empty.

        ``max_cycles`` bounds cycles COLLECTED (each return entry is one
        completed heartbeat), not dispatches — dispatching is likewise
        capped by the budget so no admitted work is left un-collected
        when the bound trips.  Returns one ``CycleResult`` (routed
        tickets + observed wall time) per collected cycle, for latency
        accounting.

        pipelined=True keeps up to ``pipeline_depth`` heartbeats in
        flight, overlapping admission/staging for cycle N+1 with device
        execution of cycle N.
        """
        depth = self.pipeline_depth if pipelined else 1
        done: List[CycleResult] = []
        dispatched = 0
        t_prev = time.time()
        while len(done) < max_cycles and (self.pending() or self._inflight
                                          or self._spilled):
            while (self.pending() and dispatched < max_cycles
                   and len(self._inflight) < depth):
                self.dispatch()
                dispatched += 1
            if not self._inflight and not self._spilled:
                break       # budget exhausted with work still queued
            routed = self.collect()
            now = time.time()
            s = self.last_collect_stats
            done.append(CycleResult(tickets=routed, wall_s=now - t_prev,
                                    admitted=s["admitted"],
                                    dirty=s["dirty"],
                                    scan_path=s["scan_path"],
                                    join_path=s["join_path"],
                                    t_stage_s=s["t_stage_s"],
                                    t_dispatch_s=s["t_dispatch_s"],
                                    t_kernel_s=s["t_kernel_s"],
                                    t_collect_s=s["t_collect_s"],
                                    backend_ops=s["backend_ops"]))
            t_prev = now
        return done

    # --------------------------------------------------- host-side fetch
    def snapshot(self, table: str) -> Dict[str, np.ndarray]:
        """Host view of a table's columns/validity at the ORIGINAL
        (unpadded) capacity.  The sharded state keeps columns as flat
        row-major leaves, so the same read works for the single-device,
        row-sharded and replicated layouts alike."""
        schema = self.plan.catalog.schemas[table]
        t = self.state[table]
        T = schema.capacity
        out = {c: np.asarray(t[c])[:T] for c in schema.columns}
        out["_valid"] = np.asarray(t["_valid"])[:T]
        out["_n"] = int(t["_n"])
        return out

    def materialize(self, table: str, row_ids: np.ndarray,
                    cols: Optional[List[str]] = None) -> Dict[str, np.ndarray]:
        """Fetch tuples by row id from the current snapshot (result
        delivery — the Output operator of Fig. 5)."""
        t = self.state[table]
        schema = self.plan.catalog.schemas[table]
        cols = cols or list(schema.columns)
        ids = np.asarray(row_ids)
        safe = np.clip(ids, 0, schema.capacity - 1)
        out = {c: np.where(ids >= 0, np.asarray(t[c])[safe], 0)
               for c in cols}
        out["_row"] = ids
        return out

"""Query-at-a-time baseline engine (the paper's MySQL / "SystemX" role).

One query compiles to one small plan (per template, like a prepared
statement): predicate-pushdown scan -> bounded candidate extraction
(modeling index-assisted access) -> per-query join gathers -> per-query
sort -> limit.  Work grows LINEARLY with the number of queries — the
behaviour SharedDB's shared plan is designed to beat (paper Figs. 10/11).

Results are bit-identical to the shared engine (property-tested).
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.plan import CompiledPlan, QueryTemplate
from repro.core.executor import Ticket
from repro.core.storage import locate_rows_by_key

INT_MIN = -2147483647
INT_MAX = 2147483647


class QueryAtATimeEngine:
    def __init__(self, plan: CompiledPlan,
                 initial_data: Dict[str, Dict[str, np.ndarray]],
                 candidate_cap=4096, jit: bool = True):
        """candidate_cap: int, or {template: int}; a template whose spine
        has no pushdown-able predicate (e.g. best_sellers) needs the full
        spine capacity for exact results — a real system would use an
        index; the cap models that access path's selectivity."""
        self.plan = plan
        self.caps = candidate_cap
        self.state = plan.catalog.init_state(initial_data)
        self._fns = {}
        # preallocated per-template parameter staging (mirrors the shared
        # engine's packed admission: fill in place, one transfer per
        # dispatch).  The transfer below uses jnp.array (copy=True): a
        # plain asarray can be ZERO-copy on the CPU backend, and an
        # in-flight dispatch must not see a later dispatch's overwrite.
        self._param_bufs = {}
        for name, tpl in plan.templates.items():
            fn = self._build(tpl)
            self._fns[name] = jax.jit(fn) if jit else fn
            self._param_bufs[name] = np.zeros((max(len(tpl.preds), 1), 2),
                                              np.int32)
        self.queries_done = 0

    def _cap_for(self, tpl: QueryTemplate) -> int:
        spine_cap = self.plan.catalog.schemas[tpl.spine].capacity
        if isinstance(self.caps, dict):
            k = self.caps.get(tpl.name, 4096)
        else:
            k = self.caps
        has_spine_pred = any(p.table == tpl.spine for p in tpl.preds)
        if not has_spine_pred:
            return spine_cap  # exactness requires the full spine
        return min(k, spine_cap)

    # ------------------------------------------------------------------
    def _build(self, tpl: QueryTemplate):
        plan = self.plan
        K = self._cap_for(tpl)
        schema = plan.catalog.schemas[tpl.spine]

        def fn(storage, params):
            """params: int32[n_preds, 2].  One query at a time."""
            spine = storage[tpl.spine]
            ok = spine["_valid"]
            # push down spine predicates
            for pi, p in enumerate(tpl.preds):
                if p.table != tpl.spine:
                    continue
                col = spine[p.col]
                ok &= (col >= params[pi, 0]) & (col <= params[pi, 1])
            # bounded candidate extraction (index-assisted access model)
            cand = jnp.nonzero(ok, size=K, fill_value=schema.capacity)[0]
            live = cand < schema.capacity
            cand_safe = jnp.minimum(cand, schema.capacity - 1)

            # per-query joins + joined-table predicates
            for j in tpl.joins:
                fk = spine[j.fk_col][cand_safe]
                pk_tbl = storage[j.pk_table]
                pk_schema = plan.catalog.schemas[j.pk_table]
                if pk_schema.indexed:
                    idx = pk_tbl["_pk_index"]
                    safe_fk = jnp.clip(fk, 0, idx.shape[0] - 1)
                    rid = jnp.where((fk >= 0) & (fk < idx.shape[0]),
                                    idx[safe_fk], -1)
                else:
                    # no dense index: key-equality lookup (mirrors the
                    # shared engine's block-join access path)
                    rid = locate_rows_by_key(pk_tbl[pk_schema.pk], fk,
                                             pk_tbl["_valid"])
                live &= rid >= 0
                rid_safe = jnp.clip(rid, 0, pk_tbl["_valid"].shape[0] - 1)
                live &= pk_tbl["_valid"][rid_safe]
                for pi, p in enumerate(tpl.preds):
                    if p.table != j.pk_table:
                        continue
                    col = pk_tbl[p.col][rid_safe]
                    live &= (col >= params[pi, 0]) & (col <= params[pi, 1])

            if tpl.group is not None:
                g = tpl.group
                codes = spine[g.group_col][cand_safe]
                vals = spine[g.agg_col][cand_safe]
                w = live.astype(jnp.float32)
                count = jax.ops.segment_sum(w, codes,
                                            num_segments=g.n_groups)
                ssum = jax.ops.segment_sum(w * vals, codes,
                                           num_segments=g.n_groups)
                score = ssum if g.order_by == "sum" else count
                top_val, top_grp = jax.lax.top_k(score, g.top_k)
                return {"groups": top_grp.astype(jnp.int32),
                        "scores": top_val,
                        "counts": count[top_grp]}

            order = jnp.arange(K)
            if tpl.sort_col:
                key = spine[tpl.sort_col][cand_safe]
                key = jnp.where(live, -key if tpl.sort_desc else key,
                                INT_MAX)
                order = jnp.argsort(key, stable=True)
            else:
                order = jnp.argsort(jnp.where(live, cand, INT_MAX),
                                    stable=True)
            rows = jnp.where(live[order], cand[order], -1)
            n = min(plan.max_results, K)
            out = jnp.full((plan.max_results,), -1, jnp.int32)
            lim = min(tpl.limit, plan.max_results)
            keep = jnp.arange(n) < lim
            return {"rows": out.at[:n].set(
                jnp.where(keep, rows[:n], -1)).astype(jnp.int32)}

        return fn

    # ------------------------------------------------------------------
    def dispatch(self, template: str, params: Dict) -> Ticket:
        """Launch one query's prepared plan; returns while the device
        still computes (the same dispatch/collect protocol as
        SharedDBEngine, so engine comparisons measure like with like)."""
        tpl = self.plan.templates[template]
        arr = self._param_bufs[template]
        for pi in range(len(tpl.preds)):
            arr[pi] = params[pi]
        t = Ticket(0, template, params, time.time())
        t.result = self._fns[template](self.state, jnp.array(arr))
        return t

    def collect(self, t: Ticket) -> Ticket:
        """Block on a dispatched query and materialize its result."""
        t.result = jax.tree.map(np.asarray, t.result)
        t.done_time = time.time()
        self.queries_done += 1
        return t

    def execute(self, template: str, params: Dict) -> Ticket:
        return self.collect(self.dispatch(template, params))

    def execute_batch(self, items: List) -> List[Ticket]:
        """Queries one at a time — the traditional model."""
        return [self.execute(name, params) for name, params in items]

    def apply_update(self, table: str, kind: str, payload: Dict) -> None:
        """Single-statement update (auto-commit), applied immediately."""
        from repro.core.storage import (UpdateSlots, apply_updates,
                                        empty_update_batch)
        schema = self.plan.catalog.schemas[table]
        slots = UpdateSlots(1, 1, 1)
        b = empty_update_batch(schema, slots, xp=np)
        if kind == "insert":
            for c, v in payload.items():
                b["ins_rows"][c][0] = int(v)
            b["ins_mask"][0] = True
        elif kind == "update":
            b["upd_key"][0] = int(payload["key"])
            b["upd_col"][0] = schema.columns.index(payload["col"])
            b["upd_val"][0] = int(payload["val"])
            b["upd_mask"][0] = True
        else:
            b["del_key"][0] = int(payload["key"])
            b["del_mask"][0] = True
        self.state = dict(self.state)
        self.state[table] = apply_updates(
            schema, self.state[table], jax.tree.map(jnp.asarray, b))

"""The data-query model (paper §3.1), TPU-native.

Every (intermediate) relation carries a *query-set* column: the set of
active query ids interested in each tuple.  The paper implements the set as
a linked list (NF2); dynamic lists do not vectorize, so we pack the set into
uint32 bitmask words: ``mask[t, w]`` holds bits for queries 32w..32w+31.

Set algebra becomes lane-parallel bitwise ops (VPU):
    union        = mask_a | mask_b
    intersection = mask_a & mask_b        <- the query_id join predicate!
    membership   = bit test
The intersection IS the paper's amended join predicate
``R.query_id = S.query_id`` (§3.3): a tuple pair joins iff some query wants
both sides.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

WORD = 32


def mask_width(qcap: int) -> int:
    if qcap % WORD != 0:
        raise ValueError(
            f"[planlint:no-bare-assert] query capacity {qcap} is not "
            f"a multiple of {WORD}")
    return qcap // WORD


def empty_mask(n_rows: int, qcap: int):
    return jnp.zeros((n_rows, mask_width(qcap)), jnp.uint32)


def full_mask(n_rows: int, qcap: int):
    return jnp.full((n_rows, mask_width(qcap)), 0xFFFFFFFF, jnp.uint32)


def pack(bits):
    """bool[..., Q] -> uint32[..., Q/32]."""
    *lead, Q = bits.shape
    W = mask_width(Q)
    b = bits.reshape(*lead, W, WORD).astype(jnp.uint32)
    weights = (jnp.uint32(1) << jnp.arange(WORD, dtype=jnp.uint32))
    return jnp.sum(b * weights, axis=-1, dtype=jnp.uint32)


def unpack(mask, qcap: int = None):
    """uint32[..., W] -> bool[..., W*32]."""
    *lead, W = mask.shape
    shifts = jnp.arange(WORD, dtype=jnp.uint32)
    bits = (mask[..., None] >> shifts) & jnp.uint32(1)
    out = bits.reshape(*lead, W * WORD).astype(bool)
    if qcap is not None:
        out = out[..., :qcap]
    return out


def union(a, b):
    return a | b


def intersect(a, b):
    return a & b


def any_query(mask):
    """bool[T]: does any active query want this tuple?"""
    return jnp.any(mask != 0, axis=-1)


def popcount(mask):
    """int32[T]: number of subscribed queries per tuple."""
    return jnp.sum(jax.lax.population_count(mask), axis=-1).astype(jnp.int32)


def query_bit(qid, qcap: int):
    """uint32[W] single-query mask row (qid may be traced)."""
    W = mask_width(qcap)
    word = qid // WORD
    bit = jnp.uint32(1) << jnp.uint32(qid % WORD)
    return jnp.where(jnp.arange(W) == word, bit, jnp.uint32(0))


def select_query(mask, qid):
    """bool[T]: rows subscribed to query `qid` (traced ok)."""
    word = qid // WORD
    bit = jnp.uint32(qid % WORD)
    w = mask[..., word] if isinstance(word, int) else \
        jnp.take(mask, word, axis=-1)
    return ((w >> bit) & jnp.uint32(1)).astype(bool)

"""qwen2-72b [dense] — GQA with QKV bias.  [arXiv:2407.10671; hf]"""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-72b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv=8,
    head_dim=128,
    d_ff=29568,
    vocab=152064,
    qkv_bias=True,
    act="swiglu",
    rope_theta=1e6,
    skip_shapes=("long_500k",),
    skip_reason="pure full attention — see DESIGN.md",
    source="arXiv:2407.10671",
)

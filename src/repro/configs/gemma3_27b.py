"""gemma3-27b [dense] — 5:1 local:global attention, 128k context.

[hf:google/gemma-3-1b-pt; unverified]  Local window 1024; one global layer
per six.  long_500k *runs*: decode against a long KV is linear per step and
5/6 of layers keep only a 1024-token window (see DESIGN.md §Arch-applicability).
"""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-27b",
    family="dense",
    n_layers=62,
    d_model=5376,
    n_heads=32,
    n_kv=16,
    head_dim=128,
    d_ff=21504,
    vocab=262144,
    window=1024,
    local_global=(5, 1),
    act="gelu_glu",
    rope_theta=1e6,
    tie_embeddings=True,
    source="hf:google/gemma-3-1b-pt",
)

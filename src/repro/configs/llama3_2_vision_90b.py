"""llama-3.2-vision-90b [vlm] — cross-attention image layers.

[hf:meta-llama/Llama-3.2-11B-Vision; unverified]  Backbone only: the vision
tower is a stub; ``input_specs`` provides precomputed, projected patch
embeddings (n_vision_tokens x d_model).  One cross-attn layer per five.
"""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv=8,
    head_dim=128,
    d_ff=28672,
    vocab=128256,
    cross_every=5,
    n_vision_tokens=6404,        # 4 tiles x 1601 patch tokens
    act="swiglu",
    rope_theta=5e5,
    skip_shapes=("long_500k",),
    skip_reason="pure full attention — see DESIGN.md",
    source="hf:meta-llama/Llama-3.2-11B-Vision",
)

"""Architecture configs.

One module per assigned architecture (public-literature specs, see the
assignment block in DESIGN.md) plus the paper's own TPC-W/SharedDB engine
config.  ``get_config(arch_id)`` is the single lookup used by the launcher,
the dry-run, tests and benchmarks.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Optional

# ---------------------------------------------------------------------------
# Shape suite (assigned): every LM arch is exercised on these four shapes.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    num_shared: int = 0
    d_ff_expert: int = 0           # per-expert FFN width
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """Unified architecture description for the model zoo."""

    name: str
    family: str                    # dense|moe|ssm|hybrid|audio|vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    qkv_bias: bool = False
    moe: Optional[MoEConfig] = None
    # Attention pattern: window > 0 means sliding-window on "local" layers.
    window: int = 0
    # local:global interleave, e.g. (5, 1) = 5 local then 1 global; (0, 1) =
    # all global.  Lowered as a uniform scan with a per-layer pattern mask.
    local_global: tuple = (0, 1)
    # Encoder-decoder (whisper): encoder layers share the width above.
    enc_dec: bool = False
    n_enc_layers: int = 0
    dec_ratio: int = 8             # dec_len = seq_len // dec_ratio for enc-dec
    # VLM: one cross-attention layer every `cross_every` layers.
    cross_every: int = 0
    n_vision_tokens: int = 6404
    # SSM (mamba2)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    conv_kernel: int = 4
    # Hybrid (recurrentgemma): pattern of block kinds per scan group.
    rglru_pattern: tuple = ()      # e.g. ("rec", "rec", "attn")
    # Shapes this arch supports (long_500k only for sub-quadratic attn).
    skip_shapes: tuple = ()
    skip_reason: str = ""
    # Norm / activation flavour
    act: str = "swiglu"
    norm: str = "rmsnorm"
    tie_embeddings: bool = False
    rope_theta: float = 10000.0
    source: str = ""
    # performance knobs (hillclimbed in EXPERIMENTS.md §Perf)
    moe_dispatch: str = "sort"     # sort | onehot | sharded
    remat: str = "full"            # full | none
    # decode: shard the KV-cache sequence dim over the TP axis (split-KV
    # flash-decoding) — the fix for GQA archs whose kv heads < tp size
    decode_cache_seq_shard: str = "none"   # none | tp
    # constrain sublayer OUTPUTS (pre-residual-add) to the seq-sharded
    # layout so TP reductions lower as reduce-scatter instead of all-reduce
    sp_outputs: bool = False

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    def vocab_padded(self, multiple: int = 2048) -> int:
        return ((self.vocab + multiple - 1) // multiple) * multiple

    def param_count(self) -> int:
        """Analytic parameter count (total; MoE counts all experts)."""
        d, ff, L = self.d_model, self.d_ff, self.n_layers
        hd = self.resolved_head_dim
        attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv * hd) \
            + (self.n_heads * hd) * d
        if self.family == "ssm":
            d_in = self.ssm_expand * d
            nh = d_in // self.ssm_head_dim
            # in_proj (z,x,B,C,dt) + out_proj + conv
            per = d * (2 * d_in + 2 * self.ssm_state + nh) + d_in * d \
                + self.conv_kernel * (d_in + 2 * self.ssm_state)
            body = per * L
        elif self.moe is not None:
            ffe = self.moe.d_ff_expert or ff
            dense_ff = 3 * d * ff * self.moe.num_shared
            expert_ff = 3 * d * ffe * self.moe.num_experts
            router = d * self.moe.num_experts
            body = (attn + dense_ff + expert_ff + router) * L
        else:
            body = (attn + 3 * d * ff) * L
        if self.rglru_pattern:
            # recurrent blocks replace attention in a fraction of layers
            n_rec = sum(1 for k in self.rglru_pattern if k == "rec")
            frac = n_rec / len(self.rglru_pattern)
            d_rnn = d
            rec = d * d_rnn * 2 + d_rnn * d + 3 * d_rnn  # gates + proj + lru
            body = int(L * (frac * (rec + 3 * d * ff)
                            + (1 - frac) * (attn + 3 * d * ff)))
        emb = self.vocab_padded() * d
        unemb = 0 if self.tie_embeddings else self.vocab_padded() * d
        if self.enc_dec:
            enc = (attn + 3 * d * ff) * self.n_enc_layers
            xattn = attn * L  # decoder cross-attention
            body += enc + xattn
        if self.cross_every:
            n_cross = self.n_layers // self.cross_every
            body += attn * n_cross
        return body + emb + unemb

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed top-k + shared)."""
        if self.moe is None:
            return self.param_count()
        d, ff, L = self.d_model, self.d_ff, self.n_layers
        hd = self.resolved_head_dim
        attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv * hd) \
            + (self.n_heads * hd) * d
        ffe = self.moe.d_ff_expert or ff
        active_ff = 3 * d * ffe * (self.moe.top_k + self.moe.num_shared)
        router = d * self.moe.num_experts
        body = (attn + active_ff + router) * L
        emb = self.vocab_padded() * d
        unemb = 0 if self.tie_embeddings else self.vocab_padded() * d
        return body + emb + unemb

    def supports(self, shape_name: str) -> bool:
        return shape_name not in self.skip_shapes


ARCH_IDS = [
    "whisper-small",
    "mixtral-8x22b",
    "qwen2-moe-a2.7b",
    "yi-6b",
    "qwen2-72b",
    "gemma3-27b",
    "stablelm-1.6b",
    "llama-3.2-vision-90b",
    "mamba2-370m",
    "recurrentgemma-2b",
]

_MODULES = {
    "whisper-small": "whisper_small",
    "mixtral-8x22b": "mixtral_8x22b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "yi-6b": "yi_6b",
    "qwen2-72b": "qwen2_72b",
    "gemma3-27b": "gemma3_27b",
    "stablelm-1.6b": "stablelm_1_6b",
    "llama-3.2-vision-90b": "llama3_2_vision_90b",
    "mamba2-370m": "mamba2_370m",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "shareddb-tpcw": "shareddb_tpcw",
}


def get_config(arch_id: str) -> Any:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.CONFIG


def smoke_config(arch_id: str) -> "ArchConfig":
    """Reduced same-family config for CPU smoke tests."""
    cfg = get_config(arch_id)
    if not isinstance(cfg, ArchConfig):
        raise TypeError(f"{arch_id} is not an LM arch config")
    small = dict(
        n_layers=max(2, len(cfg.rglru_pattern) or 0) or 2,
        d_model=64,
        n_heads=4,
        n_kv=min(cfg.n_kv, 4) if cfg.n_kv else 0,
        head_dim=16,
        d_ff=128,
        vocab=256,
        n_enc_layers=2 if cfg.enc_dec else 0,
        cross_every=2 if cfg.cross_every else 0,
        n_vision_tokens=8 if cfg.cross_every else cfg.n_vision_tokens,
        window=8 if cfg.window else 0,
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_head_dim=16 if cfg.ssm_state else cfg.ssm_head_dim,
        ssm_chunk=8 if cfg.ssm_state else cfg.ssm_chunk,
    )
    if cfg.moe is not None:
        small["moe"] = MoEConfig(
            num_experts=4, top_k=min(cfg.moe.top_k, 2),
            num_shared=min(cfg.moe.num_shared, 1), d_ff_expert=64)
    if cfg.rglru_pattern:
        small["n_layers"] = len(cfg.rglru_pattern)
    loc, glob = cfg.local_global
    if loc and glob:
        small["n_layers"] = loc + glob + 1   # one full group + leftover
    if cfg.cross_every:
        small["n_layers"] = 2 * (small["cross_every"] or cfg.cross_every)
    return dataclasses.replace(cfg, **small)

"""recurrentgemma-2b [hybrid] — RG-LRU + local attention, 2:1 pattern.

[arXiv:2402.19427; hf]  Griffin-style: two recurrent (RG-LRU) blocks per
local-attention (MQA, window 2048) block.  Constant recurrent state + bounded
window -> all shapes run, including long_500k.
"""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv=1,                      # MQA
    head_dim=256,
    d_ff=7680,
    vocab=256000,
    window=2048,
    rglru_pattern=("rec", "rec", "attn"),
    act="gelu_glu",
    tie_embeddings=True,
    source="arXiv:2402.19427",
)

"""mamba2-370m [ssm] — SSD (state-space duality), attention-free.

[arXiv:2405.21060; unverified]  Constant-size recurrent state: all four
shapes run, including long_500k.
"""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=0,                   # attention-free
    n_kv=0,
    d_ff=0,
    vocab=50280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=256,
    conv_kernel=4,
    tie_embeddings=True,
    norm="rmsnorm",
    source="arXiv:2405.21060",
)

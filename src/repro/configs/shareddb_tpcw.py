"""The paper's own configuration: the SharedDB engine over the TPC-W schema.

This mirrors Figure 6 of the paper (26 database operators over the nine TPC-W
base tables) at engine scale, plus the cycle/queue capacities that implement
the batch-oriented execution model.
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    name: str = "shareddb-tpcw"
    family: str = "engine"
    # Query-batch capacity per heartbeat cycle (global Q_max is per-operator
    # capacity x live templates; 1024 matches "hundreds of concurrent
    # queries and updates" in the paper).
    max_queries_per_cycle: int = 1024
    # Per-operator concurrent-query capacity (bitmask width = ceil(cap/32)).
    operator_query_capacity: int = 256
    # Storage capacities (rows) for the scaled TPC-W instance used in
    # benchmarks; base cardinalities follow the TPC-W scale rules.
    scale_items: int = 10000
    scale_customers: int = 28800
    max_results_per_query: int = 128
    updates_per_cycle: int = 256
    # SLA model (paper §3.5): provision so worst-case cycle <= sla_seconds/2.
    sla_seconds: float = 3.0


CONFIG = EngineConfig()

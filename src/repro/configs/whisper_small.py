"""whisper-small [audio] — enc-dec, conv frontend stubbed as frame embeddings.

[arXiv:2212.04356; unverified]  The transformer backbone only: the audio
frontend is a stub; ``input_specs`` provides precomputed frame embeddings.
Full attention both sides -> long_500k skipped (quadratic encoder).
"""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="whisper-small",
    family="audio",
    n_layers=12,                 # decoder layers
    n_enc_layers=12,
    enc_dec=True,
    dec_ratio=8,                 # dec_len = seq_len // 8 (ASR token ratio)
    d_model=768,
    n_heads=12,
    n_kv=12,
    head_dim=64,
    d_ff=3072,
    vocab=51865,
    act="gelu",
    norm="layernorm",
    skip_shapes=("long_500k",),
    skip_reason="pure full attention (enc-dec); 500k quadratic encoder "
                "prefill is out of roofline scope — see DESIGN.md",
    source="arXiv:2212.04356",
)

"""yi-6b [dense] — llama-architecture GQA.  [arXiv:2403.04652; hf]"""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="yi-6b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv=4,
    head_dim=128,
    d_ff=11008,
    vocab=64000,
    act="swiglu",
    rope_theta=5e6,
    skip_shapes=("long_500k",),
    skip_reason="pure full attention — see DESIGN.md",
    source="arXiv:2403.04652",
)

"""mixtral-8x22b [moe] — 8 experts top-2, sliding-window attention.

[arXiv:2401.04088; hf]  SWA window 4096 bounds the KV cache, so the
long_500k decode shape runs (sub-quadratic).
"""
from repro.configs import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv=8,
    head_dim=128,
    d_ff=16384,
    vocab=32768,
    moe=MoEConfig(num_experts=8, top_k=2, num_shared=0, d_ff_expert=16384),
    window=4096,
    local_global=(1, 0),         # all layers sliding-window
    act="swiglu",
    # shipped default = shard-local dispatch (EXPERIMENTS.md §Perf: 6.5-8.3x
    # vs the global-sort baseline; reproduce baseline via moe_dispatch=sort)
    moe_dispatch="sharded",
    rope_theta=1e6,
    source="arXiv:2401.04088",
)

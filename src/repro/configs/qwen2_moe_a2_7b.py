"""qwen2-moe-a2.7b [moe] — 4 shared + 60 routed experts, top-4.

[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]  Full attention -> long_500k skipped.
"""
from repro.configs import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv=16,
    head_dim=128,
    d_ff=1408,
    vocab=151936,
    qkv_bias=True,
    moe=MoEConfig(num_experts=60, top_k=4, num_shared=4, d_ff_expert=1408),
    act="swiglu",
    # shipped default = shard-local dispatch (EXPERIMENTS.md §Perf: 6.5-8.3x
    # vs the global-sort baseline; reproduce baseline via moe_dispatch=sort)
    moe_dispatch="sharded",
    skip_shapes=("long_500k",),
    skip_reason="pure full attention; 500k KV decode excluded per shape "
                "applicability rules — see DESIGN.md",
    source="hf:Qwen/Qwen1.5-MoE-A2.7B",
)

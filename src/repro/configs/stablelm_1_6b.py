"""stablelm-1.6b [dense].  [hf:stabilityai/stablelm-2-1_6b; unverified]"""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-1.6b",
    family="dense",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv=32,
    head_dim=64,
    d_ff=5632,
    vocab=100352,
    act="swiglu",
    norm="layernorm",
    skip_shapes=("long_500k",),
    skip_reason="pure full attention — see DESIGN.md",
    source="hf:stabilityai/stablelm-2-1_6b",
)

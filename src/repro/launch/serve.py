"""Serving launcher: SharedDB-cycle LM serving with batched requests.

  PYTHONPATH=src python -m repro.launch.serve --arch stablelm-1.6b --smoke \
      --requests 32 --capacity 8
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.configs import get_config, smoke_config
from repro.launch.mesh import make_axes, make_production_mesh
from repro.serving import CycleServer


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--capacity", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--prefill-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--mesh", default="none",
                    choices=["none", "single", "multi"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = None if args.mesh == "none" else make_production_mesh(
        multi_pod=args.mesh == "multi")
    axes = make_axes(mesh)
    server = CycleServer(cfg, axes, capacity=args.capacity,
                         max_seq=args.max_seq,
                         prefill_len=args.prefill_len, seed=args.seed)

    rng = np.random.default_rng(args.seed)
    t0 = time.time()
    for _ in range(args.requests):
        prompt = rng.integers(1, cfg.vocab, args.prefill_len).tolist()
        server.submit(prompt, max_new_tokens=args.new_tokens)
    done = server.run_until_drained()
    dt = time.time() - t0
    toks = sum(len(r.output) for r in done)
    lats = [r.done_time - r.arrival for r in done]
    ftl = [r.first_token_time - r.arrival for r in done]
    print(f"arch={cfg.name} requests={len(done)} cycles={server.cycles} "
          f"tokens={toks}")
    print(f"throughput: {toks/dt:.1f} tok/s | {len(done)/dt:.2f} req/s")
    print(f"latency p50={np.percentile(lats,50)*1e3:.0f}ms "
          f"p99={np.percentile(lats,99)*1e3:.0f}ms | first-token "
          f"p50={np.percentile(ftl,50)*1e3:.0f}ms")
    assert all(len(r.output) == args.new_tokens for r in done)
    return done


if __name__ == "__main__":
    main()

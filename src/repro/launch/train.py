"""Training launcher.

CPU-scale usage (smoke config, real steps):
  PYTHONPATH=src python -m repro.launch.train --arch stablelm-1.6b \
      --smoke --steps 30 --batch 8 --seq 64 --ckpt /tmp/ckpt

Production usage is the same entrypoint with --mesh single|multi (the
dry-run proves every (arch x shape x mesh) lowers; this driver is what a
real cluster job would exec per host).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, smoke_config
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.launch.mesh import make_axes, make_production_mesh
from repro.models.registry import get_model
from repro.optim.adamw import AdamWConfig
from repro.runtime import FaultTolerantLoop


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--save-every", type=int, default=10)
    ap.add_argument("--mesh", default="none",
                    choices=["none", "single", "multi"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = None if args.mesh == "none" else make_production_mesh(
        multi_pod=args.mesh == "multi")
    axes = make_axes(mesh)
    api = get_model(cfg, axes, AdamWConfig(lr=args.lr))

    dcfg = DataConfig(
        vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch,
        seed=args.seed,
        frames_dim=cfg.d_model if cfg.enc_dec else 0,
        frames_len=args.seq * cfg.dec_ratio if cfg.enc_dec else 0,
        vision_tokens=cfg.n_vision_tokens if cfg.cross_every else 0,
        vision_dim=cfg.d_model if cfg.cross_every else 0)
    pipe = TokenPipeline(dcfg)

    params = api.init_params(jax.random.PRNGKey(args.seed))
    opt = api.init_opt(params)
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.2f}M "
          f"mesh={args.mesh}", flush=True)

    jit_step = jax.jit(api.train_step, donate_argnums=(0, 1))

    def to_dev(b):
        cast = {k: jnp.asarray(v, jnp.bfloat16 if v.dtype == np.float32
                               else v.dtype) for k, v in b.items()}
        return cast

    def step_fn(state, step):
        # restored checkpoints arrive as host numpy: re-commit to device
        # (no-op for arrays already on device; donation requires jax.Array)
        params, opt = jax.tree.map(jnp.asarray, state)
        batch = to_dev(pipe.batch_at(step))
        loss, params, opt, gnorm = jit_step(params, opt, batch)
        return (params, opt), {"step": step, "loss": float(loss),
                               "gnorm": float(gnorm)}

    state = (params, opt)
    t0 = time.time()
    if args.ckpt:
        ckpt = CheckpointManager(args.ckpt)
        loop = FaultTolerantLoop(step_fn, ckpt,
                                 save_every=args.save_every)
        start = ckpt.latest_step() or 0
        if start:
            state, manifest = ckpt.restore(state, start)
            print(f"resumed from step {start}", flush=True)
        state, log = loop.run(state, start, args.steps - start)
    else:
        log = []
        for s in range(args.steps):
            state, m = step_fn(state, s)
            log.append(m)
    for m in log:
        if m["step"] % max(1, args.steps // 10) == 0 \
                or m["step"] == args.steps - 1:
            print(f"step {m['step']:5d} loss {m['loss']:.4f} "
                  f"gnorm {m['gnorm']:.3f}", flush=True)
    dt = time.time() - t0
    if log:
        first, last = log[0]["loss"], log[-1]["loss"]
        print(f"done: loss {first:.4f} -> {last:.4f} "
              f"({args.steps} steps, {dt:.1f}s)", flush=True)
    pipe.stop()
    return log


if __name__ == "__main__":
    main()

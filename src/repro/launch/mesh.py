"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.  Single pod: 16 x 16 = 256 chips ("data","model").
Multi-pod: 2 x 16 x 16 = 512 chips ("pod","data","model") — the pod axis
composes with data parallelism, so batch and gradient all-reduce shard
across pods with no new code paths.
"""
from __future__ import annotations

import jax

from repro.models.common import MeshAxes


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 512 if multi_pod else 256
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for the production mesh, have "
            f"{len(devices)}; the dry-run sets "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=512")
    return jax.make_mesh(shape, axes, devices=devices)


def make_axes(mesh) -> MeshAxes:
    """Logical axis bundle for a production mesh."""
    if mesh is None:
        return MeshAxes()
    if "pod" in mesh.axis_names:
        return MeshAxes(mesh=mesh, dp=("pod", "data"), fsdp="data",
                        tp="model")
    return MeshAxes(mesh=mesh, dp=("data",), fsdp="data", tp="model")

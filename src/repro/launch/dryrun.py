import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x shape x mesh)
cell with ShapeDtypeStruct inputs (no allocation) and record

  * memory_analysis()      — proves the step fits per device,
  * cost_analysis()        — HLO FLOPs / bytes for the roofline,
  * the collective schedule parsed from compiled.as_text().

Scan-depth extrapolation: cost_analysis counts a scan body ONCE regardless
of trip count, so each cell is additionally lowered at depth G=0 (fixed
costs: embedding, loss, leftover layers) and G=2 (fixed + one body); the
true total is  m0 + n_groups * (m2 - m0).

Results are cached incrementally in a JSON file; re-runs skip finished
cells.  Usage:

  PYTHONPATH=src python -m repro.launch.dryrun --arch all --mesh both \
      --out results/dryrun.json
"""

import argparse          # noqa: E402
import dataclasses       # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import ARCH_IDS, SHAPES, get_config  # noqa: E402
from repro.launch.mesh import make_axes, make_production_mesh  # noqa: E402
from repro.models import transformer  # noqa: E402
from repro.models.registry import get_model  # noqa: E402
from repro.roofline.analysis import (model_flops, parse_collectives,  # noqa: E402
                                     roofline_terms)


def _named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def _reduced(cfg, n_groups: int):
    """Config whose program has `n_groups` scan groups (same leftovers)."""
    prog = transformer.build_program(cfg)
    L = n_groups * len(prog.group) + len(prog.leftover)
    kw = {"n_layers": L}
    if cfg.enc_dec:
        kw["n_enc_layers"] = n_groups
    return dataclasses.replace(cfg, **kw)


def _jit_cell(api, shape, mesh, axes, donate=True):
    spec_tree = api.input_specs(shape)
    pspec_tree = api.input_pspecs(shape)
    pspecs = api.param_specs()
    b_ok = shape.global_batch % axes.dp_size == 0
    b = axes.dp if b_ok else None
    logits_spec = P(b, axes.tp)

    if shape.kind == "train":
        in_sh = (_named(mesh, pspecs), _named(mesh, api.opt_specs()),
                 _named(mesh, pspec_tree["batch"]))
        out_sh = (NamedSharding(mesh, P()), _named(mesh, pspecs),
                  _named(mesh, api.opt_specs()), NamedSharding(mesh, P()))
        args = (api.param_shapes(),
                jax.eval_shape(api.init_opt, api.param_shapes()),
                spec_tree["batch"])
        dn = (0, 1) if donate else ()
    elif shape.kind == "prefill":
        cap = api.dec_len(shape.seq_len)
        _, cache_specs = transformer.cache_struct(
            api.cfg, shape.global_batch, cap, axes,
            ctx_len=api.ctx_len(shape.seq_len))
        in_sh = (_named(mesh, pspecs), _named(mesh, pspec_tree["batch"]))
        out_sh = (NamedSharding(mesh, logits_spec),
                  _named(mesh, cache_specs))
        args = (api.param_shapes(), spec_tree["batch"])
        dn = ()
    else:
        in_sh = (_named(mesh, pspecs), _named(mesh, pspec_tree["caches"]),
                 _named(mesh, pspec_tree["tokens"]),
                 _named(mesh, pspec_tree["positions"]))
        out_sh = (NamedSharding(mesh, logits_spec),
                  _named(mesh, pspec_tree["caches"]))
        args = (api.param_shapes(), spec_tree["caches"],
                spec_tree["tokens"], spec_tree["positions"])
        dn = (1,) if donate else ()

    fn = jax.jit(api.step_fn(shape), in_shardings=in_sh,
                 out_shardings=out_sh, donate_argnums=dn)
    return fn, args


def lower_cell(arch: str, shape_name: str, mesh, multi_pod: bool):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    axes = make_axes(mesh)
    api = get_model(cfg, axes)
    fn, args = _jit_cell(api, shape, mesh, axes)
    lowered = fn.lower(*args)
    return lowered, api, shape


def analyse_cell(arch: str, shape_name: str, mesh, multi_pod: bool,
                 extrapolate: bool = True, overrides: dict = None,
                 fsdp: str = "data"):
    t0 = time.time()
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    axes = make_axes(mesh)
    if fsdp == "none":
        axes = dataclasses.replace(axes, fsdp=None)
    n_chips = 512 if multi_pod else 256

    def measure(cfg_x):
        api = get_model(cfg_x, axes)
        fn, args = _jit_cell(api, shape, mesh, axes)
        lowered = fn.lower(*args)
        compiled = lowered.compile()
        ca = compiled.cost_analysis() or {}
        coll = parse_collectives(compiled.as_text())
        return compiled, ca, coll

    # full-depth compile: memory analysis + proof the cell lowers/compiles
    compiled, ca_full, coll_full = measure(cfg)
    ma = compiled.memory_analysis()
    full_groups = transformer.build_program(cfg).n_groups

    def pick(ca, key):
        return float(ca.get(key, 0.0))

    if extrapolate and full_groups >= 2:
        _, ca0, coll0 = measure(_reduced(cfg, 0))
        _, ca2, coll2 = measure(_reduced(cfg, 2))

        def extr(v0, v2):
            body = (v2 - v0) / 2.0  # per scan group
            return v0 + full_groups * body, body

        # cost_analysis is per-device (per-partition module): x n_chips
        flops, per_group_flops = extr(pick(ca0, "flops"),
                                      pick(ca2, "flops"))
        flops *= n_chips
        per_group_flops *= n_chips
        bytes_acc, _ = extr(pick(ca0, "bytes accessed"),
                            pick(ca2, "bytes accessed"))
        bytes_acc *= n_chips
        link_traffic, _ = extr(float(coll0["total_link_traffic"]),
                               float(coll2["total_link_traffic"]))
    else:
        flops = pick(ca_full, "flops") * n_chips
        bytes_acc = pick(ca_full, "bytes accessed") * n_chips
        link_traffic = float(coll_full["total_link_traffic"])
        per_group_flops = 0.0

    coll_bytes = link_traffic * n_chips  # global bytes crossing links
    terms = roofline_terms(flops, bytes_acc, coll_bytes, n_chips)
    mf = model_flops(cfg, shape)
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "pod2x16x16" if multi_pod else "pod16x16",
        "n_chips": n_chips,
        "status": "ok",
        "memory": {
            "argument_bytes_per_device": ma.argument_size_in_bytes,
            "output_bytes_per_device": ma.output_size_in_bytes,
            "temp_bytes_per_device": ma.temp_size_in_bytes,
            "alias_bytes_per_device": ma.alias_size_in_bytes,
            "code_bytes": ma.generated_code_size_in_bytes,
        },
        "hlo_flops": flops,
        "hlo_bytes": bytes_acc,
        "collective_bytes": coll_bytes,
        "collectives": coll_full,
        "per_group_flops": per_group_flops,
        "model_flops": mf,
        "useful_flops_ratio": (mf / flops) if flops else 0.0,
        "roofline": terms,
        "wall_s": round(time.time() - t0, 2),
    }
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["both", "single", "multi"])
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--no-extrapolate", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--set", dest="overrides", action="append", default=[],
                    help="config override key=value (perf variants)")
    ap.add_argument("--fsdp", default="data", choices=["data", "none"],
                    help="none = TP-only weights (inference sharding)")
    ap.add_argument("--tag", default="",
                    help="variant tag appended to result keys")
    args = ap.parse_args()

    overrides = {}
    for kv in args.overrides:
        k, v = kv.split("=", 1)
        overrides[k] = v

    archs = ARCH_IDS if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"both": [False, True], "single": [False],
              "multi": [True]}[args.mesh]

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results = {}
    if os.path.exists(args.out) and not args.force:
        with open(args.out) as f:
            results = json.load(f)

    mesh_cache = {}
    for multi in meshes:
        mesh_cache[multi] = make_production_mesh(multi_pod=multi)

    for multi in meshes:
        mesh = mesh_cache[multi]
        mesh_name = "pod2x16x16" if multi else "pod16x16"
        for arch in archs:
            cfg = get_config(arch)
            for shape_name in shapes:
                key = f"{arch}|{shape_name}|{mesh_name}"
                if args.tag:
                    key += f"|{args.tag}"
                if key in results and results[key].get("status") in (
                        "ok", "skipped") and not args.force:
                    continue
                if not cfg.supports(shape_name):
                    results[key] = {
                        "arch": arch, "shape": shape_name,
                        "mesh": mesh_name, "status": "skipped",
                        "reason": cfg.skip_reason}
                    print(f"SKIP {key}: {cfg.skip_reason[:60]}", flush=True)
                else:
                    try:
                        rec = analyse_cell(
                            arch, shape_name, mesh, multi,
                            extrapolate=not args.no_extrapolate,
                            overrides=overrides, fsdp=args.fsdp)
                        if args.tag:
                            rec["variant"] = args.tag
                        results[key] = rec
                        r = rec["roofline"]
                        print(f"OK   {key}: dom={r['dominant']} "
                              f"frac={r['roofline_fraction']:.3f} "
                              f"step={r['step_time_s']:.4f}s "
                              f"({rec['wall_s']}s)", flush=True)
                    except Exception as e:  # noqa: BLE001
                        results[key] = {
                            "arch": arch, "shape": shape_name,
                            "mesh": mesh_name, "status": "error",
                            "error": f"{type(e).__name__}: {e}"}
                        print(f"FAIL {key}: {type(e).__name__}: {e}",
                              flush=True)
                        traceback.print_exc(limit=4)
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)

    n_ok = sum(1 for v in results.values() if v.get("status") == "ok")
    n_skip = sum(1 for v in results.values() if v.get("status") == "skipped")
    n_err = sum(1 for v in results.values() if v.get("status") == "error")
    print(f"done: {n_ok} ok, {n_skip} skipped, {n_err} errors", flush=True)
    return 0 if n_err == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())

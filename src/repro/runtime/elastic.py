"""Elastic scaling: re-mesh and re-lower when hosts join/leave.

SharedDB's always-on plan is compiled for a fixed mesh; elasticity is
handled at CYCLE boundaries (never inside a step):

  1. failure/resize detected (heartbeats, scheduler event);
  2. drain: finish the in-flight cycle, checkpoint (atomic);
  3. pick the largest supported mesh <= surviving chips from the ladder;
  4. re-lower the same step functions under the new mesh (pure function of
     config x mesh — this is exactly what launch/dryrun.py proves compiles
     for every (arch x shape x mesh));
  5. restore the checkpoint re-sharded (per-host shards re-read by the new
     owners) and resume at the saved step.

The drain -> re-lower -> resume recipe is shared machinery: the same
skeleton drives plan FOLDING (core/folding.py), where the re-lower happens
in the BACKGROUND while the old compiled heartbeat keeps serving, and the
drain/swap collapses to a single beat boundary.  ``relower_recipe``
produces both variants.

The mesh ladder keeps axis shapes divisor-friendly so every config in
repro.configs stays shardable after shrink.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import jax


# (pods, data, model) ladder — model axis kept at 16 so TP-sharded configs
# stay valid; shrink sheds data-parallel rows first (batch divisibility is
# re-checked against the config at selection time).
DEFAULT_LADDER: List[Tuple[int, ...]] = [
    (2, 16, 16), (1, 16, 16), (1, 8, 16), (1, 4, 16), (1, 2, 16),
    (1, 1, 16), (1, 1, 8), (1, 1, 4), (1, 1, 2), (1, 1, 1),
]


def relower_recipe(current, target, *, what: str = "step functions",
                   background: bool = False) -> dict:
    """The drain -> re-lower -> resume recipe as structured data.

    ``background=False`` is the elastic-shrink variant (stop-the-world at a
    cycle boundary: drain, checkpoint, re-lower, restore).  ``background=
    True`` is the plan-folding variant: the re-lower overlaps serving and
    only the swap itself lands at a beat boundary, so already-admitted
    clients keep their 2-cycle latency bound throughout.
    """
    if background:
        steps = [
            f"re-lower {what} under {target} in the background "
            "(old compiled heartbeat keeps serving)",
            "drain in-flight beats at the next beat boundary",
            "migrate carries into the new layout (atomic swap)",
            "resume: first post-swap beat is a full-rescan reseed",
        ]
    else:
        steps = [
            "drain in-flight cycle",
            "checkpoint (atomic commit)",
            f"re-lower {what} under mesh {target}",
            "restore re-sharded checkpoint",
            "resume at saved step",
        ]
    return {"current": current, "target": target, "steps": steps}


@dataclasses.dataclass
class ElasticMeshManager:
    ladder: List[Tuple[int, ...]] = dataclasses.field(
        default_factory=lambda: list(DEFAULT_LADDER))

    def __post_init__(self):
        # ``select`` returns the FIRST rung that fits, which is only the
        # LARGEST rung when the ladder is sorted descending by chip count.
        # A hand-built unsorted ladder used to silently under-provision
        # (e.g. [(1,1,1), (1,2,2)] always selected the 1-chip rung) —
        # validate the rungs and normalize the order at construction.
        for shape in self.ladder:
            if len(shape) != 3 or any(
                    not isinstance(d, int) or d < 1 for d in shape):
                raise ValueError(
                    f"ladder rung {shape!r} is not a (pods, data, model) "
                    "tuple of positive ints")
        self.ladder = sorted(self.ladder,
                             key=lambda s: s[0] * s[1] * s[2],
                             reverse=True)

    def select(self, chips_alive: int,
               global_batch: Optional[int] = None) -> Tuple[int, ...]:
        """Largest rung that fits the surviving chips (and batch)."""
        for shape in self.ladder:
            n = shape[0] * shape[1] * shape[2]
            if n > chips_alive:
                continue
            if global_batch is not None:
                dp = shape[0] * shape[1]
                if global_batch % dp != 0:
                    continue
            return shape
        raise RuntimeError(f"no viable mesh for {chips_alive} chips")

    def make_mesh(self, shape: Tuple[int, ...],
                  devices: Optional[Sequence] = None):
        """Build the mesh, optionally restricted to an ALIVE device list.

        ``jax.devices()[:n]`` is only correct when the failure happened at
        the tail of the device list; after a mid-list failure the dead
        device is still enumerated and would be meshed in.  Callers that
        learned of a death (heartbeats) pass the surviving devices
        explicitly.
        """
        n = shape[0] * shape[1] * shape[2]
        pool = list(devices) if devices is not None else jax.devices()
        if len(pool) < n:
            raise RuntimeError(
                f"mesh shape {shape} needs {n} devices, only "
                f"{len(pool)} alive")
        pool = pool[:n]
        if shape[0] > 1:
            return jax.make_mesh(shape, ("pod", "data", "model"),
                                 devices=pool)
        return jax.make_mesh(shape[1:], ("data", "model"), devices=pool)

    def shrink_plan(self, current: Tuple[int, ...], chips_alive: int,
                    global_batch: Optional[int] = None) -> dict:
        """The drain -> re-mesh -> restore recipe as structured data."""
        target = self.select(chips_alive, global_batch)
        return relower_recipe(current, target, what="step")

"""Elastic scaling: re-mesh and re-lower when hosts join/leave.

SharedDB's always-on plan is compiled for a fixed mesh; elasticity is
handled at CYCLE boundaries (never inside a step):

  1. failure/resize detected (heartbeats, scheduler event);
  2. drain: finish the in-flight cycle, checkpoint (atomic);
  3. pick the largest supported mesh <= surviving chips from the ladder;
  4. re-lower the same step functions under the new mesh (pure function of
     config x mesh — this is exactly what launch/dryrun.py proves compiles
     for every (arch x shape x mesh));
  5. restore the checkpoint re-sharded (per-host shards re-read by the new
     owners) and resume at the saved step.

The mesh ladder keeps axis shapes divisor-friendly so every config in
repro.configs stays shardable after shrink.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import jax


# (pods, data, model) ladder — model axis kept at 16 so TP-sharded configs
# stay valid; shrink sheds data-parallel rows first (batch divisibility is
# re-checked against the config at selection time).
DEFAULT_LADDER: List[Tuple[int, ...]] = [
    (2, 16, 16), (1, 16, 16), (1, 8, 16), (1, 4, 16), (1, 2, 16),
    (1, 1, 16), (1, 1, 8), (1, 1, 4), (1, 1, 2), (1, 1, 1),
]


@dataclasses.dataclass
class ElasticMeshManager:
    ladder: List[Tuple[int, ...]] = dataclasses.field(
        default_factory=lambda: list(DEFAULT_LADDER))

    def select(self, chips_alive: int,
               global_batch: Optional[int] = None) -> Tuple[int, ...]:
        """Largest rung that fits the surviving chips (and batch)."""
        for shape in self.ladder:
            n = shape[0] * shape[1] * shape[2]
            if n > chips_alive:
                continue
            if global_batch is not None:
                dp = shape[0] * shape[1]
                if global_batch % dp != 0:
                    continue
            return shape
        raise RuntimeError(f"no viable mesh for {chips_alive} chips")

    def make_mesh(self, shape: Tuple[int, ...]):
        n = shape[0] * shape[1] * shape[2]
        devices = jax.devices()[:n]
        if shape[0] > 1:
            return jax.make_mesh(shape, ("pod", "data", "model"),
                                 devices=devices)
        return jax.make_mesh(shape[1:], ("data", "model"), devices=devices)

    def shrink_plan(self, current: Tuple[int, ...], chips_alive: int,
                    global_batch: Optional[int] = None) -> dict:
        """The drain -> re-mesh -> restore recipe as structured data."""
        target = self.select(chips_alive, global_batch)
        return {
            "current": current,
            "target": target,
            "steps": [
                "drain in-flight cycle",
                "checkpoint (atomic commit)",
                f"re-lower step under mesh {target}",
                "restore re-sharded checkpoint",
                "resume at saved step",
            ],
        }

from repro.runtime.fault_tolerance import (FaultTolerantLoop,  # noqa: F401
                                           StragglerPolicy)
from repro.runtime.elastic import ElasticMeshManager  # noqa: F401

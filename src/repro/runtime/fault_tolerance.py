"""Fault tolerance for 1000+-node runs.

Design (validated here by fault-injection tests; the hardware-specific
health signals are pluggable):

* checkpoint/restart — the training loop is a pure function of
  (params, opt, data_step); CheckpointManager commits atomically, so a
  restart resumes bit-exact from the last committed step (the data
  pipeline replays from its step counter — no data loss or duplication).
* heartbeats — each host publishes a monotonically increasing step; a
  host silent for `dead_after_s` is declared failed and triggers the
  elastic path (runtime/elastic.py).
* straggler mitigation — SharedDB's bounded cycles make stragglers
  well-defined: every step has the SAME work, so a host slower than
  median * straggler_factor for `patience` consecutive steps is flagged
  and (policy) either remapped out at the next checkpoint boundary or its
  shard is replicated to a hot spare.  There is no speculative re-execution
  inside a step: XLA steps are deterministic and collectives would
  deadlock — mitigation happens at step granularity.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional


@dataclasses.dataclass
class StragglerPolicy:
    factor: float = 1.5          # slower than median x factor == straggler
    patience: int = 5            # consecutive slow steps before flagging
    dead_after_s: float = 60.0   # heartbeat silence == failure


class HeartbeatBoard:
    """In-process stand-in for the cluster KV store (etcd/Borg/SLURM)."""

    def __init__(self):
        self._last: Dict[int, float] = {}
        self._step: Dict[int, int] = {}
        self._durations: Dict[int, List[float]] = {}
        # expected membership: registration time stands in for the first
        # beat of a host that never manages one (a host dead on arrival
        # would otherwise never appear in _last and never be declared dead)
        self._registered: Dict[int, float] = {}

    def register(self, host: int, now: Optional[float] = None):
        """Declare a host EXPECTED.  Silence counts from this moment."""
        self._registered.setdefault(
            host, now if now is not None else time.time())

    def beat(self, host: int, step: int, duration_s: float,
             now: Optional[float] = None):
        t = now if now is not None else time.time()
        self._registered.setdefault(host, t)
        self._last[host] = t
        self._step[host] = step
        self._durations.setdefault(host, []).append(duration_s)

    def dead_hosts(self, policy: StragglerPolicy,
                   now: Optional[float] = None) -> List[int]:
        now = now if now is not None else time.time()
        return sorted(
            h for h, t0 in self._registered.items()
            if now - self._last.get(h, t0) > policy.dead_after_s)

    def stragglers(self, policy: StragglerPolicy) -> List[int]:
        if not self._durations:
            return []
        import numpy as np
        recent = {h: d[-policy.patience:]
                  for h, d in self._durations.items()}
        med = float(np.median([x for d in recent.values() for x in d]))
        out = []
        for h, d in recent.items():
            if len(d) >= policy.patience and \
                    all(x > policy.factor * med for x in d):
                out.append(h)
        return out


class FaultTolerantLoop:
    """Wraps a step function with checkpoint/restart + health tracking.

    step_fn(state, step) -> (state, metrics); state is a pytree.
    Failures raised by step_fn (or injected) roll back to the last
    committed checkpoint and replay — the paper-style bounded cycle makes
    replay cost at most `save_every` steps.
    """

    def __init__(self, step_fn: Callable, ckpt_manager, *,
                 save_every: int = 50,
                 policy: StragglerPolicy = StragglerPolicy(),
                 host_id: int = 0,
                 max_restarts: int = 3):
        self.step_fn = step_fn
        self.ckpt = ckpt_manager
        self.save_every = save_every
        self.policy = policy
        self.host_id = host_id
        self.max_restarts = max_restarts
        self.board = HeartbeatBoard()
        self.board.register(self.host_id)
        self.restarts = 0

    def run(self, state, start_step: int, n_steps: int,
            fail_at: Optional[Dict[int, Exception]] = None):
        """fail_at: {step: exc} fault injection used by the test-suite."""
        step = start_step
        metrics_log = []
        injected = dict(fail_at or {})
        while step < start_step + n_steps:
            t0 = time.time()
            try:
                if step in injected:
                    raise injected.pop(step)
                state, metrics = self.step_fn(state, step)
            except Exception as e:  # noqa: BLE001 — restart path
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise
                last = self.ckpt.latest_step()
                if last is None:
                    raise RuntimeError("failure before first checkpoint") \
                        from e
                state, manifest = self.ckpt.restore(state, last)
                step = manifest["extra"]["next_step"]
                continue
            self.board.beat(self.host_id, step, time.time() - t0)
            metrics_log.append(metrics)
            step += 1
            if step % self.save_every == 0:
                self.ckpt.save(state, step, extra={"next_step": step})
        return state, metrics_log
